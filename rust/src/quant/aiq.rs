//! AIQ quantizer/dequantizer.

use crate::error::{Error, Result};

/// Minimum supported bit-width.
pub const MIN_Q: u8 = 1;
/// Maximum supported bit-width (symbols stay well inside `u16`).
pub const MAX_Q: u8 = 16;

/// Quantization parameters for one tensor (Eq. 6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Bit-width `Q`; alphabet is `2^Q`.
    pub q: u8,
    /// Scale `s = (x_max − x_min) / (2^Q − 1)`.
    pub scale: f32,
    /// Zero point `z = round(−x_min / s)`, already clamped into the
    /// representable range.
    pub zero: i32,
}

impl QuantParams {
    /// Derive parameters from data min/max at bit-width `q`.
    ///
    /// Degenerate ranges (`x_max == x_min`, empty tensors) produce
    /// `scale = 1`, mapping everything to a single symbol — lossless for
    /// constant tensors, which do occur at aggressive split points.
    pub fn from_min_max(q: u8, x_min: f32, x_max: f32) -> Result<Self> {
        if !(MIN_Q..=MAX_Q).contains(&q) {
            return Err(Error::invalid(format!("Q={q} outside [{MIN_Q},{MAX_Q}]")));
        }
        if !x_min.is_finite() || !x_max.is_finite() || x_min > x_max {
            return Err(Error::invalid(format!("bad range [{x_min}, {x_max}]")));
        }
        let levels = ((1u32 << q) - 1) as f32;
        let raw_scale = (x_max - x_min) / levels;
        let scale = if raw_scale > 0.0 { raw_scale } else { 1.0 };
        let zero = (-x_min / scale).round_ties_even() as i32;
        let zero = zero.clamp(0, (1i32 << q) - 1);
        Ok(QuantParams { q, scale, zero })
    }

    /// Derive parameters by scanning `data` for min/max.
    pub fn fit(q: u8, data: &[f32]) -> Result<Self> {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in data {
            if !x.is_finite() {
                return Err(Error::invalid("non-finite value in tensor"));
            }
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if data.is_empty() {
            lo = 0.0;
            hi = 0.0;
        }
        Self::from_min_max(q, lo, hi)
    }

    /// Alphabet size `2^Q`.
    #[inline]
    pub fn alphabet(&self) -> usize {
        1usize << self.q
    }

    /// Quantize one value.
    #[inline]
    pub fn quantize_one(&self, x: f32) -> u16 {
        let max_sym = (self.alphabet() - 1) as f32;
        let v = (x / self.scale + self.zero as f32).round_ties_even();
        v.clamp(0.0, max_sym) as u16
    }

    /// Dequantize one symbol.
    #[inline]
    pub fn dequantize_one(&self, sym: u16) -> f32 {
        (sym as i32 - self.zero) as f32 * self.scale
    }

    /// The symbol that exactly represents 0.0 (post-ReLU zeros land
    /// here); the sparse encoder treats it as the implicit background.
    #[inline]
    pub fn zero_symbol(&self) -> u16 {
        // quantize_one(0.0) == clamp(round(z), …) == z by construction.
        self.zero as u16
    }
}

/// Quantize a tensor. Returns symbols in `{0, …, 2^Q − 1}`.
pub fn quantize(data: &[f32], params: &QuantParams) -> Vec<u16> {
    data.iter().map(|&x| params.quantize_one(x)).collect()
}

/// Dequantize symbols back to f32.
pub fn dequantize(symbols: &[u16], params: &QuantParams) -> Vec<f32> {
    symbols.iter().map(|&s| params.dequantize_one(s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn rejects_bad_q() {
        assert!(QuantParams::from_min_max(0, 0.0, 1.0).is_err());
        assert!(QuantParams::from_min_max(17, 0.0, 1.0).is_err());
        assert!(QuantParams::from_min_max(8, 1.0, 0.0).is_err());
        assert!(QuantParams::from_min_max(8, f32::NAN, 1.0).is_err());
    }

    #[test]
    fn symbols_stay_in_alphabet() {
        let mut rng = Rng::new(8);
        for q in [2u8, 3, 4, 6, 8] {
            let data: Vec<f32> = (0..5000).map(|_| (rng.normal() as f32) * 3.0).collect();
            let p = QuantParams::fit(q, &data).unwrap();
            let syms = quantize(&data, &p);
            let max = (1u16 << q) - 1;
            assert!(syms.iter().all(|&s| s <= max), "q={q}");
        }
    }

    #[test]
    fn zero_maps_to_zero_symbol_and_back() {
        // Post-ReLU tensors: min == 0 must reconstruct exactly to 0.0 so
        // sparsity survives the quantize/dequantize roundtrip.
        let data = [0.0f32, 0.5, 1.7, 0.0, 3.2, 0.0];
        for q in [2u8, 4, 8] {
            let p = QuantParams::fit(q, &data).unwrap();
            let z = p.zero_symbol();
            assert_eq!(p.quantize_one(0.0), z);
            assert_eq!(p.dequantize_one(z), 0.0, "q={q}");
        }
    }

    #[test]
    fn reconstruction_error_bounded_by_half_step() {
        let mut rng = Rng::new(9);
        for q in [3u8, 4, 6, 8] {
            let data: Vec<f32> =
                (0..2000).map(|_| rng.next_f32() * 10.0 - 2.0).collect();
            let p = QuantParams::fit(q, &data).unwrap();
            let rec = dequantize(&quantize(&data, &p), &p);
            // Zero-point rounding can shift the grid by up to half a step,
            // so the worst-case element error is one full step.
            let tol = p.scale * 1.0 + 1e-6;
            for (a, b) in data.iter().zip(&rec) {
                assert!((a - b).abs() <= tol, "q={q}: {a} -> {b} (scale {})", p.scale);
            }
        }
    }

    #[test]
    fn error_shrinks_with_q() {
        let mut rng = Rng::new(10);
        let data: Vec<f32> = (0..4000).map(|_| rng.next_f32() * 8.0 - 1.0).collect();
        let mut last = f64::INFINITY;
        for q in [2u8, 4, 6, 8] {
            let p = QuantParams::fit(q, &data).unwrap();
            let rec = dequantize(&quantize(&data, &p), &p);
            let mse: f64 = data
                .iter()
                .zip(&rec)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / data.len() as f64;
            assert!(mse < last, "q={q} mse {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn constant_tensor_is_lossless() {
        let data = [2.5f32; 64];
        let p = QuantParams::fit(4, &data).unwrap();
        let rec = dequantize(&quantize(&data, &p), &p);
        // scale defaults to 1, zero = round(-2.5) clamped → recovers 2.5
        // only if representable; requirement is merely "no panic, in range".
        assert_eq!(rec.len(), 64);
        let p0 = QuantParams::fit(4, &[0.0f32; 8]).unwrap();
        assert_eq!(p0.dequantize_one(p0.quantize_one(0.0)), 0.0);
    }

    #[test]
    fn empty_tensor_ok() {
        let p = QuantParams::fit(4, &[]).unwrap();
        assert_eq!(quantize(&[], &p), Vec::<u16>::new());
    }

    #[test]
    fn matches_eq6_formula_exactly() {
        // Hand-computed example: x in [-1, 3], Q = 2 → levels = 3,
        // s = 4/3, z = round(0.75) = 1.
        let p = QuantParams::from_min_max(2, -1.0, 3.0).unwrap();
        assert!((p.scale - 4.0 / 3.0).abs() < 1e-6);
        assert_eq!(p.zero, 1);
        assert_eq!(p.quantize_one(-1.0), 0);
        assert_eq!(p.quantize_one(3.0), 3);
        assert_eq!(p.quantize_one(0.0), 1);
    }
}
