//! AIQ quantizer/dequantizer.

use crate::error::{Error, Result};
use crate::tensor::{TensorMut, TensorRef};

/// Minimum supported bit-width.
pub const MIN_Q: u8 = 1;
/// Maximum supported bit-width (symbols stay well inside `u16`).
pub const MAX_Q: u8 = 16;

/// Quantization parameters for one tensor (Eq. 6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Bit-width `Q`; alphabet is `2^Q`.
    pub q: u8,
    /// Scale `s = (x_max − x_min) / (2^Q − 1)`.
    pub scale: f32,
    /// Zero point `z = round(−x_min / s)`, already clamped into the
    /// representable range.
    pub zero: i32,
}

impl QuantParams {
    /// Derive parameters from data min/max at bit-width `q`.
    ///
    /// Degenerate ranges (`x_max == x_min`, empty tensors) produce
    /// `scale = 1`, mapping everything to a single symbol — lossless for
    /// constant tensors, which do occur at aggressive split points.
    pub fn from_min_max(q: u8, x_min: f32, x_max: f32) -> Result<Self> {
        if !(MIN_Q..=MAX_Q).contains(&q) {
            return Err(Error::invalid(format!("Q={q} outside [{MIN_Q},{MAX_Q}]")));
        }
        if !x_min.is_finite() || !x_max.is_finite() || x_min > x_max {
            return Err(Error::invalid(format!("bad range [{x_min}, {x_max}]")));
        }
        let levels = ((1u32 << q) - 1) as f32;
        let raw_scale = (x_max - x_min) / levels;
        // Degenerate ranges fall back to scale = 1 — including ranges so
        // small (subnormal, < ~3e-39) that `1/scale` would overflow to
        // infinity: such tensors are constant at f32 precision, and the
        // fallback keeps [`QuantParams::inv_scale`] finite so the
        // divide-free quantize loop never sees `0.0 · ∞ = NaN`.
        let scale = if raw_scale > 0.0 && (1.0 / raw_scale).is_finite() {
            raw_scale
        } else {
            1.0
        };
        let zero = (-x_min / scale).round_ties_even() as i32;
        let zero = zero.clamp(0, (1i32 << q) - 1);
        Ok(QuantParams { q, scale, zero })
    }

    /// Derive parameters by scanning `data` for min/max.
    pub fn fit(q: u8, data: &[f32]) -> Result<Self> {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in data {
            if !x.is_finite() {
                return Err(Error::invalid("non-finite value in tensor"));
            }
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if data.is_empty() {
            lo = 0.0;
            hi = 0.0;
        }
        Self::from_min_max(q, lo, hi)
    }

    /// Alphabet size `2^Q`.
    #[inline]
    pub fn alphabet(&self) -> usize {
        1usize << self.q
    }

    /// Reciprocal of the scale, so quantization is a multiply instead
    /// of a divide. Always finite: [`QuantParams::from_min_max`]
    /// rejects non-positive scales and collapses subnormal ones to the
    /// degenerate `scale = 1` case. `0.0 * inv_scale == 0.0` exactly,
    /// so the zero-point identity `quantize_one(0.0) == zero_symbol()`
    /// is preserved.
    #[inline]
    pub fn inv_scale(&self) -> f32 {
        1.0 / self.scale
    }

    /// Quantize one value.
    #[inline]
    pub fn quantize_one(&self, x: f32) -> u16 {
        let max_sym = (self.alphabet() - 1) as f32;
        let v = (x * self.inv_scale() + self.zero as f32).round_ties_even();
        v.clamp(0.0, max_sym) as u16
    }

    /// Dequantize one symbol.
    #[inline]
    pub fn dequantize_one(&self, sym: u16) -> f32 {
        (sym as i32 - self.zero) as f32 * self.scale
    }

    /// The symbol that exactly represents 0.0 (post-ReLU zeros land
    /// here); the sparse encoder treats it as the implicit background.
    #[inline]
    pub fn zero_symbol(&self) -> u16 {
        // quantize_one(0.0) == clamp(round(z), …) == z by construction.
        self.zero as u16
    }
}

/// Quantize a tensor. Returns symbols in `{0, …, 2^Q − 1}`.
///
/// The per-element inner loop is divide-free: the scale reciprocal,
/// zero point, and clamp bound are hoisted out of the loop once.
pub fn quantize(data: &[f32], params: &QuantParams) -> Vec<u16> {
    let inv = params.inv_scale();
    let zero = params.zero as f32;
    let max_sym = (params.alphabet() - 1) as f32;
    data.iter()
        .map(|&x| (x * inv + zero).round_ties_even().clamp(0.0, max_sym) as u16)
        .collect()
}

/// Fit quantization parameters and quantize in one call: the tensor is
/// traversed exactly twice (one fused min/max/finite scan, one
/// divide-free quantize pass). A thin shim over
/// [`fit_and_quantize_tensor`], so the scan/quantize arithmetic exists
/// in exactly one place.
pub fn fit_and_quantize(q: u8, data: &[f32]) -> Result<(QuantParams, Vec<u16>)> {
    fit_and_quantize_tensor(q, &TensorRef::from_f32(data))
}

/// Fit quantization parameters and quantize a dtype-tagged tensor view
/// in one call, converting f16/bf16 elements to `f32` **on load** —
/// exactly two fused passes over the borrowed storage (min/max/finite
/// scan, then the divide-free quantize), with no intermediate `f32`
/// `Vec` for any dtype. For `f32` views this computes bit-identical
/// parameters and symbols to [`fit_and_quantize`].
pub fn fit_and_quantize_tensor(q: u8, t: &TensorRef<'_>) -> Result<(QuantParams, Vec<u16>)> {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    let mut finite = true;
    t.for_each_f32(|x| {
        finite &= x.is_finite();
        lo = lo.min(x);
        hi = hi.max(x);
    });
    if !finite {
        return Err(Error::invalid("non-finite value in tensor"));
    }
    if t.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    let params = QuantParams::from_min_max(q, lo, hi)?;
    let inv = params.inv_scale();
    let zero = params.zero as f32;
    let max_sym = (params.alphabet() - 1) as f32;
    let mut symbols = Vec::with_capacity(t.len());
    t.for_each_f32(|x| {
        symbols.push((x * inv + zero).round_ties_even().clamp(0.0, max_sym) as u16)
    });
    Ok((params, symbols))
}

/// Dequantize symbols back to f32.
pub fn dequantize(symbols: &[u16], params: &QuantParams) -> Vec<f32> {
    symbols.iter().map(|&s| params.dequantize_one(s)).collect()
}

/// Dequantize `symbols` straight into a caller-owned output buffer,
/// converting each reconstructed `f32` to the buffer's dtype — the
/// zero-allocation tail of [`crate::engine::Engine::decompress_into`].
/// Elements `0..symbols.len()` of `out` are written; errors when the
/// buffer is shorter than the symbol count.
pub fn dequantize_into(
    symbols: &[u16],
    params: &QuantParams,
    out: &mut TensorMut<'_>,
) -> Result<()> {
    if out.len() < symbols.len() {
        return Err(Error::invalid(format!(
            "output buffer of {} elements too small for {} decoded elements",
            out.len(),
            symbols.len()
        )));
    }
    let zero = params.zero;
    let scale = params.scale;
    out.store_prefix_f32(symbols.len(), |i| (symbols[i] as i32 - zero) as f32 * scale);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn rejects_bad_q() {
        assert!(QuantParams::from_min_max(0, 0.0, 1.0).is_err());
        assert!(QuantParams::from_min_max(17, 0.0, 1.0).is_err());
        assert!(QuantParams::from_min_max(8, 1.0, 0.0).is_err());
        assert!(QuantParams::from_min_max(8, f32::NAN, 1.0).is_err());
    }

    #[test]
    fn symbols_stay_in_alphabet() {
        let mut rng = Rng::new(8);
        for q in [2u8, 3, 4, 6, 8] {
            let data: Vec<f32> = (0..5000).map(|_| (rng.normal() as f32) * 3.0).collect();
            let p = QuantParams::fit(q, &data).unwrap();
            let syms = quantize(&data, &p);
            let max = (1u16 << q) - 1;
            assert!(syms.iter().all(|&s| s <= max), "q={q}");
        }
    }

    #[test]
    fn zero_maps_to_zero_symbol_and_back() {
        // Post-ReLU tensors: min == 0 must reconstruct exactly to 0.0 so
        // sparsity survives the quantize/dequantize roundtrip.
        let data = [0.0f32, 0.5, 1.7, 0.0, 3.2, 0.0];
        for q in [2u8, 4, 8] {
            let p = QuantParams::fit(q, &data).unwrap();
            let z = p.zero_symbol();
            assert_eq!(p.quantize_one(0.0), z);
            assert_eq!(p.dequantize_one(z), 0.0, "q={q}");
        }
    }

    #[test]
    fn reconstruction_error_bounded_by_half_step() {
        let mut rng = Rng::new(9);
        for q in [3u8, 4, 6, 8] {
            let data: Vec<f32> =
                (0..2000).map(|_| rng.next_f32() * 10.0 - 2.0).collect();
            let p = QuantParams::fit(q, &data).unwrap();
            let rec = dequantize(&quantize(&data, &p), &p);
            // Zero-point rounding can shift the grid by up to half a step,
            // so the worst-case element error is one full step.
            let tol = p.scale * 1.0 + 1e-6;
            for (a, b) in data.iter().zip(&rec) {
                assert!((a - b).abs() <= tol, "q={q}: {a} -> {b} (scale {})", p.scale);
            }
        }
    }

    #[test]
    fn error_shrinks_with_q() {
        let mut rng = Rng::new(10);
        let data: Vec<f32> = (0..4000).map(|_| rng.next_f32() * 8.0 - 1.0).collect();
        let mut last = f64::INFINITY;
        for q in [2u8, 4, 6, 8] {
            let p = QuantParams::fit(q, &data).unwrap();
            let rec = dequantize(&quantize(&data, &p), &p);
            let mse: f64 = data
                .iter()
                .zip(&rec)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / data.len() as f64;
            assert!(mse < last, "q={q} mse {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn constant_tensor_is_lossless() {
        let data = [2.5f32; 64];
        let p = QuantParams::fit(4, &data).unwrap();
        let rec = dequantize(&quantize(&data, &p), &p);
        // scale defaults to 1, zero = round(-2.5) clamped → recovers 2.5
        // only if representable; requirement is merely "no panic, in range".
        assert_eq!(rec.len(), 64);
        let p0 = QuantParams::fit(4, &[0.0f32; 8]).unwrap();
        assert_eq!(p0.dequantize_one(p0.quantize_one(0.0)), 0.0);
    }

    #[test]
    fn empty_tensor_ok() {
        let p = QuantParams::fit(4, &[]).unwrap();
        assert_eq!(quantize(&[], &p), Vec::<u16>::new());
        let (p2, syms) = fit_and_quantize(4, &[]).unwrap();
        assert_eq!(p2, p);
        assert!(syms.is_empty());
    }

    #[test]
    fn subnormal_range_collapses_to_degenerate_scale() {
        // Range so small that 1/scale would overflow f32: must take the
        // degenerate constant-tensor path, keep the reciprocal finite,
        // and keep the zero-point identity (no 0.0 · ∞ = NaN).
        let data = [0.0f32, 1e-40, 5e-41, 1e-39];
        for q in [2u8, 8] {
            let p = QuantParams::fit(q, &data).unwrap();
            assert_eq!(p.scale, 1.0, "q={q}");
            assert!(p.inv_scale().is_finite());
            assert_eq!(p.quantize_one(0.0), p.zero_symbol());
            let rec = dequantize(&quantize(&data, &p), &p);
            for (a, b) in data.iter().zip(&rec) {
                assert!((a - b).abs() <= p.scale, "q={q}: {a} -> {b}");
            }
        }
    }

    #[test]
    fn bulk_quantize_matches_quantize_one() {
        // The hoisted-reciprocal bulk loop and the scalar helper must
        // agree on every element, including boundary values.
        let mut rng = Rng::new(12);
        for q in [2u8, 4, 8, 12] {
            let mut data: Vec<f32> =
                (0..3000).map(|_| (rng.normal() as f32) * 5.0).collect();
            data.extend_from_slice(&[0.0, -0.0, 1e-30, -1e-30]);
            let p = QuantParams::fit(q, &data).unwrap();
            let bulk = quantize(&data, &p);
            for (&x, &s) in data.iter().zip(&bulk) {
                assert_eq!(s, p.quantize_one(x), "q={q} x={x}");
            }
        }
    }

    #[test]
    fn fit_and_quantize_matches_two_step() {
        let mut rng = Rng::new(13);
        let data: Vec<f32> = (0..5000).map(|_| rng.next_f32() * 6.0 - 2.0).collect();
        for q in [2u8, 4, 8] {
            let (params, syms) = fit_and_quantize(q, &data).unwrap();
            assert_eq!(params, QuantParams::fit(q, &data).unwrap());
            assert_eq!(syms, quantize(&data, &params));
        }
        assert!(fit_and_quantize(4, &[1.0, f32::NAN]).is_err());
    }

    #[test]
    fn tensor_fit_matches_f32_path_bit_exactly() {
        // The dtype-generic fused path must agree with the legacy f32
        // entry point on both parameters and symbols for every storage.
        let mut rng = Rng::new(14);
        let data: Vec<f32> = (0..4000)
            .map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.normal() as f32 * 2.0 })
            .collect();
        for q in [2u8, 4, 8] {
            let (p_ref, s_ref) = fit_and_quantize(q, &data).unwrap();
            let (p, s) = fit_and_quantize_tensor(q, &TensorRef::from_f32(&data)).unwrap();
            assert_eq!(p, p_ref, "q={q}");
            assert_eq!(s, s_ref, "q={q}");
            let le = TensorRef::from_f32(&data).to_le_bytes();
            let (p, s) = fit_and_quantize_tensor(
                q,
                &TensorRef::from_le_bytes(crate::tensor::Dtype::F32, &le).unwrap(),
            )
            .unwrap();
            assert_eq!(p, p_ref);
            assert_eq!(s, s_ref);
        }
    }

    #[test]
    fn tensor_fit_converts_halves_on_load() {
        use crate::tensor::half;
        let mut rng = Rng::new(15);
        let data: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        let bf16: Vec<u16> = data.iter().map(|&x| half::f32_to_bf16(x)).collect();
        let widened: Vec<f32> = bf16.iter().map(|&b| half::bf16_to_f32(b)).collect();
        let (p_ref, s_ref) = fit_and_quantize(4, &widened).unwrap();
        let (p, s) = fit_and_quantize_tensor(4, &TensorRef::from_bf16_bits(&bf16)).unwrap();
        assert_eq!(p, p_ref);
        assert_eq!(s, s_ref);
        // Non-finite halves are rejected like non-finite f32s.
        let bad = [half::f32_to_f16(1.0), 0x7C00 /* +inf */];
        assert!(fit_and_quantize_tensor(4, &TensorRef::from_f16_bits(&bad)).is_err());
    }

    #[test]
    fn dequantize_into_converts_and_checks_capacity() {
        let data = [0.0f32, 0.75, -1.5, 2.0];
        let (params, symbols) = fit_and_quantize(4, &data).unwrap();
        let reference = dequantize(&symbols, &params);
        let mut out = vec![0.0f32; 4];
        dequantize_into(&symbols, &params, &mut TensorMut::from_f32(&mut out)).unwrap();
        assert_eq!(out, reference);
        // Larger buffers keep their tail untouched.
        let mut wide = vec![9.0f32; 6];
        dequantize_into(&symbols, &params, &mut TensorMut::from_f32(&mut wide)).unwrap();
        assert_eq!(&wide[..4], reference.as_slice());
        assert_eq!(&wide[4..], &[9.0, 9.0]);
        // Short buffers error.
        let mut short = vec![0.0f32; 3];
        assert!(
            dequantize_into(&symbols, &params, &mut TensorMut::from_f32(&mut short)).is_err()
        );
        // Half-precision outputs reconstruct within half dtype ULP of
        // the f32 reconstruction.
        let mut bits = vec![0u16; 4];
        dequantize_into(&symbols, &params, &mut TensorMut::from_bf16_bits(&mut bits)).unwrap();
        for (i, &b) in bits.iter().enumerate() {
            let got = crate::tensor::half::bf16_to_f32(b);
            assert!((got - reference[i]).abs() <= reference[i].abs() * 0.01 + 1e-6);
        }
    }

    #[test]
    fn matches_eq6_formula_exactly() {
        // Hand-computed example: x in [-1, 3], Q = 2 → levels = 3,
        // s = 4/3, z = round(0.75) = 1.
        let p = QuantParams::from_min_max(2, -1.0, 3.0).unwrap();
        assert!((p.scale - 4.0 / 3.0).abs() < 1e-6);
        assert_eq!(p.zero, 1);
        assert_eq!(p.quantize_one(-1.0), 0);
        assert_eq!(p.quantize_one(3.0), 3);
        assert_eq!(p.quantize_one(0.0), 1);
    }
}
