//! Asymmetric Integer Quantization (AIQ), Eq. (6) of the paper.
//!
//! ```text
//! x̂ = round(x / s + z),   s = (x_max − x_min) / (2^Q − 1),
//! z = round(−x_min / s)
//! ```
//!
//! producing symbols in `{0, …, 2^Q − 1}`. The Rust implementation
//! mirrors the Layer-1 Pallas kernel bit-for-bit (ties-to-even rounding,
//! saturation at the alphabet edges) so artifacts produced by either
//! path interoperate; `python/tests/test_kernels.py` checks the Pallas
//! kernel against the same semantics and `rust/tests` cross-check this
//! module against values captured from the reference oracle.

pub mod aiq;

pub use aiq::{dequantize, quantize, QuantParams, MAX_Q, MIN_Q};
