//! Asymmetric Integer Quantization (AIQ), Eq. (6) of the paper.
//!
//! ```text
//! x̂ = round(x / s + z),   s = (x_max − x_min) / (2^Q − 1),
//! z = round(−x_min / s)
//! ```
//!
//! producing symbols in `{0, …, 2^Q − 1}`. The Rust implementation
//! mirrors the Layer-1 Pallas kernel (ties-to-even rounding, saturation
//! at the alphabet edges, multiply by the hoisted scale reciprocal) so
//! artifacts produced by either path interoperate. Exactness caveat:
//! XLA may contract the kernel's multiply-add into an FMA, which can
//! move inputs sitting exactly on a rounding boundary by one symbol
//! relative to Rust's two-rounding form — cross-language checks compare
//! within one quantization step. `python/tests/test_kernels.py` checks
//! the Pallas kernel against the jnp reference oracle (identical
//! lowering, exact agreement).

pub mod aiq;

pub use aiq::{
    dequantize, dequantize_into, fit_and_quantize, fit_and_quantize_tensor, quantize,
    QuantParams, MAX_Q, MIN_Q,
};
