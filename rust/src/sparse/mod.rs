//! Sparse representations of quantized intermediate features.

pub mod csr;

pub use csr::ModCsr;
