//! Modified Compressed Sparse Row (CSR) encoding (§3.1).
//!
//! Standard CSR stores the *cumulative* nonzero count per row; the paper
//! instead stores the direct per-row count `r[i]` ("non-cumulative"),
//! deferring the prefix sum to the decoder. This shrinks the dynamic
//! range of `r`'s symbols (counts are bounded by `K`, cumulative offsets
//! grow to `nnz`), which measurably lowers the entropy rANS sees.
//!
//! "Zero" here is the quantizer's *background symbol* (the image of 0.0
//! under AIQ), not literal integer zero — post-ReLU zeros land on the
//! zero point `z`, which is nonzero whenever `x_min < 0`.

use crate::error::{Error, Result};

/// Modified-CSR form of a quantized `n_rows × n_cols` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct ModCsr {
    /// Number of rows `N`.
    pub n_rows: usize,
    /// Number of columns `K`.
    pub n_cols: usize,
    /// Background symbol treated as implicit zero.
    pub background: u16,
    /// Non-background values `v`, row-major scan order.
    pub values: Vec<u16>,
    /// Column index of each value `c` (parallel to `values`).
    pub cols: Vec<u16>,
    /// Direct (non-cumulative) nonzero count per row `r`.
    pub row_counts: Vec<u32>,
}

impl ModCsr {
    /// Encode a dense row-major symbol matrix. Single `O(T)` pass.
    pub fn encode(symbols: &[u16], n_rows: usize, n_cols: usize, background: u16) -> Result<Self> {
        if n_rows * n_cols != symbols.len() {
            return Err(Error::invalid(format!(
                "{n_rows}×{n_cols} != {} elements",
                symbols.len()
            )));
        }
        if n_cols > u16::MAX as usize + 1 {
            return Err(Error::invalid(format!("K={n_cols} exceeds u16 column index")));
        }
        let mut values = Vec::new();
        let mut cols = Vec::new();
        let mut row_counts = Vec::with_capacity(n_rows);
        for row in 0..n_rows {
            let mut count = 0u32;
            let base = row * n_cols;
            for col in 0..n_cols {
                let s = symbols[base + col];
                if s != background {
                    values.push(s);
                    cols.push(col as u16);
                    count += 1;
                }
            }
            row_counts.push(count);
        }
        Ok(ModCsr { n_rows, n_cols, background, values, cols, row_counts })
    }

    /// Number of stored (non-background) entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Density in `[0, 1]`.
    pub fn density(&self) -> f64 {
        let t = self.n_rows * self.n_cols;
        if t == 0 { 0.0 } else { self.nnz() as f64 / t as f64 }
    }

    /// Reconstruct the dense matrix. The decoder performs the deferred
    /// cumulative sum over `row_counts`.
    pub fn decode(&self) -> Result<Vec<u16>> {
        self.validate()?;
        let mut out = vec![self.background; self.n_rows * self.n_cols];
        let mut k = 0usize;
        for (row, &count) in self.row_counts.iter().enumerate() {
            let base = row * self.n_cols;
            for _ in 0..count {
                out[base + self.cols[k] as usize] = self.values[k];
                k += 1;
            }
        }
        Ok(out)
    }

    /// Structural validation: counts consistent with array lengths,
    /// column indices in range and strictly increasing within each row,
    /// stored values never equal to the background symbol.
    pub fn validate(&self) -> Result<()> {
        if self.row_counts.len() != self.n_rows {
            return Err(Error::corrupt("row_counts length != n_rows"));
        }
        let total: u64 = self.row_counts.iter().map(|&c| c as u64).sum();
        if total != self.values.len() as u64 || self.values.len() != self.cols.len() {
            return Err(Error::corrupt("CSR array lengths inconsistent"));
        }
        let mut k = 0usize;
        for &count in &self.row_counts {
            if count as usize > self.n_cols {
                return Err(Error::corrupt("row count exceeds K"));
            }
            let mut prev: i64 = -1;
            for _ in 0..count {
                let col = self.cols[k] as i64;
                if col >= self.n_cols as i64 {
                    return Err(Error::corrupt("column index out of range"));
                }
                if col <= prev {
                    return Err(Error::corrupt("column indices not strictly increasing"));
                }
                if self.values[k] == self.background {
                    return Err(Error::corrupt("background symbol stored as value"));
                }
                prev = col;
                k += 1;
            }
        }
        Ok(())
    }

    /// Concatenate into the unified stream `D = v ⊕ c ⊕ r` (§3.1) used
    /// for single-pass rANS coding. Length `ℓ_D = 2·nnz + N`.
    pub fn concat(&self) -> Vec<u32> {
        let mut d = Vec::with_capacity(2 * self.nnz() + self.n_rows);
        d.extend(self.values.iter().map(|&v| v as u32));
        d.extend(self.cols.iter().map(|&c| c as u32));
        d.extend(self.row_counts.iter().copied());
        d
    }

    /// Rebuild from a concatenated stream (inverse of [`ModCsr::concat`]).
    ///
    /// `nnz` disambiguates the section boundaries:
    /// `D = v[0..nnz] ⊕ c[0..nnz] ⊕ r[0..n_rows]`.
    pub fn from_concat(
        d: &[u32],
        nnz: usize,
        n_rows: usize,
        n_cols: usize,
        background: u16,
    ) -> Result<Self> {
        if d.len() != 2 * nnz + n_rows {
            return Err(Error::corrupt(format!(
                "concat stream length {} != 2*{nnz} + {n_rows}",
                d.len()
            )));
        }
        let to_u16 = |x: u32, what: &str| -> Result<u16> {
            u16::try_from(x).map_err(|_| Error::corrupt(format!("{what} overflows u16")))
        };
        let mut values = Vec::with_capacity(nnz);
        let mut cols = Vec::with_capacity(nnz);
        for &x in &d[0..nnz] {
            values.push(to_u16(x, "value symbol")?);
        }
        for &x in &d[nnz..2 * nnz] {
            cols.push(to_u16(x, "column index")?);
        }
        let row_counts = d[2 * nnz..].to_vec();
        let csr = ModCsr { n_rows, n_cols, background, values, cols, row_counts };
        csr.validate()?;
        Ok(csr)
    }

    /// Alphabet required to entropy-code `concat()`:
    /// `max(value_alphabet, K, max_row_count + 1)`.
    pub fn concat_alphabet(&self, value_alphabet: usize) -> usize {
        let max_count = self.row_counts.iter().copied().max().unwrap_or(0) as usize;
        value_alphabet.max(self.n_cols).max(max_count + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_matrix(seed: u64, n: usize, k: usize, density: f64, alphabet: u16) -> Vec<u16> {
        let mut rng = Rng::new(seed);
        (0..n * k)
            .map(|_| {
                if rng.next_f64() < density {
                    1 + rng.below(alphabet as u64 - 1) as u16
                } else {
                    0
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_dense_sparse_dense() {
        for (n, k, d) in [(16, 8, 0.3), (100, 17, 0.05), (1, 1, 1.0), (64, 64, 0.0)] {
            let m = random_matrix(n as u64, n, k, d, 16);
            let csr = ModCsr::encode(&m, n, k, 0).unwrap();
            assert_eq!(csr.decode().unwrap(), m, "n={n} k={k} d={d}");
        }
    }

    #[test]
    fn nonzero_background_symbol() {
        // Background = 3 (a nonzero zero-point, the common AIQ case).
        let m = vec![3u16, 5, 3, 3, 7, 3, 3, 3, 1];
        let csr = ModCsr::encode(&m, 3, 3, 3).unwrap();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.values, vec![5, 7, 1]);
        assert_eq!(csr.row_counts, vec![1, 1, 1]);
        assert_eq!(csr.decode().unwrap(), m);
    }

    #[test]
    fn row_counts_are_non_cumulative() {
        let m = vec![
            1u16, 0, 2, 0, // row 0: 2 nonzeros
            0, 0, 0, 0, // row 1: 0
            4, 4, 4, 4, // row 2: 4
        ];
        let csr = ModCsr::encode(&m, 3, 4, 0).unwrap();
        assert_eq!(csr.row_counts, vec![2, 0, 4]); // not [2, 2, 6]
    }

    #[test]
    fn concat_layout_and_length() {
        let m = vec![0u16, 9, 0, 8];
        let csr = ModCsr::encode(&m, 2, 2, 0).unwrap();
        let d = csr.concat();
        assert_eq!(d.len(), 2 * csr.nnz() + csr.n_rows);
        assert_eq!(d, vec![9, 8, 1, 1, 1, 1]); // v ⊕ c ⊕ r
    }

    #[test]
    fn concat_roundtrip() {
        let m = random_matrix(42, 57, 23, 0.2, 64);
        let csr = ModCsr::encode(&m, 57, 23, 0).unwrap();
        let d = csr.concat();
        let back = ModCsr::from_concat(&d, csr.nnz(), 57, 23, 0).unwrap();
        assert_eq!(back, csr);
        assert_eq!(back.decode().unwrap(), m);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(ModCsr::encode(&[0u16; 10], 3, 4, 0).is_err());
    }

    #[test]
    fn validate_catches_corruption() {
        let m = random_matrix(7, 10, 10, 0.3, 16);
        let good = ModCsr::encode(&m, 10, 10, 0).unwrap();

        let mut bad = good.clone();
        if !bad.cols.is_empty() {
            bad.cols[0] = 10; // out of range
            assert!(bad.validate().is_err());
        }

        let mut bad = good.clone();
        bad.row_counts[0] += 1; // counts no longer match nnz
        assert!(bad.validate().is_err());

        let mut bad = good.clone();
        if !bad.values.is_empty() {
            bad.values[0] = 0; // background stored explicitly
            assert!(bad.validate().is_err());
        }

        let mut bad = good;
        bad.row_counts = vec![0; 9];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_concat_rejects_bad_lengths() {
        let d = vec![1u32, 2, 3];
        assert!(ModCsr::from_concat(&d, 2, 2, 2, 0).is_err());
    }

    #[test]
    fn density_and_alphabet() {
        let m = random_matrix(3, 20, 50, 0.1, 8);
        let csr = ModCsr::encode(&m, 20, 50, 0).unwrap();
        assert!((csr.density() - csr.nnz() as f64 / 1000.0).abs() < 1e-12);
        let a = csr.concat_alphabet(8);
        assert!(a >= 50); // column indices demand at least K
    }

    #[test]
    fn full_and_empty_rows() {
        let mut m = vec![0u16; 6 * 4];
        for c in 0..4 {
            m[2 * 4 + c] = 5; // row 2 completely full
        }
        let csr = ModCsr::encode(&m, 6, 4, 0).unwrap();
        assert_eq!(csr.row_counts, vec![0, 0, 4, 0, 0, 0]);
        assert_eq!(csr.decode().unwrap(), m);
    }
}
