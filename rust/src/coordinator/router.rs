//! Multi-model request router.
//!
//! Production SC deployments serve several architectures from one
//! gateway (Table 5's motivation: "multiple model architectures might
//! share the same system"). The router owns a route table mapping model
//! names to replica sets of inference handlers (edge pipelines bound to
//! transports), dispatches by name with round-robin replica selection,
//! and keeps per-route metrics.
//!
//! Replica failures classify through [`Error::is_retryable`]: a
//! retryable failure (transport fault, timeout, shed) fails over to the
//! next replica in the rotation before surfacing, while a fatal error
//! (bad argument, corruption) returns immediately — every replica would
//! reject the same request identically.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::telemetry::Registry;

use super::edge::InferOutcome;

/// A routed request payload.
#[derive(Debug, Clone)]
pub enum RouteInput {
    /// Vision: flat image batch.
    Vision(Vec<f32>),
    /// LM: flat token batch.
    Lm(Vec<i32>),
}

/// One inference backend (an edge pipeline bound to a transport).
pub type RouteHandler = Box<dyn Fn(&RouteInput) -> Result<InferOutcome> + Send + Sync>;

struct Route {
    replicas: Vec<RouteHandler>,
    next: AtomicUsize,
}

/// Name-based request router with round-robin replicas.
#[derive(Default)]
pub struct Router {
    routes: HashMap<String, Route>,
    default_route: Option<String>,
    metrics: Arc<Registry>,
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Router metrics (per-route counters + latency histograms).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Register a replica handler for `model`. The first registered
    /// model becomes the default route.
    pub fn register(&mut self, model: &str, handler: RouteHandler) {
        if self.default_route.is_none() {
            self.default_route = Some(model.to_string());
        }
        self.routes
            .entry(model.to_string())
            .or_insert_with(|| Route { replicas: Vec::new(), next: AtomicUsize::new(0) })
            .replicas
            .push(handler);
    }

    /// Override the default route.
    pub fn set_default(&mut self, model: &str) -> Result<()> {
        if !self.routes.contains_key(model) {
            return Err(Error::invalid(format!("no route '{model}'")));
        }
        self.default_route = Some(model.to_string());
        Ok(())
    }

    /// Registered model names.
    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Replica count for a model.
    pub fn replica_count(&self, model: &str) -> usize {
        self.routes.get(model).map(|r| r.replicas.len()).unwrap_or(0)
    }

    /// Dispatch a request. `model = None` uses the default route.
    ///
    /// Retryable replica failures fail over to the next replica in the
    /// rotation (at most one full pass); fatal errors return at once.
    pub fn dispatch(&self, model: Option<&str>, input: &RouteInput) -> Result<InferOutcome> {
        let name = match model {
            Some(m) => m,
            None => self
                .default_route
                .as_deref()
                .ok_or_else(|| Error::invalid("router has no routes"))?,
        };
        let route = self.routes.get(name).ok_or_else(|| {
            self.metrics.incr("router.unknown_model", 1);
            Error::invalid(format!("unknown model '{name}'"))
        })?;
        let replicas = route.replicas.len();
        let start = route.next.fetch_add(1, Ordering::Relaxed);
        let sw = crate::util::timer::Stopwatch::new();
        let mut result = Err(Error::invalid(format!("route '{name}' has no replicas")));
        for hop in 0..replicas {
            result = (route.replicas[(start + hop) % replicas])(input);
            match &result {
                Err(e) if e.is_retryable() && hop + 1 < replicas => {
                    self.metrics.incr(&format!("router.{name}.failover_total"), 1);
                }
                _ => break,
            }
        }
        let ms = sw.elapsed_ms();
        self.metrics.incr(&format!("router.{name}.requests"), 1);
        self.metrics.histogram(&format!("router.{name}.latency_ms")).record_ms(ms);
        if result.is_err() {
            self.metrics.incr(&format!("router.{name}.errors"), 1);
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::LatencyBreakdown;

    fn outcome(tag: f32) -> InferOutcome {
        InferOutcome {
            logits: vec![tag],
            breakdown: LatencyBreakdown::default(),
            stats: None,
            payload_bytes: 1,
        }
    }

    fn handler(tag: f32) -> RouteHandler {
        Box::new(move |_input| Ok(outcome(tag)))
    }

    #[test]
    fn dispatch_by_name_and_default() {
        let mut r = Router::new();
        r.register("a", handler(1.0));
        r.register("b", handler(2.0));
        let input = RouteInput::Vision(vec![0.0]);
        assert_eq!(r.dispatch(Some("b"), &input).unwrap().logits, vec![2.0]);
        // First-registered is default.
        assert_eq!(r.dispatch(None, &input).unwrap().logits, vec![1.0]);
        r.set_default("b").unwrap();
        assert_eq!(r.dispatch(None, &input).unwrap().logits, vec![2.0]);
        assert!(r.set_default("zzz").is_err());
    }

    #[test]
    fn unknown_model_is_clean_error_and_counted() {
        let mut r = Router::new();
        r.register("a", handler(1.0));
        let input = RouteInput::Vision(vec![]);
        assert!(r.dispatch(Some("nope"), &input).is_err());
        assert_eq!(r.metrics().get("router.unknown_model"), 1);
    }

    #[test]
    fn round_robin_across_replicas() {
        let mut r = Router::new();
        r.register("a", handler(1.0));
        r.register("a", handler(2.0));
        r.register("a", handler(3.0));
        assert_eq!(r.replica_count("a"), 3);
        let input = RouteInput::Vision(vec![]);
        let picks: Vec<f32> = (0..6).map(|_| r.dispatch(Some("a"), &input).unwrap().logits[0]).collect();
        assert_eq!(picks, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn errors_are_counted_per_route() {
        let mut r = Router::new();
        r.register("a", Box::new(|_| Err(Error::runtime("down"))));
        let input = RouteInput::Lm(vec![1, 2, 3]);
        assert!(r.dispatch(Some("a"), &input).is_err());
        assert_eq!(r.metrics().get("router.a.errors"), 1);
        assert_eq!(r.metrics().get("router.a.requests"), 1);
    }

    #[test]
    fn empty_router_rejects() {
        let r = Router::new();
        assert!(r.dispatch(None, &RouteInput::Vision(vec![])).is_err());
    }

    #[test]
    fn retryable_failure_fails_over_to_next_replica() {
        let mut r = Router::new();
        r.register("a", Box::new(|_| Err(Error::timeout("replica 0 down"))));
        r.register("a", handler(2.0));
        let input = RouteInput::Vision(vec![]);
        // Rotation starts at replica 0, which times out; the dispatch
        // must land on replica 1 instead of surfacing the timeout.
        let got = r.dispatch(Some("a"), &input).unwrap();
        assert_eq!(got.logits, vec![2.0]);
        assert_eq!(r.metrics().get("router.a.failover_total"), 1);
        assert_eq!(r.metrics().get("router.a.errors"), 0);
    }

    #[test]
    fn fatal_failure_does_not_fail_over() {
        let mut r = Router::new();
        r.register("a", Box::new(|_| Err(Error::invalid("bad shape"))));
        r.register("a", handler(2.0));
        let input = RouteInput::Vision(vec![]);
        let err = r.dispatch(Some("a"), &input).unwrap_err();
        assert!(!err.is_retryable(), "{err}");
        assert_eq!(r.metrics().get("router.a.failover_total"), 0);
        assert_eq!(r.metrics().get("router.a.errors"), 1);
    }

    #[test]
    fn all_replicas_down_surfaces_last_error() {
        let mut r = Router::new();
        r.register("a", Box::new(|_| Err(Error::timeout("down 0"))));
        r.register("a", Box::new(|_| Err(Error::timeout("down 1"))));
        let err = r.dispatch(Some("a"), &RouteInput::Vision(vec![])).unwrap_err();
        assert!(err.is_retryable());
        assert_eq!(r.metrics().get("router.a.failover_total"), 1, "one hop, then give up");
    }
}
