//! Bucketed dynamic batching.
//!
//! PJRT executables have static shapes, so the serving path compiles a
//! small set of batch *buckets* (e.g. {1, 8}) and the batcher groups
//! concurrent requests into the largest bucket that fits, padding the
//! remainder by replication. Requests wait at most `max_wait` before a
//! partial bucket is dispatched — the classic dynamic-batching
//! latency/throughput dial.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Supported batch sizes, ascending (must be non-empty).
    pub buckets: Vec<usize>,
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    /// Queue-depth bound: a `submit` finding this many requests already
    /// pending is shed immediately with [`Error::Rejected`] instead of
    /// growing an unbounded backlog. Unbounded by default.
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 8],
            max_wait: Duration::from_millis(2),
            max_queue: usize::MAX,
        }
    }
}

struct Pending<Req, Resp> {
    req: Req,
    enqueued: Instant,
    reply: Sender<(Result<Resp>, f64)>,
}

struct Shared<Req, Resp> {
    queue: Mutex<VecDeque<Pending<Req, Resp>>>,
    available: Condvar,
    stopped: AtomicBool,
    shed: AtomicU64,
}

/// A bucketed dynamic batcher.
///
/// `submit` enqueues a request and returns a receiver for
/// `(response, queue_ms)`. A worker thread (started via [`Batcher::run`])
/// repeatedly forms batches and invokes the execution closure, which
/// receives the (possibly padded) request batch and must return one
/// response per *real* request.
pub struct Batcher<Req, Resp> {
    shared: Arc<Shared<Req, Resp>>,
    cfg: BatcherConfig,
}

impl<Req, Resp> Clone for Batcher<Req, Resp> {
    fn clone(&self) -> Self {
        Batcher { shared: Arc::clone(&self.shared), cfg: self.cfg.clone() }
    }
}

impl<Req: Send + 'static, Resp: Send + 'static> Batcher<Req, Resp> {
    /// Create a batcher with `cfg` (buckets sorted ascending).
    pub fn new(mut cfg: BatcherConfig) -> Self {
        assert!(!cfg.buckets.is_empty(), "batcher needs at least one bucket");
        cfg.buckets.sort_unstable();
        Batcher {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
                stopped: AtomicBool::new(false),
                shed: AtomicU64::new(0),
            }),
            cfg,
        }
    }

    /// Largest configured bucket.
    pub fn max_bucket(&self) -> usize {
        *self.cfg.buckets.last().unwrap()
    }

    /// Requests shed so far (queue full or submitted after stop).
    pub fn shed_total(&self) -> u64 {
        self.shared.shed.load(Ordering::Relaxed)
    }

    /// Shed one request through its own reply channel, so callers see
    /// the same `(Result, queue_ms)` shape whether the request ran or
    /// was rejected at the door.
    fn shed(&self, tx: Sender<(Result<Resp>, f64)>, why: String) {
        self.shared.shed.fetch_add(1, Ordering::Relaxed);
        let retry_after_ms = (self.cfg.max_wait.as_millis() as u64).max(1);
        let _ = tx.send((Err(Error::rejected(retry_after_ms, why)), 0.0));
    }

    /// Enqueue one request; sheds with [`Error::Rejected`] (delivered
    /// through the returned receiver) when the batcher is stopped or the
    /// queue is at [`BatcherConfig::max_queue`].
    pub fn submit(&self, req: Req) -> Receiver<(Result<Resp>, f64)> {
        let (tx, rx) = channel();
        if self.shared.stopped.load(Ordering::SeqCst) {
            self.shed(tx, "batcher is stopped".into());
            return rx;
        }
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.cfg.max_queue {
                drop(q);
                self.shed(tx, format!("batch queue full ({} pending)", self.cfg.max_queue));
                return rx;
            }
            q.push_back(Pending { req, enqueued: Instant::now(), reply: tx });
        }
        self.shared.available.notify_one();
        rx
    }

    /// Stop the worker loop(s) after the queue drains.
    pub fn stop(&self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
    }

    /// Pick the bucket for `pending` requests: the largest bucket that
    /// is fully covered, or the smallest bucket if the oldest request
    /// has waited past `max_wait`.
    fn pick_bucket(&self, pending: usize, oldest_wait: Duration) -> Option<usize> {
        let covered = self
            .cfg
            .buckets
            .iter()
            .rev()
            .find(|&&b| pending >= b)
            .copied();
        match covered {
            Some(b) if b == self.max_bucket() => Some(b),
            _ if oldest_wait >= self.cfg.max_wait && pending > 0 => {
                Some(covered.unwrap_or(self.cfg.buckets[0]))
            }
            _ => None,
        }
    }

    /// Worker loop bound to a shared compression engine: like
    /// [`Batcher::run`], but hands `exec` the engine so batch execution
    /// compresses/decompresses on the process-wide persistent pool
    /// instead of spawning scoped threads per batch. This is the plumbing
    /// that keeps N concurrent batcher workers from oversubscribing the
    /// host: they all dispatch lanes onto one machine-sized pool.
    pub fn run_with_engine(
        &self,
        engine: std::sync::Arc<crate::engine::Engine>,
        mut exec: impl FnMut(Vec<Req>, usize, &crate::engine::Engine) -> Vec<Result<Resp>>,
    ) {
        self.run(move |reqs, bucket| exec(reqs, bucket, &engine));
    }

    /// Worker loop: form batches and execute them with `exec`.
    ///
    /// `exec(batch, bucket)` gets exactly `len ≤ bucket` real requests
    /// and must return `len` responses (the batcher handles padding by
    /// telling `exec` the bucket size; `exec` replicates inputs as
    /// needed for the static shape).
    pub fn run(&self, mut exec: impl FnMut(Vec<Req>, usize) -> Vec<Result<Resp>>) {
        loop {
            let batch: Vec<Pending<Req, Resp>>;
            let bucket: usize;
            {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if self.shared.stopped.load(Ordering::SeqCst) && q.is_empty() {
                        return;
                    }
                    let pending = q.len();
                    let oldest_wait = q
                        .front()
                        .map(|p| p.enqueued.elapsed())
                        .unwrap_or(Duration::ZERO);
                    if let Some(b) = self.pick_bucket(pending, oldest_wait) {
                        bucket = b;
                        let take = pending.min(b);
                        batch = q.drain(..take).collect();
                        break;
                    }
                    // Wait for more work or the oldest deadline.
                    let timeout = if q.is_empty() {
                        Duration::from_millis(50)
                    } else {
                        self.cfg.max_wait.saturating_sub(oldest_wait).max(Duration::from_micros(100))
                    };
                    let (guard, _) = self
                        .shared
                        .available
                        .wait_timeout(q, timeout)
                        .expect("batcher lock poisoned");
                    q = guard;
                }
            }
            let queue_times: Vec<f64> = batch
                .iter()
                .map(|p| p.enqueued.elapsed().as_secs_f64() * 1e3)
                .collect();
            let (reqs, replies): (Vec<Req>, Vec<Sender<(Result<Resp>, f64)>>) =
                batch.into_iter().map(|p| (p.req, p.reply)).unzip();
            let n = reqs.len();
            let mut results = exec(reqs, bucket);
            if results.len() != n {
                // Contract violation: surface as errors rather than hang.
                results = (0..n)
                    .map(|_| Err(Error::invalid("batch exec returned wrong response count")))
                    .collect();
            }
            for ((resp, tx), qms) in results.into_iter().zip(replies).zip(queue_times) {
                let _ = tx.send((resp, qms));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_requests_dispatch_after_wait() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|reqs, _bucket| reqs.into_iter().map(|r| Ok(r * 2)).collect()))
        };
        let rx = b.submit(21);
        let (resp, queue_ms) = rx.recv().unwrap();
        assert_eq!(resp.unwrap(), 42);
        assert!(queue_ms >= 0.0);
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn full_bucket_dispatches_without_wait() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_secs(10), // would stall partial batches
            ..Default::default()
        });
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let worker = {
            let b = b.clone();
            let sizes = Arc::clone(&sizes);
            std::thread::spawn(move || {
                b.run(move |reqs, bucket| {
                    sizes.lock().unwrap().push((reqs.len(), bucket));
                    reqs.into_iter().map(Ok).collect()
                })
            })
        };
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv().unwrap().0.unwrap();
        }
        b.stop();
        worker.join().unwrap();
        let sizes = sizes.lock().unwrap();
        // All four went out in full buckets of 4 (or split across fewer
        // dispatches, but never via the 10s timeout).
        assert!(sizes.iter().all(|&(n, b)| n <= b));
        assert!(sizes.iter().any(|&(_, b)| b == 4));
    }

    #[test]
    fn wrong_response_count_errors_all() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![2],
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|_reqs, _| vec![]))
        };
        let rx = b.submit(1);
        let (resp, _) = rx.recv().unwrap();
        assert!(resp.is_err());
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn run_with_engine_compresses_batches_on_shared_pool() {
        use crate::engine::{Engine, EngineConfig};
        use crate::pipeline::PipelineConfig;

        let engine = Arc::new(Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() }));
        let b: Batcher<Vec<f32>, usize> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_micros(500),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            let engine = Arc::clone(&engine);
            std::thread::spawn(move || {
                b.run_with_engine(engine, |reqs, _bucket, eng| {
                    reqs.into_iter()
                        .map(|data| {
                            eng.compress(&data, &PipelineConfig::paper(4))
                                .map(|(bytes, _)| bytes.len())
                        })
                        .collect()
                })
            })
        };
        let tensors: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..2048).map(|j| if (i + j) % 3 == 0 { 1.5 } else { 0.0 }).collect())
            .collect();
        let rxs: Vec<_> = tensors.iter().map(|t| b.submit(t.clone())).collect();
        for rx in rxs {
            let (size, _) = rx.recv().unwrap();
            assert!(size.unwrap() > 0);
        }
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn concurrent_submitters() {
        let b: Batcher<u64, u64> = Batcher::new(BatcherConfig {
            buckets: vec![1, 8],
            max_wait: Duration::from_micros(500),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|reqs, _| reqs.into_iter().map(|r| Ok(r + 1)).collect()))
        };
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let b = b.clone();
                s.spawn(move || {
                    for i in 0..50u64 {
                        let rx = b.submit(t * 1000 + i);
                        let (r, _) = rx.recv().unwrap();
                        assert_eq!(r.unwrap(), t * 1000 + i + 1);
                    }
                });
            }
        });
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn queue_full_sheds_with_rejected() {
        // No worker running: the queue fills and the bound trips.
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1],
            max_wait: Duration::from_millis(40),
            max_queue: 2,
        });
        let _held1 = b.submit(1);
        let _held2 = b.submit(2);
        let rx = b.submit(3);
        let (resp, queue_ms) = rx.recv().unwrap();
        let err = resp.unwrap_err();
        assert!(matches!(err, Error::Rejected { retry_after_ms: 40, .. }), "{err}");
        assert!(err.is_retryable());
        assert_eq!(queue_ms, 0.0, "a shed request never queued");
        assert_eq!(b.shed_total(), 1);
    }

    #[test]
    fn submit_after_stop_is_rejected() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig::default());
        b.stop();
        let (resp, _) = b.submit(7).recv().unwrap();
        assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
        assert_eq!(b.shed_total(), 1);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1],
            max_wait: Duration::ZERO,
            ..Default::default()
        });
        // Enqueue before the worker starts, then stop immediately: the
        // drain-then-exit contract must still answer every request.
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        b.stop();
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || {
                b.run(|reqs, _| {
                    std::thread::sleep(Duration::from_millis(5));
                    reqs.into_iter().map(|r| Ok(r * 10)).collect()
                })
            })
        };
        for (i, rx) in rxs.into_iter().enumerate() {
            let (resp, _) = rx.recv().unwrap();
            assert_eq!(resp.unwrap(), i as u32 * 10);
        }
        worker.join().unwrap();
        assert_eq!(b.shed_total(), 0);
    }

    #[test]
    fn dispatch_is_oldest_first_under_concurrent_submitters() {
        let b: Batcher<(u64, u64), (u64, u64)> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_micros(200),
            ..Default::default()
        });
        let seen = Arc::new(Mutex::new(Vec::new()));
        let worker = {
            let b = b.clone();
            let seen = Arc::clone(&seen);
            std::thread::spawn(move || {
                b.run(move |reqs, _| {
                    seen.lock().unwrap().extend(reqs.iter().copied());
                    reqs.into_iter().map(Ok).collect()
                })
            })
        };
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let b = b.clone();
                s.spawn(move || {
                    let rxs: Vec<_> = (0..25u64).map(|i| b.submit((t, i))).collect();
                    for rx in rxs {
                        rx.recv().unwrap().0.unwrap();
                    }
                });
            }
        });
        b.stop();
        worker.join().unwrap();
        // The queue is FIFO, so each submitter's requests must be
        // dispatched in its own submission order regardless of how the
        // four interleave globally.
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 100);
        for t in 0..4u64 {
            let order: Vec<u64> = seen.iter().filter(|(tt, _)| *tt == t).map(|(_, i)| *i).collect();
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(order, sorted, "submitter {t} dispatched out of order");
        }
    }
}
