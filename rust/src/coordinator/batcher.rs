//! Bucketed dynamic batching.
//!
//! PJRT executables have static shapes, so the serving path compiles a
//! small set of batch *buckets* (e.g. {1, 8}) and the batcher groups
//! concurrent requests into the largest bucket that fits, padding the
//! remainder by replication. Requests wait at most `max_wait` before a
//! partial bucket is dispatched — the classic dynamic-batching
//! latency/throughput dial.
//!
//! `max_queue`, `max_wait`, and the batch-size ceiling live in a shared
//! [`ServingKnobs`] handle and are re-read per decision, so the
//! adaptive controller (see [`daemon`](super::daemon)) and operators
//! can retune a running batcher without a restart.
//!
//! Shutdown contract: every submitted request is *answered*, never
//! silently dropped. The worker drains the queue after [`Batcher::stop`];
//! [`Batcher::shutdown_now`] instead rejects the undispatched backlog
//! with explicit [`Error::Rejected`]; and if the batcher is dropped (or
//! the exec closure panics) with requests still queued, those requests
//! are rejected rather than left with a dead reply channel.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::knobs::ServingKnobs;
use crate::error::{Error, Result};

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Supported batch sizes, ascending (must be non-empty).
    pub buckets: Vec<usize>,
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    /// Queue-depth bound: a `submit` finding this many requests already
    /// pending is shed immediately with [`Error::Rejected`] instead of
    /// growing an unbounded backlog. Unbounded by default.
    pub max_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 8],
            max_wait: Duration::from_millis(2),
            max_queue: usize::MAX,
        }
    }
}

struct Pending<Req, Resp> {
    req: Req,
    enqueued: Instant,
    reply: Sender<(Result<Resp>, f64)>,
}

struct Shared<Req, Resp> {
    queue: Mutex<VecDeque<Pending<Req, Resp>>>,
    available: Condvar,
    stopped: AtomicBool,
    shed: AtomicU64,
    knobs: Arc<ServingKnobs>,
}

impl<Req, Resp> Shared<Req, Resp> {
    /// Reject every queued-but-undispatched request through its reply
    /// channel. Returns how many were answered this way.
    fn reject_queued(&self, why: &str) -> usize {
        let drained: Vec<Pending<Req, Resp>> =
            self.queue.lock().unwrap().drain(..).collect();
        let retry_after_ms = (self.knobs.max_wait().as_millis() as u64).max(1);
        let n = drained.len();
        for p in drained {
            let _ = p.reply.send((Err(Error::rejected(retry_after_ms, why.to_string())), 0.0));
        }
        self.shed.fetch_add(n as u64, Ordering::Relaxed);
        n
    }
}

impl<Req, Resp> Drop for Shared<Req, Resp> {
    /// Last line of the answer-everything contract: if the batcher is
    /// dropped with requests still queued (no worker ever ran, or the
    /// worker exited early), answer them with an explicit rejection so
    /// waiting callers see `Rejected`, not a dead channel.
    fn drop(&mut self) {
        self.reject_queued("batcher dropped before dispatch");
    }
}

/// A bucketed dynamic batcher.
///
/// `submit` enqueues a request and returns a receiver for
/// `(response, queue_ms)`. A worker thread (started via [`Batcher::run`])
/// repeatedly forms batches and invokes the execution closure, which
/// receives the (possibly padded) request batch and must return one
/// response per *real* request.
pub struct Batcher<Req, Resp> {
    shared: Arc<Shared<Req, Resp>>,
    cfg: BatcherConfig,
}

impl<Req, Resp> Clone for Batcher<Req, Resp> {
    fn clone(&self) -> Self {
        Batcher { shared: Arc::clone(&self.shared), cfg: self.cfg.clone() }
    }
}

impl<Req: Send + 'static, Resp: Send + 'static> Batcher<Req, Resp> {
    /// Create a batcher with `cfg` (buckets sorted ascending). The
    /// queue/wait bounds seed a fresh [`ServingKnobs`] handle, readable
    /// via [`Batcher::knobs`] for live retuning.
    pub fn new(cfg: BatcherConfig) -> Self {
        let knobs = Arc::new(ServingKnobs::default());
        knobs.set_max_queue(cfg.max_queue);
        knobs.set_max_wait(cfg.max_wait);
        Self::with_knobs(cfg, knobs)
    }

    /// Create a batcher that reads its queue/wait/batch bounds from an
    /// existing shared `knobs` handle (the daemon shares one handle
    /// between admission, batching, and the adaptive controller). The
    /// handle's values win over `cfg.max_queue`/`cfg.max_wait`.
    pub fn with_knobs(mut cfg: BatcherConfig, knobs: Arc<ServingKnobs>) -> Self {
        assert!(!cfg.buckets.is_empty(), "batcher needs at least one bucket");
        cfg.buckets.sort_unstable();
        Batcher {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
                stopped: AtomicBool::new(false),
                shed: AtomicU64::new(0),
                knobs,
            }),
            cfg,
        }
    }

    /// The live-reconfigurable bounds this batcher reads per decision.
    pub fn knobs(&self) -> Arc<ServingKnobs> {
        Arc::clone(&self.shared.knobs)
    }

    /// Largest configured bucket.
    pub fn max_bucket(&self) -> usize {
        *self.cfg.buckets.last().unwrap()
    }

    /// Largest bucket currently allowed by the adaptive batch ceiling
    /// (`knobs.batch_limit()`); never below the smallest bucket.
    fn effective_max_bucket(&self) -> usize {
        let limit = self.shared.knobs.batch_limit();
        self.cfg
            .buckets
            .iter()
            .rev()
            .find(|&&b| b <= limit)
            .copied()
            .unwrap_or(self.cfg.buckets[0])
    }

    fn max_wait(&self) -> Duration {
        self.shared.knobs.max_wait()
    }

    /// Requests shed so far (queue full, stopped, or drained).
    pub fn shed_total(&self) -> u64 {
        self.shared.shed.load(Ordering::Relaxed)
    }

    /// Shed one request through its own reply channel, so callers see
    /// the same `(Result, queue_ms)` shape whether the request ran or
    /// was rejected at the door.
    fn shed(&self, tx: Sender<(Result<Resp>, f64)>, why: String) {
        self.shared.shed.fetch_add(1, Ordering::Relaxed);
        let retry_after_ms = (self.max_wait().as_millis() as u64).max(1);
        let _ = tx.send((Err(Error::rejected(retry_after_ms, why)), 0.0));
    }

    /// Enqueue one request; sheds with [`Error::Rejected`] (delivered
    /// through the returned receiver) when the batcher is stopped or the
    /// queue is at the `max_queue` knob.
    pub fn submit(&self, req: Req) -> Receiver<(Result<Resp>, f64)> {
        let (tx, rx) = channel();
        let max_queue = self.shared.knobs.max_queue();
        {
            // The stopped check must happen *under the queue lock*:
            // checked outside, a submit racing `stop` can enqueue after
            // the worker's final empty-queue check and never be
            // answered. Under the lock the worker either sees this
            // request before exiting or this submit sees `stopped`.
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.stopped.load(Ordering::SeqCst) {
                drop(q);
                self.shed(tx, "batcher is stopped".into());
                return rx;
            }
            if q.len() >= max_queue {
                drop(q);
                self.shed(tx, format!("batch queue full ({max_queue} pending)"));
                return rx;
            }
            q.push_back(Pending { req, enqueued: Instant::now(), reply: tx });
        }
        self.shared.available.notify_one();
        rx
    }

    /// Stop the worker loop(s) after the queue drains: already-queued
    /// requests are still executed, new submits are shed.
    pub fn stop(&self) {
        let _q = self.shared.queue.lock().unwrap();
        self.shared.stopped.store(true, Ordering::SeqCst);
        drop(_q);
        self.shared.available.notify_all();
    }

    /// Fast drain: stop accepting work *and* answer every
    /// queued-but-undispatched request with [`Error::Rejected`] right
    /// now instead of executing it. Already-dispatched batches finish
    /// normally. Returns how many queued requests were rejected.
    pub fn shutdown_now(&self, why: &str) -> usize {
        self.stop();
        self.shared.reject_queued(why)
    }

    /// Pick the bucket for `pending` requests: the largest admissible
    /// bucket that is fully covered, or the smallest bucket if the
    /// oldest request has waited past `max_wait`. "Admissible" respects
    /// the live batch ceiling, so the adaptive controller shrinks
    /// dispatch sizes mid-flight.
    fn pick_bucket(&self, pending: usize, oldest_wait: Duration) -> Option<usize> {
        let effective_max = self.effective_max_bucket();
        let covered = self
            .cfg
            .buckets
            .iter()
            .rev()
            .find(|&&b| b <= effective_max && pending >= b)
            .copied();
        match covered {
            Some(b) if b == effective_max => Some(b),
            _ if oldest_wait >= self.max_wait() && pending > 0 => {
                Some(covered.unwrap_or(self.cfg.buckets[0]))
            }
            _ => None,
        }
    }

    /// Worker loop bound to a shared compression engine: like
    /// [`Batcher::run`], but hands `exec` the engine so batch execution
    /// compresses/decompresses on the process-wide persistent pool
    /// instead of spawning scoped threads per batch. This is the plumbing
    /// that keeps N concurrent batcher workers from oversubscribing the
    /// host: they all dispatch lanes onto one machine-sized pool.
    pub fn run_with_engine(
        &self,
        engine: std::sync::Arc<crate::engine::Engine>,
        mut exec: impl FnMut(Vec<Req>, usize, &crate::engine::Engine) -> Vec<Result<Resp>>,
    ) {
        self.run(move |reqs, bucket| exec(reqs, bucket, &engine));
    }

    /// Worker loop: form batches and execute them with `exec`.
    ///
    /// `exec(batch, bucket)` gets exactly `len ≤ bucket` real requests
    /// and must return `len` responses (the batcher handles padding by
    /// telling `exec` the bucket size; `exec` replicates inputs as
    /// needed for the static shape).
    pub fn run(&self, mut exec: impl FnMut(Vec<Req>, usize) -> Vec<Result<Resp>>) {
        loop {
            let batch: Vec<Pending<Req, Resp>>;
            let bucket: usize;
            {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if self.shared.stopped.load(Ordering::SeqCst) && q.is_empty() {
                        return;
                    }
                    let pending = q.len();
                    let oldest_wait = q
                        .front()
                        .map(|p| p.enqueued.elapsed())
                        .unwrap_or(Duration::ZERO);
                    if let Some(b) = self.pick_bucket(pending, oldest_wait) {
                        bucket = b;
                        let take = pending.min(b);
                        batch = q.drain(..take).collect();
                        break;
                    }
                    // Wait for more work or the oldest deadline.
                    let timeout = if q.is_empty() {
                        Duration::from_millis(50)
                    } else {
                        self.max_wait().saturating_sub(oldest_wait).max(Duration::from_micros(100))
                    };
                    let (guard, _) = self
                        .shared
                        .available
                        .wait_timeout(q, timeout)
                        .expect("batcher lock poisoned");
                    q = guard;
                }
            }
            let queue_times: Vec<f64> = batch
                .iter()
                .map(|p| p.enqueued.elapsed().as_secs_f64() * 1e3)
                .collect();
            let (reqs, replies): (Vec<Req>, Vec<Sender<(Result<Resp>, f64)>>) =
                batch.into_iter().map(|p| (p.req, p.reply)).unzip();
            let n = reqs.len();
            // A panicking exec must not strand its batch: answer every
            // request with an explicit rejection, then re-raise so a
            // supervisor can restart the worker.
            let mut results = match catch_unwind(AssertUnwindSafe(|| exec(reqs, bucket))) {
                Ok(r) => r,
                Err(payload) => {
                    for tx in &replies {
                        let _ = tx.send((
                            Err(Error::rejected(1, "batch exec panicked".to_string())),
                            0.0,
                        ));
                    }
                    self.shared.shed.fetch_add(n as u64, Ordering::Relaxed);
                    resume_unwind(payload);
                }
            };
            if results.len() != n {
                // Contract violation: surface as errors rather than hang.
                results = (0..n)
                    .map(|_| Err(Error::invalid("batch exec returned wrong response count")))
                    .collect();
            }
            for ((resp, tx), qms) in results.into_iter().zip(replies).zip(queue_times) {
                let _ = tx.send((resp, qms));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_requests_dispatch_after_wait() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|reqs, _bucket| reqs.into_iter().map(|r| Ok(r * 2)).collect()))
        };
        let rx = b.submit(21);
        let (resp, queue_ms) = rx.recv().unwrap();
        assert_eq!(resp.unwrap(), 42);
        assert!(queue_ms >= 0.0);
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn full_bucket_dispatches_without_wait() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_secs(10), // would stall partial batches
            ..Default::default()
        });
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let worker = {
            let b = b.clone();
            let sizes = Arc::clone(&sizes);
            std::thread::spawn(move || {
                b.run(move |reqs, bucket| {
                    sizes.lock().unwrap().push((reqs.len(), bucket));
                    reqs.into_iter().map(Ok).collect()
                })
            })
        };
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        for rx in rxs {
            rx.recv().unwrap().0.unwrap();
        }
        b.stop();
        worker.join().unwrap();
        let sizes = sizes.lock().unwrap();
        // All four went out in full buckets of 4 (or split across fewer
        // dispatches, but never via the 10s timeout).
        assert!(sizes.iter().all(|&(n, b)| n <= b));
        assert!(sizes.iter().any(|&(_, b)| b == 4));
    }

    #[test]
    fn wrong_response_count_errors_all() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![2],
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|_reqs, _| vec![]))
        };
        let rx = b.submit(1);
        let (resp, _) = rx.recv().unwrap();
        assert!(resp.is_err());
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn run_with_engine_compresses_batches_on_shared_pool() {
        use crate::engine::{Engine, EngineConfig};
        use crate::pipeline::PipelineConfig;

        let engine = Arc::new(Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() }));
        let b: Batcher<Vec<f32>, usize> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_micros(500),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            let engine = Arc::clone(&engine);
            std::thread::spawn(move || {
                b.run_with_engine(engine, |reqs, _bucket, eng| {
                    reqs.into_iter()
                        .map(|data| {
                            eng.compress(&data, &PipelineConfig::paper(4))
                                .map(|(bytes, _)| bytes.len())
                        })
                        .collect()
                })
            })
        };
        let tensors: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..2048).map(|j| if (i + j) % 3 == 0 { 1.5 } else { 0.0 }).collect())
            .collect();
        let rxs: Vec<_> = tensors.iter().map(|t| b.submit(t.clone())).collect();
        for rx in rxs {
            let (size, _) = rx.recv().unwrap();
            assert!(size.unwrap() > 0);
        }
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn concurrent_submitters() {
        let b: Batcher<u64, u64> = Batcher::new(BatcherConfig {
            buckets: vec![1, 8],
            max_wait: Duration::from_micros(500),
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|reqs, _| reqs.into_iter().map(|r| Ok(r + 1)).collect()))
        };
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let b = b.clone();
                s.spawn(move || {
                    for i in 0..50u64 {
                        let rx = b.submit(t * 1000 + i);
                        let (r, _) = rx.recv().unwrap();
                        assert_eq!(r.unwrap(), t * 1000 + i + 1);
                    }
                });
            }
        });
        b.stop();
        worker.join().unwrap();
    }

    #[test]
    fn queue_full_sheds_with_rejected() {
        // No worker running: the queue fills and the bound trips.
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1],
            max_wait: Duration::from_millis(40),
            max_queue: 2,
        });
        let _held1 = b.submit(1);
        let _held2 = b.submit(2);
        let rx = b.submit(3);
        let (resp, queue_ms) = rx.recv().unwrap();
        let err = resp.unwrap_err();
        assert!(matches!(err, Error::Rejected { retry_after_ms: 40, .. }), "{err}");
        assert!(err.is_retryable());
        assert_eq!(queue_ms, 0.0, "a shed request never queued");
        assert_eq!(b.shed_total(), 1);
    }

    #[test]
    fn submit_after_stop_is_rejected() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig::default());
        b.stop();
        let (resp, _) = b.submit(7).recv().unwrap();
        assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
        assert_eq!(b.shed_total(), 1);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1],
            max_wait: Duration::ZERO,
            ..Default::default()
        });
        // Enqueue before the worker starts, then stop immediately: the
        // drain-then-exit contract must still answer every request.
        let rxs: Vec<_> = (0..4).map(|i| b.submit(i)).collect();
        b.stop();
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || {
                b.run(|reqs, _| {
                    std::thread::sleep(Duration::from_millis(5));
                    reqs.into_iter().map(|r| Ok(r * 10)).collect()
                })
            })
        };
        for (i, rx) in rxs.into_iter().enumerate() {
            let (resp, _) = rx.recv().unwrap();
            assert_eq!(resp.unwrap(), i as u32 * 10);
        }
        worker.join().unwrap();
        assert_eq!(b.shed_total(), 0);
    }

    #[test]
    fn dispatch_is_oldest_first_under_concurrent_submitters() {
        let b: Batcher<(u64, u64), (u64, u64)> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_micros(200),
            ..Default::default()
        });
        let seen = Arc::new(Mutex::new(Vec::new()));
        let worker = {
            let b = b.clone();
            let seen = Arc::clone(&seen);
            std::thread::spawn(move || {
                b.run(move |reqs, _| {
                    seen.lock().unwrap().extend(reqs.iter().copied());
                    reqs.into_iter().map(Ok).collect()
                })
            })
        };
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let b = b.clone();
                s.spawn(move || {
                    let rxs: Vec<_> = (0..25u64).map(|i| b.submit((t, i))).collect();
                    for rx in rxs {
                        rx.recv().unwrap().0.unwrap();
                    }
                });
            }
        });
        b.stop();
        worker.join().unwrap();
        // The queue is FIFO, so each submitter's requests must be
        // dispatched in its own submission order regardless of how the
        // four interleave globally.
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 100);
        for t in 0..4u64 {
            let order: Vec<u64> = seen.iter().filter(|(tt, _)| *tt == t).map(|(_, i)| *i).collect();
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(order, sorted, "submitter {t} dispatched out of order");
        }
    }

    #[test]
    fn shutdown_now_answers_queued_requests_with_rejected() {
        // No worker running: shutdown_now must answer the backlog itself.
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig::default());
        let rxs: Vec<_> = (0..3).map(|i| b.submit(i)).collect();
        let rejected = b.shutdown_now("draining for shutdown");
        assert_eq!(rejected, 3);
        for rx in rxs {
            let (resp, _) = rx.recv().expect("drained request must be answered, not dropped");
            assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
        }
        assert_eq!(b.shed_total(), 3);
        // And later submits are shed at the door.
        let (resp, _) = b.submit(9).recv().unwrap();
        assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
    }

    #[test]
    fn dropping_batcher_rejects_queued_requests_instead_of_hanging() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig::default());
        let rx = b.submit(1);
        drop(b);
        let (resp, _) = rx.recv().expect("drop must answer, not sever, the reply channel");
        assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
    }

    #[test]
    fn zero_unanswered_requests_across_racy_shutdown() {
        // Submitters race a mid-stream stop(): every single request must
        // receive *some* answer (Ok or Rejected) — a disconnected reply
        // channel would surface here as a recv() error.
        for trial in 0..8 {
            let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
                buckets: vec![1, 4],
                max_wait: Duration::from_micros(100),
                ..Default::default()
            });
            let worker = {
                let b = b.clone();
                std::thread::spawn(move || {
                    b.run(|reqs, _| reqs.into_iter().map(|r| Ok(r + 1)).collect())
                })
            };
            std::thread::scope(|s| {
                for t in 0..4u32 {
                    let b = b.clone();
                    s.spawn(move || {
                        for i in 0..25u32 {
                            let rx = b.submit(t * 100 + i);
                            let (resp, _) = rx
                                .recv()
                                .unwrap_or_else(|_| panic!("trial {trial}: unanswered request"));
                            match resp {
                                Ok(v) => assert_eq!(v, t * 100 + i + 1),
                                Err(e) => {
                                    assert!(matches!(e, Error::Rejected { .. }), "{e}")
                                }
                            }
                        }
                    });
                }
                let b = b.clone();
                s.spawn(move || {
                    std::thread::sleep(Duration::from_micros(50 * trial));
                    b.stop();
                });
            });
            worker.join().unwrap();
        }
    }

    #[test]
    fn panicking_exec_answers_its_batch_before_unwinding() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1],
            max_wait: Duration::ZERO,
            ..Default::default()
        });
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || b.run(|_reqs, _| -> Vec<Result<u32>> { panic!("exec bug") }))
        };
        let rx = b.submit(5);
        let (resp, _) = rx.recv().expect("panicked batch must still be answered");
        assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
        assert!(worker.join().is_err(), "the panic must propagate to the supervisor");
    }

    #[test]
    fn batch_limit_knob_caps_bucket_choice_live() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1, 4, 8],
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        // Unlimited ceiling: 8 pending fill the 8-bucket.
        assert_eq!(b.pick_bucket(8, Duration::ZERO), Some(8));
        // Ceiling 5 admits the 4-bucket at 8 pending.
        b.knobs().set_batch_limit(5);
        assert_eq!(b.pick_bucket(8, Duration::ZERO), Some(4));
        // Ceiling below every bucket falls back to the smallest.
        b.knobs().set_batch_limit(1);
        assert_eq!(b.pick_bucket(8, Duration::ZERO), Some(1));
        // Raising it back restores full batches without a restart.
        b.knobs().set_batch_limit(usize::MAX);
        assert_eq!(b.pick_bucket(8, Duration::ZERO), Some(8));
    }

    #[test]
    fn max_queue_knob_reconfigures_live() {
        let b: Batcher<u32, u32> = Batcher::new(BatcherConfig {
            buckets: vec![1],
            max_wait: Duration::from_millis(10),
            max_queue: 1,
        });
        let _held = b.submit(1);
        let (resp, _) = b.submit(2).recv().unwrap();
        assert!(matches!(resp.unwrap_err(), Error::Rejected { .. }));
        b.knobs().set_max_queue(10);
        let rx = b.submit(3);
        assert!(
            matches!(rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Empty)),
            "after raising max_queue the submit must queue, not shed"
        );
    }
}
