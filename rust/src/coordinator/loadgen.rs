//! Synthetic fleet load generator for the serving daemon.
//!
//! Spins up one [`Daemon`] and drives it with hundreds (CI) to
//! thousands (manual) of simulated edge sessions: every edge gets its
//! own in-process link — a seeded fraction of them wrapped in the
//! [`FaultyTransport`] chaos model (drops, bit flips, duplicates,
//! mid-frame truncation, delays) — and a retrying [`Session`] on top,
//! exactly the stack a real edge runs. A bounded worker pool walks the
//! fleet so thousands of *sessions* don't require thousands of
//! *client* threads (the daemon still carries one pump per session).
//!
//! The invariant the generator proves is the daemon's no-silent-drop
//! contract at scale: every issued request ends in exactly one explicit
//! outcome — `ok` (verified payload checksum), `rejected` (explicit
//! `Busy`/quota shed), or `failed` (link gave out / server error) — and
//! [`LoadReport::unanswered`] counts anything unaccounted for, which
//! must be zero. The report carries `req_per_s`, `p50_ms`, `p99_ms`
//! for the BENCH line, plus the daemon's adaptive-batching counters so
//! a run shows the controller actually moved.
//!
//! Reproducibility: the fleet layout, fault schedules, payloads, and
//! session jitter all derive from [`LoadgenConfig::seed`]. Wall-clock
//! figures vary run to run; outcome accounting does not.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::Error;
use crate::telemetry::LogHistogram;
use crate::util::json::ObjBuilder;
use crate::util::prng::Rng;

use super::daemon::{Daemon, DaemonConfig, ExecFn};
use super::fault::{FaultSpec, FaultyTransport};
use super::protocol::{Frame, FrameKind};
use super::session::{Session, SessionConfig};

/// Fleet shape and chaos mix for one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Simulated edge sessions (each is one attached daemon connection).
    pub edges: usize,
    /// Sequential requests issued per edge.
    pub requests_per_edge: usize,
    /// Distinct tenants the edges are spread across (round-robin).
    pub tenants: usize,
    /// Master seed for fleet layout, faults, payloads, and jitter.
    pub seed: u64,
    /// Fraction of edges whose link runs the chaos schedule.
    pub faulty_share: f64,
    /// Fault schedule applied (both directions) on faulty links.
    pub chaos: FaultSpec,
    /// Synthetic service time per request, microseconds (0 = pure echo).
    pub service_us: u64,
    /// Request payload size, bytes.
    pub payload_bytes: usize,
    /// Client worker threads walking the fleet (0 = `min(edges, 64)`).
    pub workers: usize,
    /// Daemon under test.
    pub daemon: DaemonConfig,
    /// Per-edge session retry/deadline policy (seed is re-derived per
    /// edge).
    pub session: SessionConfig,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            edges: 100,
            requests_per_edge: 5,
            tenants: 8,
            seed: 0x10ad_6e4e,
            faulty_share: 0.1,
            chaos: FaultSpec::chaos(0.02, Duration::from_micros(500)),
            service_us: 0,
            payload_bytes: 32,
            workers: 0,
            daemon: DaemonConfig::default(),
            session: SessionConfig {
                deadline_ms: 5_000,
                try_timeout_ms: 500,
                max_retries: 3,
                base_backoff_ms: 2,
                max_backoff_ms: 40,
                heartbeat_ms: 0,
                seed: 0x10ad_6e4e,
            },
        }
    }
}

/// Outcome accounting and latency tail of one run; see
/// [`LoadReport::to_json`] for the BENCH export.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Sessions attached.
    pub edges: usize,
    /// Requests issued (`edges × requests_per_edge`).
    pub requests: u64,
    /// Verified successful replies.
    pub ok: u64,
    /// Explicit sheds (`Busy`: queue, quota, admission, or drain).
    pub rejected: u64,
    /// Explicit failures (link gave out, server error, bad checksum).
    pub failed: u64,
    /// Requests with *no* explicit outcome — must be zero; anything
    /// else is a silent drop or a lost client worker.
    pub unanswered: i64,
    /// Wall-clock of the request phase, seconds.
    pub elapsed_s: f64,
    /// Answered requests per second.
    pub req_per_s: f64,
    /// Client-observed latency median, ms.
    pub p50_ms: f64,
    /// Client-observed latency 99th percentile, ms.
    pub p99_ms: f64,
    /// Client-observed latency max, ms.
    pub max_ms: f64,
    /// Daemon batches dispatched.
    pub dispatch_total: u64,
    /// Adaptive controller grow decisions.
    pub batch_grow_total: u64,
    /// Adaptive controller shrink decisions.
    pub batch_shrink_total: u64,
    /// Largest batch the daemon formed.
    pub max_batch: f64,
    /// Requests shed by per-tenant quota.
    pub quota_shed_total: u64,
    /// Distinct tenants the daemon observed.
    pub tenants_seen: usize,
}

impl LoadReport {
    /// Compact JSON with the BENCH keys (`req_per_s`, `p50_ms`,
    /// `p99_ms`, `unanswered`) at top level.
    pub fn to_json(&self) -> String {
        ObjBuilder::new()
            .field("edges", self.edges)
            .field("requests", self.requests as usize)
            .field("ok", self.ok as usize)
            .field("rejected", self.rejected as usize)
            .field("failed", self.failed as usize)
            .field("unanswered", self.unanswered)
            .field("elapsed_s", self.elapsed_s)
            .field("req_per_s", self.req_per_s)
            .field("p50_ms", self.p50_ms)
            .field("p99_ms", self.p99_ms)
            .field("max_ms", self.max_ms)
            .field("dispatch_total", self.dispatch_total as usize)
            .field("batch_grow_total", self.batch_grow_total as usize)
            .field("batch_shrink_total", self.batch_shrink_total as usize)
            .field("max_batch", self.max_batch)
            .field("quota_shed_total", self.quota_shed_total as usize)
            .field("tenants_seen", self.tenants_seen)
            .build()
            .to_string_compact()
    }
}

/// Deterministic request payload for `(edge, request)`.
fn payload_for(edge: usize, req: usize, bytes: usize) -> Vec<u8> {
    (0..bytes.max(1)).map(|k| ((edge * 31 + req * 7 + k * 13) % 251) as u8).collect()
}

/// The checksum the synthetic exec echoes back (exact in f32 for any
/// sane payload size).
fn checksum(payload: &[u8]) -> f32 {
    payload.iter().map(|&b| b as u64).sum::<u64>() as f32
}

/// Synthetic request handler: checksum echo with an optional busy-wait
/// service time, standing in for decode + tail compute.
pub fn synthetic_exec(service_us: u64) -> ExecFn {
    Arc::new(move |frame: &Frame| {
        if service_us > 0 {
            std::thread::sleep(Duration::from_micros(service_us));
        }
        let kind = match &frame.kind {
            FrameKind::InferLm { payload, .. }
            | FrameKind::InferLmRaw { payload, .. }
            | FrameKind::InferVision { payload, .. }
            | FrameKind::InferVisionRaw { payload, .. } => FrameKind::Logits {
                data: vec![checksum(payload)],
                decode_ms: 0.0,
                compute_ms: service_us as f32 / 1e3,
            },
            other => FrameKind::ServerError { message: format!("loadgen exec got {other:?}") },
        };
        Frame::new(frame.request_id, kind)
    })
}

/// Run one synthetic fleet against a fresh daemon and account every
/// request's outcome.
pub fn run(cfg: &LoadgenConfig) -> LoadReport {
    let daemon = Daemon::new(cfg.daemon.clone(), synthetic_exec(cfg.service_us));
    let mut rng = Rng::new(cfg.seed);

    // Lay out the fleet: per-edge link (seeded chaos on a faulty
    // share), cloud half attached under a round-robin tenant.
    let tenants = cfg.tenants.max(1);
    let mut slots: Vec<Mutex<Option<FaultyTransport>>> = Vec::with_capacity(cfg.edges);
    for i in 0..cfg.edges {
        let spec = if rng.bool_with(cfg.faulty_share) { cfg.chaos } else { FaultSpec::none() };
        let (edge_end, cloud_end) = FaultyTransport::pair(rng.fork(i as u64).next_u64(), spec, spec);
        daemon.attach(Box::new(cloud_end), &format!("t{:02}", i % tenants));
        slots.push(Mutex::new(Some(edge_end)));
    }

    let latency = Arc::new(LogHistogram::new());
    let next_edge = AtomicUsize::new(0);
    let ok = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let workers = if cfg.workers == 0 { cfg.edges.clamp(1, 64) } else { cfg.workers.max(1) };

    let started = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                loop {
                    let i = next_edge.fetch_add(1, Ordering::SeqCst);
                    if i >= cfg.edges {
                        return;
                    }
                    let transport = slots[i].lock().unwrap().take().expect("edge taken once");
                    let mut session = Session::new(
                        transport,
                        SessionConfig {
                            seed: cfg.seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                            ..cfg.session.clone()
                        },
                    );
                    for r in 0..cfg.requests_per_edge {
                        let payload = payload_for(i, r, cfg.payload_bytes);
                        let want = checksum(&payload);
                        let t0 = Instant::now();
                        let outcome = session
                            .call(FrameKind::InferLm { model: "loadgen".into(), payload });
                        latency.record_ms(t0.elapsed().as_secs_f64() * 1e3);
                        match outcome {
                            Ok(frame) => match frame.kind {
                                FrameKind::Logits { ref data, .. }
                                    if data.first() == Some(&want) =>
                                {
                                    ok.fetch_add(1, Ordering::SeqCst);
                                }
                                FrameKind::Busy { .. } => {
                                    rejected.fetch_add(1, Ordering::SeqCst);
                                }
                                _ => {
                                    failed.fetch_add(1, Ordering::SeqCst);
                                }
                            },
                            Err(Error::Rejected { .. }) => {
                                rejected.fetch_add(1, Ordering::SeqCst);
                            }
                            Err(_) => {
                                failed.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    }
                }
            });
        }
    });
    let elapsed_s = started.elapsed().as_secs_f64().max(1e-9);

    let requests = (cfg.edges * cfg.requests_per_edge) as u64;
    let (ok, rejected, failed) =
        (ok.into_inner(), rejected.into_inner(), failed.into_inner());
    let answered = ok + rejected + failed;
    let metrics = daemon.metrics();
    let report = LoadReport {
        edges: cfg.edges,
        requests,
        ok,
        rejected,
        failed,
        unanswered: requests as i64 - answered as i64,
        elapsed_s,
        req_per_s: answered as f64 / elapsed_s,
        p50_ms: latency.quantile_ms(0.5),
        p99_ms: latency.quantile_ms(0.99),
        max_ms: latency.max_ms(),
        dispatch_total: metrics.get("daemon.dispatch_total"),
        batch_grow_total: metrics.get("daemon.batch_grow_total"),
        batch_shrink_total: metrics.get("daemon.batch_shrink_total"),
        max_batch: metrics.histogram("daemon.batch_size").max_ms(),
        quota_shed_total: metrics.get("daemon.quota_shed_total"),
        tenants_seen: daemon.tenant_count(),
    };
    daemon.shutdown();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fleet_accounts_every_request() {
        let cfg = LoadgenConfig {
            edges: 40,
            requests_per_edge: 3,
            tenants: 4,
            faulty_share: 0.0,
            ..Default::default()
        };
        let report = run(&cfg);
        assert_eq!(report.requests, 120);
        assert_eq!(report.unanswered, 0, "every request needs an explicit outcome");
        assert_eq!(report.ok, 120, "clean links and no quota pressure: all succeed");
        assert!(report.req_per_s > 0.0);
        assert_eq!(report.tenants_seen, 4);
    }

    #[test]
    fn chaotic_fleet_still_accounts_every_request() {
        let cfg = LoadgenConfig {
            edges: 30,
            requests_per_edge: 4,
            tenants: 3,
            faulty_share: 0.5,
            chaos: FaultSpec::chaos(0.05, Duration::from_micros(200)),
            ..Default::default()
        };
        let report = run(&cfg);
        assert_eq!(report.unanswered, 0, "chaos may fail requests but never swallow them");
        assert_eq!(report.ok + report.rejected + report.failed, report.requests);
        assert!(report.ok > 0, "retrying sessions should land most requests");
    }

    #[test]
    fn report_json_carries_the_bench_keys() {
        let report = run(&LoadgenConfig {
            edges: 8,
            requests_per_edge: 2,
            faulty_share: 0.0,
            ..Default::default()
        });
        let json = report.to_json();
        for key in ["req_per_s", "p50_ms", "p99_ms", "\"unanswered\":0"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let parsed = crate::util::json::parse(&json).unwrap();
        assert!(parsed.get("req_per_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn identical_seeds_give_identical_fleet_layouts() {
        // Outcome accounting (not wall-clock) is the reproducible part:
        // same seed → same payloads, same fault schedule, same totals.
        let cfg = LoadgenConfig {
            edges: 20,
            requests_per_edge: 2,
            faulty_share: 0.3,
            ..Default::default()
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.unanswered, 0);
        assert_eq!(b.unanswered, 0);
    }
}
