//! Cloud node: decompress → tail compute → reply.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{Engine as CodecEngine, EngineHandle};
use crate::error::{Error, Result};
use crate::runtime::{Engine, ExecPool, LmSplitExec, Manifest, VisionSplitExec};
use crate::telemetry::Registry;
use crate::tensor::{Dtype, TensorRef};
use crate::util::timer::Stopwatch;

use super::protocol::{Frame, FrameKind};
use super::transport::{TcpTransport, Transport};

/// The cloud-side serving node.
///
/// Owns the PJRT engine, the artifact pool, and per-route executable
/// caches; `handle` is a pure request→reply function so the same node
/// serves TCP connections, in-proc transports, and direct calls from
/// benches. Container decoding fans out on the shared compression
/// engine's persistent worker pool, so concurrent connections share one
/// machine-sized pool instead of oversubscribing the host.
pub struct CloudNode {
    manifest: Manifest,
    pool: ExecPool,
    codec: EngineHandle,
    metrics: Arc<Registry>,
    vision_cache: Mutex<HashMap<(String, usize, usize), Arc<VisionSplitExec>>>,
    lm_cache: Mutex<HashMap<String, Arc<LmSplitExec>>>,
}

impl CloudNode {
    /// Load the manifest and initialize the PJRT engine.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let engine = Arc::new(Engine::cpu()?);
        let pool = ExecPool::new(engine, artifacts_dir.as_ref());
        Ok(CloudNode {
            manifest,
            pool,
            codec: EngineHandle::shared(),
            metrics: Arc::new(Registry::new()),
            vision_cache: Mutex::new(HashMap::new()),
            lm_cache: Mutex::new(HashMap::new()),
        })
    }

    /// Decode on a dedicated compression engine instead of the shared
    /// one (tests and multi-tenant setups). Decode-side threading
    /// follows that engine's config
    /// ([`crate::engine::EngineConfig::decode_parallel`]) — there is no
    /// per-node knob anymore.
    pub fn with_codec_engine(mut self, codec: Arc<CodecEngine>) -> Self {
        self.codec = EngineHandle::dedicated(codec);
        self
    }

    /// The node's metrics registry.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling on first use) the vision executables for a route.
    pub fn vision_exec(&self, model: &str, sl: usize, batch: usize) -> Result<Arc<VisionSplitExec>> {
        let key = (model.to_string(), sl, batch);
        if let Some(e) = self.vision_cache.lock().unwrap().get(&key) {
            return Ok(Arc::clone(e));
        }
        let exec = Arc::new(VisionSplitExec::load(&self.pool, &self.manifest, model, sl, batch)?);
        let mut cache = self.vision_cache.lock().unwrap();
        let entry = cache.entry(key).or_insert_with(|| Arc::clone(&exec));
        Ok(Arc::clone(entry))
    }

    /// Fetch (compiling on first use) the LM executables for a model.
    pub fn lm_exec(&self, model: &str) -> Result<Arc<LmSplitExec>> {
        if let Some(e) = self.lm_cache.lock().unwrap().get(model) {
            return Ok(Arc::clone(e));
        }
        let exec = Arc::new(LmSplitExec::load(&self.pool, &self.manifest, model)?);
        let mut cache = self.lm_cache.lock().unwrap();
        let entry = cache.entry(model.to_string()).or_insert_with(|| Arc::clone(&exec));
        Ok(Arc::clone(entry))
    }

    /// Widen a raw frame payload of `dtype` elements to the `f32`
    /// vector the tail artifacts consume (element-wise, straight off
    /// the borrowed wire bytes).
    fn bytes_to_f32s(dtype: Dtype, payload: &[u8]) -> Result<Vec<f32>> {
        TensorRef::from_le_bytes(dtype, payload)
            .map(|t| t.to_f32_vec())
            .map_err(|e| Error::protocol(format!("raw payload: {e}")))
    }

    fn infer_vision(&self, model: &str, sl: usize, batch: usize, payload: &[u8]) -> Result<FrameKind> {
        let exec = self.vision_exec(model, sl, batch)?;
        let sw = Stopwatch::new();
        let (symbols, params) = self.codec.get().decompress_to_symbols(payload)?;
        let decode_ms = sw.elapsed_ms();
        let sw = Stopwatch::new();
        let logits = exec.run_tail(&symbols, &params)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.vision_requests", 1);
        self.metrics.histogram("cloud.decode_ms").record_ms(decode_ms);
        self.metrics.histogram("cloud.compute_ms").record_ms(compute_ms);
        Ok(FrameKind::Logits { data: logits, decode_ms: decode_ms as f32, compute_ms: compute_ms as f32 })
    }

    fn infer_vision_raw(
        &self,
        model: &str,
        sl: usize,
        batch: usize,
        dtype: Dtype,
        payload: &[u8],
    ) -> Result<FrameKind> {
        let exec = self.vision_exec(model, sl, batch)?;
        let sw = Stopwatch::new();
        let feat = Self::bytes_to_f32s(dtype, payload)?;
        let decode_ms = sw.elapsed_ms();
        let sw = Stopwatch::new();
        let logits = exec.run_tail_raw(&feat)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.vision_raw_requests", 1);
        Ok(FrameKind::Logits { data: logits, decode_ms: decode_ms as f32, compute_ms: compute_ms as f32 })
    }

    fn infer_lm(&self, model: &str, payload: &[u8]) -> Result<FrameKind> {
        let exec = self.lm_exec(model)?;
        let sw = Stopwatch::new();
        let (symbols, params) = self.codec.get().decompress_to_symbols(payload)?;
        let decode_ms = sw.elapsed_ms();
        let sw = Stopwatch::new();
        let logits = exec.run_tail(&symbols, &params)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.lm_requests", 1);
        self.metrics.histogram("cloud.decode_ms").record_ms(decode_ms);
        self.metrics.histogram("cloud.compute_ms").record_ms(compute_ms);
        Ok(FrameKind::Logits { data: logits, decode_ms: decode_ms as f32, compute_ms: compute_ms as f32 })
    }

    fn infer_lm_raw(&self, model: &str, dtype: Dtype, payload: &[u8]) -> Result<FrameKind> {
        let exec = self.lm_exec(model)?;
        let hidden = Self::bytes_to_f32s(dtype, payload)?;
        let sw = Stopwatch::new();
        let logits = exec.run_tail_raw(&hidden)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.lm_raw_requests", 1);
        Ok(FrameKind::Logits { data: logits, decode_ms: 0.0, compute_ms: compute_ms as f32 })
    }

    /// Handle one frame, producing the reply. Errors become
    /// `ServerError` replies rather than tearing the connection down.
    pub fn handle(&self, frame: &Frame) -> Frame {
        let reply = match &frame.kind {
            FrameKind::Ping => Ok(FrameKind::Pong),
            FrameKind::InferVision { model, sl, batch, payload } => {
                self.infer_vision(model, *sl, *batch, payload)
            }
            FrameKind::InferVisionRaw { model, sl, batch, dtype, payload } => {
                self.infer_vision_raw(model, *sl, *batch, *dtype, payload)
            }
            FrameKind::InferLm { model, payload } => self.infer_lm(model, payload),
            FrameKind::InferLmRaw { model, dtype, payload } => {
                self.infer_lm_raw(model, *dtype, payload)
            }
            FrameKind::Stats => Ok(FrameKind::StatsReply {
                json: self.metrics.snapshot().to_string_compact(),
            }),
            FrameKind::Shutdown => Ok(FrameKind::Pong),
            other => Err(Error::protocol(format!("unexpected frame {other:?}"))),
        };
        let kind = match reply {
            Ok(k) => k,
            Err(e) => {
                self.metrics.incr("cloud.errors", 1);
                FrameKind::ServerError { message: e.to_string() }
            }
        };
        Frame { request_id: frame.request_id, kind }
    }

    /// Serve a single transport until the peer shuts down or errors.
    pub fn serve_transport(&self, t: &mut dyn Transport) -> Result<()> {
        loop {
            let frame = match t.recv() {
                Ok(f) => f,
                Err(_) => return Ok(()), // peer closed
            };
            let shutdown = matches!(frame.kind, FrameKind::Shutdown);
            let reply = self.handle(&frame);
            t.send(&reply)?;
            if shutdown {
                return Ok(());
            }
        }
    }

    /// Accept loop over TCP; one thread per connection. Returns when
    /// `stop` becomes true (checked between accepts) or after a client
    /// sends `Shutdown` (which also raises `stop`).
    pub fn serve_tcp(self: &Arc<Self>, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::transport(format!("nonblocking: {e}")))?;
        let mut workers = Vec::new();
        while !stop.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| Error::transport(format!("blocking: {e}")))?;
                    let node = Arc::clone(self);
                    let stop = Arc::clone(&stop);
                    workers.push(std::thread::spawn(move || {
                        let mut t = match TcpTransport::new(stream) {
                            Ok(t) => t,
                            Err(_) => return,
                        };
                        loop {
                            let frame = match t.recv() {
                                Ok(f) => f,
                                Err(_) => return,
                            };
                            let is_shutdown = matches!(frame.kind, FrameKind::Shutdown);
                            let reply = node.handle(&frame);
                            let _ = t.send(&reply);
                            if is_shutdown {
                                stop.store(true, Ordering::SeqCst);
                                return;
                            }
                        }
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(Error::transport(format!("accept: {e}"))),
            }
        }
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}
