//! Cloud node: decompress → tail compute → reply.
//!
//! Inference frames pass through a bounded **admission** gate before
//! touching the decoder: when the in-flight count hits
//! [`ServerLimits::max_inflight`], or the request's deadline header is
//! provably unmeetable given the observed service-time EWMA, the node
//! sheds the request explicitly with a [`FrameKind::Busy`] reply
//! carrying a retry-after hint instead of queueing it into a timeout.
//! Control frames (Ping/Stats/Shutdown) always bypass admission so
//! liveness probes keep working under overload.
//!
//! A registry-deployed node additionally pins a
//! [`ModelSlot`](crate::runtime::registry::ModelSlot): requests whose
//! model-version header disagrees with the active deployment are
//! answered with [`FrameKind::VersionSkew`] **before** admission (a
//! mismatched request must not consume an in-flight slot, and must
//! never be decoded against the wrong tail), and
//! [`CloudNode::hot_swap`] stages → smoke-verifies → atomically flips
//! the active version while in-flight requests drain on the snapshot
//! they started with.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::engine::{Engine as CodecEngine, EngineHandle};
use crate::error::{Error, Result};
use crate::runtime::registry::{smoke_decode, ChunkStore, DeployParams, ModelSlot};
use crate::runtime::{Engine, ExecPool, LmSplitExec, Manifest, VisionSplitExec};
use crate::telemetry::Registry;
use crate::tensor::{Dtype, TensorRef};
use crate::util::timer::Stopwatch;

use super::knobs::ServingKnobs;
use super::protocol::{Frame, FrameKind};
use super::transport::{TcpTransport, Transport};

/// Bounds on concurrent work the serving loops will accept.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerLimits {
    /// Maximum inference frames being handled at once across all
    /// connections; requests beyond this are shed with `Busy`.
    pub max_inflight: usize,
}

impl Default for ServerLimits {
    fn default() -> Self {
        ServerLimits { max_inflight: 32 }
    }
}

/// Admission gate shared by all serving threads.
///
/// Tracks the in-flight count and an EWMA of observed service times so
/// shed decisions (and the retry-after hint they carry) reflect the
/// node's actual throughput rather than a hardcoded guess. The cap is
/// read per admission from a shared [`ServingKnobs`] handle, so it can
/// be retuned on a live server ([`Admission::knobs`]).
pub struct Admission {
    knobs: Arc<ServingKnobs>,
    inflight: AtomicUsize,
    /// EWMA of service time in microseconds; `0` until the first
    /// completion. Updated with α = 1/8 (racy read-modify-write is fine:
    /// it is a smoothed hint, not an invariant).
    ewma_service_us: AtomicU64,
}

impl Admission {
    /// Gate seeded from static limits (a private knobs handle).
    pub fn new(limits: ServerLimits) -> Self {
        Self::with_knobs(Arc::new(ServingKnobs::from_limits(&limits)))
    }

    /// Gate reading `max_inflight` from an existing shared handle.
    pub fn with_knobs(knobs: Arc<ServingKnobs>) -> Self {
        Admission { knobs, inflight: AtomicUsize::new(0), ewma_service_us: AtomicU64::new(0) }
    }

    /// The live-reconfigurable limits this gate reads per admission.
    pub fn knobs(&self) -> &Arc<ServingKnobs> {
        &self.knobs
    }

    /// Requests currently holding a slot.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    fn ewma_ms(&self) -> u64 {
        self.ewma_service_us.load(Ordering::Relaxed) / 1_000
    }

    /// The slot-acquisition decision shared by both guard flavours:
    /// `Ok(())` with the slot held, or the suggested retry-after (ms).
    fn admit_slot(&self, deadline_ms: Option<u32>) -> std::result::Result<(), u64> {
        let ewma_ms = self.ewma_ms();
        let queued = self.inflight.fetch_add(1, Ordering::SeqCst);
        if queued >= self.knobs.max_inflight() {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(ewma_ms.max(1));
        }
        if let (Some(deadline), true) = (deadline_ms, ewma_ms > 0) {
            let est_ms = ewma_ms.saturating_mul(queued as u64 + 1);
            if est_ms > deadline as u64 {
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                return Err(ewma_ms.max(1));
            }
        }
        Ok(())
    }

    fn release(&self, start: Instant) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        let us = start.elapsed().as_micros().min(u64::MAX as u128) as u64;
        self.note_service(us);
    }

    /// Admit one request, or return the suggested retry-after (ms).
    ///
    /// Sheds when the in-flight cap is hit, and — when the request
    /// carries a deadline header — when the backlog ahead of it times
    /// the service-time EWMA already exceeds that deadline (the request
    /// is provably unmeetable, so failing fast beats a doomed decode).
    pub fn try_admit(&self, deadline_ms: Option<u32>) -> std::result::Result<AdmitGuard<'_>, u64> {
        self.admit_slot(deadline_ms)?;
        Ok(AdmitGuard { admission: self, start: Instant::now() })
    }

    /// Like [`Admission::try_admit`], but the returned permit owns an
    /// `Arc` to the gate so it can travel with a queued job across
    /// threads (the daemon holds the slot from ingress until the reply
    /// is sent, so the EWMA observes queue + service time).
    pub fn try_admit_owned(
        self: &Arc<Self>,
        deadline_ms: Option<u32>,
    ) -> std::result::Result<AdmitPermit, u64> {
        self.admit_slot(deadline_ms)?;
        Ok(AdmitPermit { admission: Arc::clone(self), start: Instant::now() })
    }

    fn note_service(&self, observed_us: u64) {
        let old = self.ewma_service_us.load(Ordering::Relaxed);
        let new = if old == 0 { observed_us } else { old - old / 8 + observed_us / 8 };
        self.ewma_service_us.store(new, Ordering::Relaxed);
    }
}

/// Releases the in-flight slot and feeds the service-time EWMA on drop.
pub struct AdmitGuard<'a> {
    admission: &'a Admission,
    start: Instant,
}

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        self.admission.release(self.start);
    }
}

/// Owned flavour of [`AdmitGuard`] for jobs that outlive the admitting
/// stack frame (queued behind a batcher, executed on another thread).
pub struct AdmitPermit {
    admission: Arc<Admission>,
    start: Instant,
}

impl Drop for AdmitPermit {
    fn drop(&mut self) {
        self.admission.release(self.start);
    }
}

/// The cloud-side serving node.
///
/// Owns the PJRT engine, the artifact pool, and per-route executable
/// caches; `handle` is a pure request→reply function so the same node
/// serves TCP connections, in-proc transports, and direct calls from
/// benches. Container decoding fans out on the shared compression
/// engine's persistent worker pool, so concurrent connections share one
/// machine-sized pool instead of oversubscribing the host.
pub struct CloudNode {
    manifest: Manifest,
    pool: ExecPool,
    codec: EngineHandle,
    metrics: Arc<Registry>,
    admission: Arc<Admission>,
    /// Active registry deployment. Version 0 = unversioned legacy
    /// serving: no skew checks run and version headers are ignored.
    model_slot: ModelSlot<DeployParams>,
    /// When present, the node also serves registry delta-sync frames
    /// (FetchManifest/FetchChunk) out of this local store.
    registry: Option<RegistryProvider>,
    vision_cache: Mutex<HashMap<(String, usize, usize), Arc<VisionSplitExec>>>,
    lm_cache: Mutex<HashMap<String, Arc<LmSplitExec>>>,
}

/// Serves the registry delta-sync frames (tags 17–20) out of a local
/// [`ChunkStore`]. Standalone so it plugs into [`CloudNode`] *and*
/// bare test/CI responders that have no inference artifacts at all.
///
/// Every chunk leaves the store fully verified ([`ChunkStore`] never
/// hands out a corrupt payload), but the requester re-verifies anyway —
/// the server is not in the trust boundary.
pub struct RegistryProvider {
    store: ChunkStore,
}

impl RegistryProvider {
    pub fn new(store: ChunkStore) -> Self {
        RegistryProvider { store }
    }

    /// Answer a registry frame; `None` when `kind` is not one. Failures
    /// become `ServerError` replies (typed fatal on the client side —
    /// re-requesting an absent chunk cannot help).
    pub fn try_serve(&self, kind: &FrameKind) -> Option<FrameKind> {
        match kind {
            FrameKind::FetchManifest { model, version } => {
                let slot = if *version == 0 { None } else { Some(*version) };
                Some(match self.store.signed_manifest_text(model, slot) {
                    Ok(json) => FrameKind::ManifestReply { json },
                    Err(e) => FrameKind::ServerError { message: e.to_string() },
                })
            }
            FrameKind::FetchChunk { sha256 } => {
                Some(match self.store.get_chunk_by_addr(sha256) {
                    Ok(payload) => FrameKind::ChunkReply { payload },
                    Err(e) => FrameKind::ServerError { message: e.to_string() },
                })
            }
            _ => None,
        }
    }
}

/// The pre-admission version check, as a pure function so it is
/// testable without artifacts: `Some(reply)` when the request must be
/// refused with a skew frame. `active == 0` (unversioned node) and
/// headerless requests (legacy edges) always pass.
fn skew_reply(active: u64, frame: &Frame) -> Option<FrameKind> {
    match frame.model_version {
        Some(offered) if active != 0 && offered != active => Some(FrameKind::VersionSkew {
            active,
            offered,
            message: "cloud is serving a different deployment; resync from the registry".into(),
        }),
        _ => None,
    }
}

impl CloudNode {
    /// Load the manifest and initialize the PJRT engine.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let engine = Arc::new(Engine::cpu()?);
        let pool = ExecPool::new(engine, artifacts_dir.as_ref());
        Ok(CloudNode {
            manifest,
            pool,
            codec: EngineHandle::shared(),
            metrics: Arc::new(Registry::new()),
            admission: Arc::new(Admission::new(ServerLimits::default())),
            model_slot: ModelSlot::new(0, DeployParams::paper(8)),
            registry: None,
            vision_cache: Mutex::new(HashMap::new()),
            lm_cache: Mutex::new(HashMap::new()),
        })
    }

    /// Also serve registry delta-sync frames out of `store`. Fetch
    /// frames bypass the inference admission gate *and* the version
    /// skew check — a skewed edge must be able to fetch the very
    /// deployment that fixes its skew.
    pub fn with_registry_store(mut self, store: ChunkStore) -> Self {
        self.registry = Some(RegistryProvider::new(store));
        self
    }

    /// Replace the default admission bounds.
    pub fn with_limits(mut self, limits: ServerLimits) -> Self {
        self.admission = Arc::new(Admission::new(limits));
        self
    }

    /// Share an existing knobs handle (daemon/operator retuning): the
    /// admission gate re-reads `max_inflight` on every decision.
    pub fn with_serving_knobs(mut self, knobs: Arc<ServingKnobs>) -> Self {
        self.admission = Arc::new(Admission::with_knobs(knobs));
        self
    }

    /// The node's admission gate (shared, hot-reconfigurable via
    /// [`Admission::knobs`]).
    pub fn admission(&self) -> &Arc<Admission> {
        &self.admission
    }

    /// Pin the node to a registry deployment: requests declaring a
    /// different `model_version` are answered with `VersionSkew` before
    /// admission. Version 0 keeps the unversioned legacy behaviour.
    pub fn with_model_version(mut self, version: u64, deploy: DeployParams) -> Self {
        self.model_slot = ModelSlot::new(version, deploy);
        self
    }

    /// The active deployment version (0 = unversioned).
    pub fn model_version(&self) -> u64 {
        self.model_slot.version()
    }

    /// Stage → smoke-verify → atomically flip to `(version, deploy)`.
    ///
    /// The smoke check ([`smoke_decode`]) replays a synthetic
    /// compress/decode roundtrip at the staged codec parameters while
    /// the old version is still serving; any failure (or a
    /// non-monotonic version) leaves the prior deployment active and
    /// counts `cloud.rollback_total`. A successful flip counts
    /// `cloud.swap_total`; in-flight requests drain on the snapshot
    /// they admitted with.
    pub fn hot_swap(&self, version: u64, deploy: DeployParams) -> Result<()> {
        match self.model_slot.hot_swap(version, deploy, smoke_decode) {
            Ok(_displaced) => {
                self.metrics.incr("cloud.swap_total", 1);
                Ok(())
            }
            Err(e) => {
                self.metrics.incr("cloud.rollback_total", 1);
                Err(e)
            }
        }
    }

    /// Decode on a dedicated compression engine instead of the shared
    /// one (tests and multi-tenant setups). Decode-side threading
    /// follows that engine's config
    /// ([`crate::engine::EngineConfig::decode_parallel`]) — there is no
    /// per-node knob anymore.
    pub fn with_codec_engine(mut self, codec: Arc<CodecEngine>) -> Self {
        self.codec = EngineHandle::dedicated(codec);
        self
    }

    /// The node's metrics registry.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Fetch (compiling on first use) the vision executables for a route.
    pub fn vision_exec(&self, model: &str, sl: usize, batch: usize) -> Result<Arc<VisionSplitExec>> {
        let key = (model.to_string(), sl, batch);
        if let Some(e) = self.vision_cache.lock().unwrap().get(&key) {
            return Ok(Arc::clone(e));
        }
        let exec = Arc::new(VisionSplitExec::load(&self.pool, &self.manifest, model, sl, batch)?);
        let mut cache = self.vision_cache.lock().unwrap();
        let entry = cache.entry(key).or_insert_with(|| Arc::clone(&exec));
        Ok(Arc::clone(entry))
    }

    /// Fetch (compiling on first use) the LM executables for a model.
    pub fn lm_exec(&self, model: &str) -> Result<Arc<LmSplitExec>> {
        if let Some(e) = self.lm_cache.lock().unwrap().get(model) {
            return Ok(Arc::clone(e));
        }
        let exec = Arc::new(LmSplitExec::load(&self.pool, &self.manifest, model)?);
        let mut cache = self.lm_cache.lock().unwrap();
        let entry = cache.entry(model.to_string()).or_insert_with(|| Arc::clone(&exec));
        Ok(Arc::clone(entry))
    }

    /// Widen a raw frame payload of `dtype` elements to the `f32`
    /// vector the tail artifacts consume (element-wise, straight off
    /// the borrowed wire bytes).
    fn bytes_to_f32s(dtype: Dtype, payload: &[u8]) -> Result<Vec<f32>> {
        TensorRef::from_le_bytes(dtype, payload)
            .map(|t| t.to_f32_vec())
            .map_err(|e| Error::protocol(format!("raw payload: {e}")))
    }

    fn infer_vision(&self, model: &str, sl: usize, batch: usize, payload: &[u8]) -> Result<FrameKind> {
        let exec = self.vision_exec(model, sl, batch)?;
        let sw = Stopwatch::new();
        let (symbols, params) = self.codec.get().decompress_to_symbols(payload)?;
        let decode_ms = sw.elapsed_ms();
        let sw = Stopwatch::new();
        let logits = exec.run_tail(&symbols, &params)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.vision_requests", 1);
        self.metrics.histogram("cloud.decode_ms").record_ms(decode_ms);
        self.metrics.histogram("cloud.compute_ms").record_ms(compute_ms);
        Ok(FrameKind::Logits { data: logits, decode_ms: decode_ms as f32, compute_ms: compute_ms as f32 })
    }

    fn infer_vision_raw(
        &self,
        model: &str,
        sl: usize,
        batch: usize,
        dtype: Dtype,
        payload: &[u8],
    ) -> Result<FrameKind> {
        let exec = self.vision_exec(model, sl, batch)?;
        let sw = Stopwatch::new();
        let feat = Self::bytes_to_f32s(dtype, payload)?;
        let decode_ms = sw.elapsed_ms();
        let sw = Stopwatch::new();
        let logits = exec.run_tail_raw(&feat)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.vision_raw_requests", 1);
        Ok(FrameKind::Logits { data: logits, decode_ms: decode_ms as f32, compute_ms: compute_ms as f32 })
    }

    fn infer_lm(&self, model: &str, payload: &[u8]) -> Result<FrameKind> {
        let exec = self.lm_exec(model)?;
        let sw = Stopwatch::new();
        let (symbols, params) = self.codec.get().decompress_to_symbols(payload)?;
        let decode_ms = sw.elapsed_ms();
        let sw = Stopwatch::new();
        let logits = exec.run_tail(&symbols, &params)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.lm_requests", 1);
        self.metrics.histogram("cloud.decode_ms").record_ms(decode_ms);
        self.metrics.histogram("cloud.compute_ms").record_ms(compute_ms);
        Ok(FrameKind::Logits { data: logits, decode_ms: decode_ms as f32, compute_ms: compute_ms as f32 })
    }

    fn infer_lm_raw(&self, model: &str, dtype: Dtype, payload: &[u8]) -> Result<FrameKind> {
        let exec = self.lm_exec(model)?;
        let hidden = Self::bytes_to_f32s(dtype, payload)?;
        let sw = Stopwatch::new();
        let logits = exec.run_tail_raw(&hidden)?;
        let compute_ms = sw.elapsed_ms();
        self.metrics.incr("cloud.lm_raw_requests", 1);
        Ok(FrameKind::Logits { data: logits, decode_ms: 0.0, compute_ms: compute_ms as f32 })
    }

    /// The pre-admission version check exactly as [`Self::admit_and_handle`]
    /// runs it: `Some(refusal)` when the frame declares a different
    /// deployment than the active one (counting `cloud.skew_total`).
    /// Public so alternative fronts — the serving daemon's connection
    /// pumps — can refuse skewed requests before spending tenant quota,
    /// admission slots, or batch space on them.
    pub fn check_skew(&self, frame: &Frame) -> Option<Frame> {
        skew_reply(self.model_slot.version(), frame).map(|kind| {
            self.metrics.incr("cloud.skew_total", 1);
            Frame::new(frame.request_id, kind)
        })
    }

    /// Handle one frame, producing the reply. Errors become
    /// `ServerError` replies rather than tearing the connection down.
    pub fn handle(&self, frame: &Frame) -> Frame {
        let reply = match &frame.kind {
            FrameKind::Ping => Ok(FrameKind::Pong),
            FrameKind::InferVision { model, sl, batch, payload } => {
                self.infer_vision(model, *sl, *batch, payload)
            }
            FrameKind::InferVisionRaw { model, sl, batch, dtype, payload } => {
                self.infer_vision_raw(model, *sl, *batch, *dtype, payload)
            }
            FrameKind::InferLm { model, payload } => self.infer_lm(model, payload),
            FrameKind::InferLmRaw { model, dtype, payload } => {
                self.infer_lm_raw(model, *dtype, payload)
            }
            FrameKind::Stats => Ok(FrameKind::StatsReply { json: self.metrics.snapshot_json() }),
            FrameKind::Shutdown => Ok(FrameKind::Pong),
            kind @ (FrameKind::FetchManifest { .. } | FrameKind::FetchChunk { .. }) => {
                match self.registry.as_ref().and_then(|r| r.try_serve(kind)) {
                    Some(reply) => {
                        self.metrics.incr("cloud.registry_requests", 1);
                        Ok(reply)
                    }
                    None => Err(Error::protocol(
                        "this node does not serve registry frames (no registry store attached)",
                    )),
                }
            }
            other => Err(Error::protocol(format!("unexpected frame {other:?}"))),
        };
        let kind = match reply {
            Ok(k) => k,
            Err(e) => {
                self.metrics.incr("cloud.errors", 1);
                FrameKind::ServerError { message: e.to_string() }
            }
        };
        Frame::new(frame.request_id, kind)
    }

    /// [`CloudNode::handle`] behind the admission gate: inference frames
    /// that would blow the in-flight cap — or whose deadline header is
    /// already unmeetable — are shed with an explicit `Busy` reply;
    /// control frames (Ping/Stats/Shutdown) always pass.
    pub fn admit_and_handle(&self, frame: &Frame) -> Frame {
        let needs_admission = matches!(
            frame.kind,
            FrameKind::InferVision { .. }
                | FrameKind::InferVisionRaw { .. }
                | FrameKind::InferLm { .. }
                | FrameKind::InferLmRaw { .. }
        );
        if !needs_admission {
            return self.handle(frame);
        }
        // Version check BEFORE admission: a mismatched request must not
        // consume an in-flight slot, and must never reach the decoder —
        // features decoded against the wrong tail are silent garbage.
        if let Some(reply) = self.check_skew(frame) {
            return reply;
        }
        match self.admission.try_admit(frame.deadline_ms) {
            Ok(_guard) => self.handle(frame),
            Err(retry_after_ms) => {
                self.metrics.incr("cloud.shed_total", 1);
                let kind = FrameKind::Busy {
                    retry_after_ms: retry_after_ms.min(u32::MAX as u64) as u32,
                    message: format!(
                        "inflight cap {} reached or deadline unmeetable",
                        self.admission.knobs().max_inflight()
                    ),
                };
                Frame::new(frame.request_id, kind)
            }
        }
    }

    /// Shared receive loop: handle frames until the peer goes away.
    ///
    /// Retryable receive errors (an injected garble on a lossy link, a
    /// spurious timeout) are tolerated up to a short consecutive run so
    /// one bad frame does not kill a message-framed connection; a dead
    /// peer produces the same error back-to-back and exits promptly.
    /// Returns `true` when the loop ended because a `Shutdown` frame
    /// was served.
    fn serve_loop(&self, t: &mut dyn Transport) -> bool {
        let mut consecutive_errors = 0u32;
        loop {
            let frame = match t.recv() {
                Ok(f) => {
                    consecutive_errors = 0;
                    f
                }
                Err(e) if e.is_retryable() && consecutive_errors < 8 => {
                    consecutive_errors += 1;
                    self.metrics.incr("cloud.recv_errors", 1);
                    continue;
                }
                Err(_) => return false, // peer closed or stream is dead
            };
            let shutdown = matches!(frame.kind, FrameKind::Shutdown);
            let reply = self.admit_and_handle(&frame);
            if t.send(&reply).is_err() {
                return shutdown;
            }
            if shutdown {
                return true;
            }
        }
    }

    /// Serve a single transport until the peer shuts down or errors.
    pub fn serve_transport(&self, t: &mut dyn Transport) -> Result<()> {
        self.serve_loop(t);
        Ok(())
    }

    /// Accept loop over TCP; one thread per connection. Returns when
    /// `stop` becomes true (checked between accepts) or after a client
    /// sends `Shutdown` (which also raises `stop`).
    pub fn serve_tcp(self: &Arc<Self>, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::transport(format!("nonblocking: {e}")))?;
        let mut workers = Vec::new();
        while !stop.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| Error::transport(format!("blocking: {e}")))?;
                    let node = Arc::clone(self);
                    let stop = Arc::clone(&stop);
                    workers.push(std::thread::spawn(move || {
                        let mut t = match TcpTransport::new(stream) {
                            Ok(t) => t,
                            Err(_) => return,
                        };
                        if node.serve_loop(&mut t) {
                            stop.store(true, Ordering::SeqCst);
                        }
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(Error::transport(format!("accept: {e}"))),
            }
        }
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_sheds_above_cap_and_guard_releases() {
        let adm = Admission::new(ServerLimits { max_inflight: 2 });
        let g1 = adm.try_admit(None).unwrap();
        let g2 = adm.try_admit(None).unwrap();
        let retry_after = adm.try_admit(None).err().unwrap();
        assert!(retry_after >= 1, "shed must carry a positive retry-after hint");
        drop(g1);
        let g3 = adm.try_admit(None).unwrap();
        drop(g2);
        drop(g3);
        assert_eq!(adm.inflight.load(Ordering::SeqCst), 0, "guards must release their slots");
    }

    #[test]
    fn admission_sheds_provably_unmeetable_deadline() {
        let adm = Admission::new(ServerLimits { max_inflight: 64 });
        // Teach the EWMA a 50 ms service time.
        adm.note_service(50_000);
        // 1 ms of budget cannot cover a 50 ms service: shed fast.
        let retry_after = adm.try_admit(Some(1)).err().unwrap();
        assert!(retry_after >= 1);
        assert_eq!(adm.inflight.load(Ordering::SeqCst), 0, "a shed must not leak its slot");
        // A generous deadline is admitted.
        let g = adm.try_admit(Some(10_000)).unwrap();
        drop(g);
        // No deadline header → only the cap applies.
        assert!(adm.try_admit(None).is_ok());
    }

    #[test]
    fn max_inflight_reconfigures_on_a_live_gate() {
        let adm = Arc::new(Admission::new(ServerLimits { max_inflight: 1 }));
        let g1 = adm.try_admit(None).unwrap();
        assert!(adm.try_admit(None).is_err(), "cap 1 is full");
        // Raise the cap without rebuilding the gate: the next admit wins.
        adm.knobs().set_max_inflight(2);
        let g2 = adm.try_admit(None).unwrap();
        drop(g1);
        drop(g2);
        // Lower it below the default and verify owned permits respect it.
        adm.knobs().set_max_inflight(1);
        let p = adm.try_admit_owned(None).unwrap();
        assert!(adm.try_admit_owned(None).is_err());
        drop(p);
        assert_eq!(adm.inflight(), 0, "owned permit must release its slot");
        assert!(adm.try_admit_owned(None).is_ok());
    }

    #[test]
    fn ewma_smooths_rather_than_tracks() {
        let adm = Admission::new(ServerLimits::default());
        adm.note_service(8_000);
        adm.note_service(80_000);
        let ewma = adm.ewma_service_us.load(Ordering::Relaxed);
        assert!(ewma > 8_000 && ewma < 80_000, "EWMA must smooth the spike, got {ewma}");
    }

    #[test]
    fn skew_check_refuses_mismatch_and_allows_legacy() {
        let infer = |version: Option<u64>| {
            let mut f = Frame::new(
                1,
                FrameKind::InferLm { model: "m".into(), payload: vec![1, 2, 3] },
            );
            f.model_version = version;
            f
        };
        // Versioned node, matching request → admitted.
        assert!(skew_reply(5, &infer(Some(5))).is_none());
        // Versioned node, stale request → refused with both versions.
        match skew_reply(5, &infer(Some(3))) {
            Some(FrameKind::VersionSkew { active: 5, offered: 3, .. }) => {}
            other => panic!("expected skew reply, got {other:?}"),
        }
        // Legacy (headerless) request is always admitted.
        assert!(skew_reply(5, &infer(None)).is_none());
        // Unversioned node ignores headers entirely.
        assert!(skew_reply(0, &infer(Some(9))).is_none());
    }
}
