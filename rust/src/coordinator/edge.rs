//! Edge node: head compute → engine compression → transmit.
//!
//! The edge owns a *reshape-plan cache*: Algorithm 1 runs once per
//! (tensor length, Q) pair and subsequent requests reuse the chosen `Ñ`
//! via `ReshapeStrategy::Fixed`, keeping the optimizer entirely off the
//! steady-state hot path (the paper's GPU pipeline assumes the same).
//! Compression itself runs on the shared [`Engine`]'s persistent worker
//! pool, so any number of edge nodes in one process fan lanes out onto
//! one machine-sized pool instead of each spawning scoped threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::channel::OutageChannel;
use crate::engine::{Engine, EngineHandle};
use crate::error::{Error, Result};
use crate::pipeline::{CompressStats, PipelineConfig, StreamLayout};
use crate::quant::{self, QuantParams};
use crate::runtime::{LmSplitExec, VisionSplitExec};
use crate::telemetry::{LatencyBreakdown, Registry};
use crate::tensor::{Dtype, TensorRef};
use crate::util::timer::Stopwatch;

use super::protocol::{Frame, FrameKind};
use super::transport::Transport;

pub use crate::engine::PlanCache;

/// Edge pipeline configuration.
#[derive(Debug, Clone)]
pub struct EdgeConfig {
    /// Manifest model name.
    pub model: String,
    /// Split layer (vision; ignored for LM).
    pub sl: usize,
    /// Artifact batch size.
    pub batch: usize,
    /// AIQ bit-width.
    pub q: u8,
    /// rANS lanes.
    pub lanes: usize,
    /// Thread the rANS lanes.
    pub parallel: bool,
    /// Per-lane stream layout (v1 scalar lanes by default; see
    /// [`StreamLayout`]). The cloud side needs no matching knob — the
    /// stream is self-describing.
    pub layout: StreamLayout,
    /// Element type of the features this edge ships
    /// ([`Dtype::F32`] default). The feature-level entry points
    /// ([`LmEdgeNode::infer_features`], [`LmEdgeNode::infer_raw_features`])
    /// validate their tensors against it; containers carry the tag on
    /// the wire, so the cloud side again needs no knob.
    pub dtype: Dtype,
}

impl EdgeConfig {
    /// Paper-default edge config for a model route.
    pub fn paper(model: &str, sl: usize, batch: usize, q: u8) -> Self {
        EdgeConfig {
            model: model.into(),
            sl,
            batch,
            q,
            lanes: 8,
            parallel: crate::pipeline::codec::default_parallelism(),
            layout: StreamLayout::V1,
            dtype: Dtype::F32,
        }
    }

    /// This configuration shipping `dtype` features (the Llama2-style
    /// LM path uses `bf16`).
    pub fn with_dtype(self, dtype: Dtype) -> Self {
        EdgeConfig { dtype, ..self }
    }
}

/// Result of one edge-driven inference.
#[derive(Debug, Clone)]
pub struct InferOutcome {
    /// Tail logits (batch × classes, or choices × seq × vocab for LM).
    pub logits: Vec<f32>,
    /// The four-factor latency breakdown (+ queue time when batched).
    pub breakdown: LatencyBreakdown,
    /// Compression statistics.
    pub stats: Option<CompressStats>,
    /// Bytes that crossed the (simulated) wireless link.
    pub payload_bytes: usize,
}

fn expect_logits(frame: Frame) -> Result<(Vec<f32>, f32, f32)> {
    match frame.kind {
        FrameKind::Logits { data, decode_ms, compute_ms } => Ok((data, decode_ms, compute_ms)),
        FrameKind::ServerError { message } => Err(Error::protocol(format!("server: {message}"))),
        other => Err(Error::protocol(format!("unexpected reply {other:?}"))),
    }
}

/// Vision edge node bound to one transport.
pub struct EdgeNode<T: Transport> {
    /// Configuration.
    pub cfg: EdgeConfig,
    exec: Arc<VisionSplitExec>,
    transport: Mutex<T>,
    engine: EngineHandle,
    plan_cache: PlanCache,
    channel: OutageChannel,
    metrics: Arc<Registry>,
    next_id: AtomicU64,
}

impl<T: Transport> EdgeNode<T> {
    /// Build an edge node over an established transport, compressing on
    /// the process-wide shared engine pool (resolved lazily).
    pub fn new(exec: Arc<VisionSplitExec>, transport: T, cfg: EdgeConfig) -> Self {
        EdgeNode {
            cfg,
            exec,
            transport: Mutex::new(transport),
            engine: EngineHandle::shared(),
            plan_cache: PlanCache::default(),
            channel: OutageChannel::paper_default(),
            metrics: Arc::new(Registry::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Compress on a dedicated engine instead of the shared one. Lane
    /// *threading* stays governed by `cfg.parallel` (explicit caller
    /// config) — set it to match the new engine's pool if desired.
    pub fn with_engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = EngineHandle::dedicated(engine);
        self
    }

    /// Override the channel model.
    pub fn with_channel(mut self, channel: OutageChannel) -> Self {
        self.channel = channel;
        self
    }

    /// Node metrics.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Reshape-plan cache statistics.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        self.plan_cache.stats()
    }

    fn roundtrip(&self, kind: FrameKind) -> Result<Frame> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut t = self.transport.lock().unwrap();
        t.send(&Frame { request_id: id, kind })?;
        let reply = t.recv()?;
        if reply.request_id != id {
            return Err(Error::protocol(format!(
                "reply id {} for request {id}",
                reply.request_id
            )));
        }
        Ok(reply)
    }

    /// Compressed inference: head → AIQ symbols → CSR+rANS → cloud.
    pub fn infer(&self, images: &[f32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let (symbols, params) = self.exec.run_head(images, self.cfg.q)?;
        let reshape = self.plan_cache.strategy(&symbols, &params)?;
        let pcfg = PipelineConfig {
            q: self.cfg.q,
            lanes: self.cfg.lanes,
            parallel: self.cfg.parallel,
            reshape,
            layout: self.cfg.layout,
        };
        let (container, stats) =
            self.engine.get().compress_quantized(&symbols, params, &pcfg)?;
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = container.len();
        let transfer_ms = self.channel.comm_latency_ms(payload_bytes);

        let reply = self.roundtrip(FrameKind::InferVision {
            model: self.cfg.model.clone(),
            sl: self.cfg.sl,
            batch: self.cfg.batch,
            payload: container,
        })?;
        let (logits, decode_ms, compute_ms) = expect_logits(reply)?;
        let breakdown = LatencyBreakdown {
            queue_ms: 0.0,
            encode_ms,
            transfer_ms,
            decode_ms: decode_ms as f64,
            compute_ms: compute_ms as f64,
        };
        self.metrics.record_breakdown("edge", &breakdown);
        self.metrics.incr("edge.requests", 1);
        self.metrics.incr("edge.bytes_sent", payload_bytes as u64);
        Ok(InferOutcome { logits, breakdown, stats: Some(stats), payload_bytes })
    }

    /// Uncompressed baseline inference (E-1 shape): raw float IF over
    /// the link.
    pub fn infer_raw(&self, images: &[f32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let feat = self.exec.run_head_raw(images)?;
        let mut payload = Vec::with_capacity(feat.len() * 4);
        for &x in &feat {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = payload.len();
        let transfer_ms = self.channel.comm_latency_ms(payload_bytes);
        let reply = self.roundtrip(FrameKind::InferVisionRaw {
            model: self.cfg.model.clone(),
            sl: self.cfg.sl,
            batch: self.cfg.batch,
            dtype: Dtype::F32,
            payload,
        })?;
        let (logits, decode_ms, compute_ms) = expect_logits(reply)?;
        let breakdown = LatencyBreakdown {
            queue_ms: 0.0,
            encode_ms,
            transfer_ms,
            decode_ms: decode_ms as f64,
            compute_ms: compute_ms as f64,
        };
        self.metrics.record_breakdown("edge_raw", &breakdown);
        Ok(InferOutcome { logits, breakdown, stats: None, payload_bytes })
    }

    /// Liveness check.
    pub fn ping(&self) -> Result<()> {
        match self.roundtrip(FrameKind::Ping)?.kind {
            FrameKind::Pong => Ok(()),
            other => Err(Error::protocol(format!("unexpected {other:?}"))),
        }
    }

    /// Ask the cloud node to shut down its accept loop.
    pub fn shutdown_server(&self) -> Result<()> {
        let _ = self.roundtrip(FrameKind::Shutdown)?;
        Ok(())
    }
}

/// LM edge node bound to one transport.
pub struct LmEdgeNode<T: Transport> {
    /// Configuration (sl/batch come from the manifest entry).
    pub cfg: EdgeConfig,
    exec: Arc<LmSplitExec>,
    transport: Mutex<T>,
    engine: EngineHandle,
    plan_cache: PlanCache,
    channel: OutageChannel,
    next_id: AtomicU64,
}

impl<T: Transport> LmEdgeNode<T> {
    /// Build an LM edge node on the shared engine pool (resolved lazily).
    pub fn new(exec: Arc<LmSplitExec>, transport: T, cfg: EdgeConfig) -> Self {
        LmEdgeNode {
            cfg,
            exec,
            transport: Mutex::new(transport),
            engine: EngineHandle::shared(),
            plan_cache: PlanCache::default(),
            channel: OutageChannel::paper_default(),
            next_id: AtomicU64::new(1),
        }
    }

    /// Compress on a dedicated engine instead of the shared one. Lane
    /// *threading* stays governed by `cfg.parallel` (explicit caller
    /// config) — set it to match the new engine's pool if desired.
    pub fn with_engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = EngineHandle::dedicated(engine);
        self
    }

    /// Override the channel model.
    pub fn with_channel(mut self, channel: OutageChannel) -> Self {
        self.channel = channel;
        self
    }

    fn roundtrip(&self, kind: FrameKind) -> Result<Frame> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut t = self.transport.lock().unwrap();
        t.send(&Frame { request_id: id, kind })?;
        let reply = t.recv()?;
        if reply.request_id != id {
            return Err(Error::protocol("reply id mismatch"));
        }
        Ok(reply)
    }

    /// Reject tensors whose dtype disagrees with [`EdgeConfig::dtype`]
    /// (shared by both feature-level entry points).
    fn check_dtype(&self, features: &TensorRef<'_>) -> Result<()> {
        if features.dtype() != self.cfg.dtype {
            return Err(Error::invalid(format!(
                "edge configured for {} features, got {}",
                self.cfg.dtype,
                features.dtype()
            )));
        }
        Ok(())
    }

    /// Ship one request frame (whose link payload is `payload_bytes`
    /// long) and fold the logits reply into an [`InferOutcome`] — the
    /// single definition of the outcome/breakdown assembly all four
    /// inference entry points share.
    fn ship(
        &self,
        kind: FrameKind,
        encode_ms: f64,
        payload_bytes: usize,
        stats: Option<CompressStats>,
    ) -> Result<InferOutcome> {
        let transfer_ms = self.channel.comm_latency_ms(payload_bytes);
        let reply = self.roundtrip(kind)?;
        let (logits, decode_ms, compute_ms) = expect_logits(reply)?;
        Ok(InferOutcome {
            logits,
            breakdown: LatencyBreakdown {
                queue_ms: 0.0,
                encode_ms,
                transfer_ms,
                decode_ms: decode_ms as f64,
                compute_ms: compute_ms as f64,
            },
            stats,
            payload_bytes,
        })
    }

    /// Compress `symbols` (originating from a `dtype` tensor) through
    /// the plan cache + engine and ship the container. `sw` was started
    /// before the head/quantize step so `encode_ms` covers it.
    fn compress_and_ship(
        &self,
        symbols: &[u16],
        params: QuantParams,
        dtype: Dtype,
        sw: Stopwatch,
    ) -> Result<InferOutcome> {
        let reshape = self.plan_cache.strategy(symbols, &params)?;
        let pcfg = PipelineConfig {
            q: self.cfg.q,
            lanes: self.cfg.lanes,
            parallel: self.cfg.parallel,
            reshape,
            layout: self.cfg.layout,
        };
        let (container, stats) =
            self.engine.get().compress_quantized_dtype(symbols, params, dtype, &pcfg)?;
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = container.len();
        self.ship(
            FrameKind::InferLm { model: self.cfg.model.clone(), payload: container },
            encode_ms,
            payload_bytes,
            Some(stats),
        )
    }

    /// Compressed LM inference over one tokenized choice batch (the
    /// head artifact emits f32-derived AIQ symbols).
    pub fn infer(&self, tokens: &[i32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let (symbols, params) = self.exec.run_head(tokens, self.cfg.q)?;
        self.compress_and_ship(&symbols, params, Dtype::F32, sw)
    }

    /// Compressed LM inference over a caller-provided feature tensor —
    /// the dtype-generic edge entry point for half-precision (Llama2-
    /// style) hidden states. The borrowed tensor is quantized with
    /// conversion fused into the load
    /// ([`quant::fit_and_quantize_tensor`]): **no intermediate `f32`
    /// `Vec` is allocated on the quantize path for any dtype**. The
    /// emitted container carries the tensor's dtype tag, which the
    /// cloud decoder sniffs. Errors when the tensor's dtype disagrees
    /// with [`EdgeConfig::dtype`].
    pub fn infer_features(&self, features: TensorRef<'_>) -> Result<InferOutcome> {
        self.check_dtype(&features)?;
        let sw = Stopwatch::new();
        let (params, symbols) = quant::fit_and_quantize_tensor(self.cfg.q, &features)?;
        self.compress_and_ship(&symbols, params, features.dtype(), sw)
    }

    /// Uncompressed baseline over a caller-provided feature tensor: the
    /// raw little-endian bytes of the tensor's dtype cross the link
    /// (half-precision halves the baseline's wire bytes). Errors when
    /// the tensor's dtype disagrees with [`EdgeConfig::dtype`], so the
    /// baseline measures the same deployment the compressed path does.
    pub fn infer_raw_features(&self, features: TensorRef<'_>) -> Result<InferOutcome> {
        self.check_dtype(&features)?;
        let sw = Stopwatch::new();
        let payload = features.to_le_bytes();
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = payload.len();
        self.ship(
            FrameKind::InferLmRaw {
                model: self.cfg.model.clone(),
                dtype: features.dtype(),
                payload,
            },
            encode_ms,
            payload_bytes,
            None,
        )
    }

    /// Uncompressed baseline LM inference (f32 hidden states from the
    /// head artifact; `encode_ms` covers head compute + serialization,
    /// matching the compressed path's head + pipeline timing).
    pub fn infer_raw(&self, tokens: &[i32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let hidden = self.exec.run_head_raw(tokens)?;
        let payload = TensorRef::from_f32(&hidden).to_le_bytes();
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = payload.len();
        self.ship(
            FrameKind::InferLmRaw {
                model: self.cfg.model.clone(),
                dtype: Dtype::F32,
                payload,
            },
            encode_ms,
            payload_bytes,
            None,
        )
    }
}
