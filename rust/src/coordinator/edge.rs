//! Edge node: head compute → engine compression → transmit.
//!
//! The edge owns a *reshape-plan cache*: Algorithm 1 runs once per
//! (tensor length, Q) pair and subsequent requests reuse the chosen `Ñ`
//! via `ReshapeStrategy::Fixed`, keeping the optimizer entirely off the
//! steady-state hot path (the paper's GPU pipeline assumes the same).
//! Compression itself runs on the shared [`Engine`]'s persistent worker
//! pool, so any number of edge nodes in one process fan lanes out onto
//! one machine-sized pool instead of each spawning scoped threads.
//!
//! All round trips go through the [`Session`] layer: request IDs and
//! deadlines ride the frame header, retryable failures back off and
//! resend, a dead connection is redialed through the connector
//! installed with `with_reconnect`, and a cloud-side `Busy` shed
//! surfaces as a clean [`Error::Rejected`]. The vision node can
//! additionally carry a [`DegradePolicy`]: consecutive retryable
//! failures step the AIQ bit-width Q down toward the policy floor
//! (fewer wire bytes → fewer link-budget failures), with an optional
//! raw-frame fallback, and a run of successes climbs back up.
//!
//! Registry-deployed edges additionally pin their requests to a
//! `model_version` (`with_model_version`) and install a resync hook
//! (`with_resync`): a cloud that hot-swapped to a newer deployment
//! answers `VersionSkew`, the hook re-fetches from the registry, and
//! the request retries at the server's version — features are never
//! silently decoded against a mismatched tail.

use std::sync::{Arc, Mutex};

use crate::channel::OutageChannel;
use crate::engine::{Engine, EngineHandle};
use crate::error::{Error, Result};
use crate::pipeline::{CompressStats, PipelineConfig, StreamLayout};
use crate::quant::{self, QuantParams};
use crate::runtime::{LmSplitExec, VisionSplitExec};
use crate::telemetry::{LatencyBreakdown, Registry};
use crate::tensor::{Dtype, TensorRef};
use crate::util::timer::Stopwatch;

use super::protocol::{Frame, FrameKind};
use super::session::{DegradeEvent, DegradePolicy, DegradeState, Session, SessionConfig};
use super::transport::Transport;

pub use crate::engine::PlanCache;

/// Session defaults for an edge node constructed without an explicit
/// policy: no end-to-end deadline (so no deadline header is attached
/// and in-process round trips behave like the old blocking path), a
/// generous per-try budget, and a small retry allowance as the safety
/// net. Deployments wanting real deadlines pass their own
/// [`SessionConfig`] via `with_session_config`.
fn default_session_config() -> SessionConfig {
    SessionConfig { deadline_ms: 0, try_timeout_ms: 30_000, ..SessionConfig::default() }
}

/// Edge pipeline configuration.
#[derive(Debug, Clone)]
pub struct EdgeConfig {
    /// Manifest model name.
    pub model: String,
    /// Split layer (vision; ignored for LM).
    pub sl: usize,
    /// Artifact batch size.
    pub batch: usize,
    /// AIQ bit-width.
    pub q: u8,
    /// rANS lanes.
    pub lanes: usize,
    /// Thread the rANS lanes.
    pub parallel: bool,
    /// Per-lane stream layout (v1 scalar lanes by default; see
    /// [`StreamLayout`]). The cloud side needs no matching knob — the
    /// stream is self-describing.
    pub layout: StreamLayout,
    /// Element type of the features this edge ships
    /// ([`Dtype::F32`] default). The feature-level entry points
    /// ([`LmEdgeNode::infer_features`], [`LmEdgeNode::infer_raw_features`])
    /// validate their tensors against it; containers carry the tag on
    /// the wire, so the cloud side again needs no knob.
    pub dtype: Dtype,
}

impl EdgeConfig {
    /// Paper-default edge config for a model route.
    pub fn paper(model: &str, sl: usize, batch: usize, q: u8) -> Self {
        EdgeConfig {
            model: model.into(),
            sl,
            batch,
            q,
            lanes: 8,
            parallel: crate::pipeline::codec::default_parallelism(),
            layout: StreamLayout::V1,
            dtype: Dtype::F32,
        }
    }

    /// This configuration shipping `dtype` features (the Llama2-style
    /// LM path uses `bf16`).
    pub fn with_dtype(self, dtype: Dtype) -> Self {
        EdgeConfig { dtype, ..self }
    }

    /// The registry-manifest view of this serving point: the codec
    /// parameters a [`crate::runtime::registry::RegistryManifest`] binds
    /// (and a hot-swap smoke check replays) for this edge.
    pub fn deploy_params(&self) -> crate::runtime::registry::DeployParams {
        crate::runtime::registry::DeployParams {
            sl: self.sl,
            batch: self.batch,
            q: self.q,
            lanes: self.lanes,
            states: match self.layout {
                StreamLayout::V1 => 1,
                StreamLayout::MultiState(n) => n,
            },
            dtype: self.dtype.to_string(),
        }
    }
}

/// Result of one edge-driven inference.
#[derive(Debug, Clone)]
pub struct InferOutcome {
    /// Tail logits (batch × classes, or choices × seq × vocab for LM).
    pub logits: Vec<f32>,
    /// The four-factor latency breakdown (+ queue time when batched).
    pub breakdown: LatencyBreakdown,
    /// Compression statistics.
    pub stats: Option<CompressStats>,
    /// Bytes that crossed the (simulated) wireless link.
    pub payload_bytes: usize,
}

fn expect_logits(frame: Frame) -> Result<(Vec<f32>, f32, f32)> {
    match frame.kind {
        FrameKind::Logits { data, decode_ms, compute_ms } => Ok((data, decode_ms, compute_ms)),
        FrameKind::ServerError { message } => Err(Error::protocol(format!("server: {message}"))),
        // The session layer normally converts sheds and skews to their
        // typed errors before they get here; these arms cover direct
        // `handle` callers.
        FrameKind::Busy { retry_after_ms, message } => {
            Err(Error::rejected(retry_after_ms as u64, message))
        }
        FrameKind::VersionSkew { active, offered, message } => {
            Err(Error::version_skew(active, offered, message))
        }
        other => Err(Error::protocol(format!("unexpected reply {other:?}"))),
    }
}

/// Vision edge node bound to one transport (through the session layer).
pub struct EdgeNode<T: Transport> {
    /// Configuration.
    pub cfg: EdgeConfig,
    exec: Arc<VisionSplitExec>,
    session: Mutex<Session<T>>,
    engine: EngineHandle,
    plan_cache: PlanCache,
    channel: OutageChannel,
    metrics: Arc<Registry>,
    degrade: Option<Mutex<DegradeState>>,
}

impl<T: Transport> EdgeNode<T> {
    /// Build an edge node over an established transport, compressing on
    /// the process-wide shared engine pool (resolved lazily).
    pub fn new(exec: Arc<VisionSplitExec>, transport: T, cfg: EdgeConfig) -> Self {
        let metrics = Arc::new(Registry::new());
        let session =
            Session::new(transport, default_session_config()).with_metrics(Arc::clone(&metrics));
        EdgeNode {
            cfg,
            exec,
            session: Mutex::new(session),
            engine: EngineHandle::shared(),
            plan_cache: PlanCache::default(),
            channel: OutageChannel::paper_default(),
            metrics,
            degrade: None,
        }
    }

    /// Compress on a dedicated engine instead of the shared one. Lane
    /// *threading* stays governed by `cfg.parallel` (explicit caller
    /// config) — set it to match the new engine's pool if desired.
    pub fn with_engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = EngineHandle::dedicated(engine);
        self
    }

    /// Override the channel model.
    pub fn with_channel(mut self, channel: OutageChannel) -> Self {
        self.channel = channel;
        self
    }

    /// Replace the session retry/deadline/heartbeat policy.
    pub fn with_session_config(self, scfg: SessionConfig) -> Self {
        self.session.lock().unwrap().set_config(scfg);
        self
    }

    /// Install a dialer the session uses to replace a dead transport.
    pub fn with_reconnect(mut self, connector: Box<dyn FnMut() -> Result<T> + Send>) -> Self {
        let session = self.session.into_inner().unwrap().with_connector(connector);
        self.session = Mutex::new(session);
        self
    }

    /// Pin requests to a registry `model_version` (the tag-15 header);
    /// a cloud serving a different version answers `VersionSkew`.
    pub fn with_model_version(mut self, model_version: u64) -> Self {
        let session = self.session.into_inner().unwrap().with_model_version(model_version);
        self.session = Mutex::new(session);
        self
    }

    /// Install the skew-recovery hook: on a `VersionSkew` reply the
    /// session re-fetches through it (once per request) and retries at
    /// the server's version instead of failing the call.
    pub fn with_resync(mut self, resync: Box<dyn FnMut(u64) -> Result<u64> + Send>) -> Self {
        let session = self.session.into_inner().unwrap().with_resync(resync);
        self.session = Mutex::new(session);
        self
    }

    /// The model version requests are currently pinned to, if any.
    pub fn model_version(&self) -> Option<u64> {
        self.session.lock().unwrap().model_version()
    }

    /// Enable graceful degradation: after sustained retryable failures
    /// the node encodes with a smaller Q (down to the policy floor, then
    /// optionally raw frames); sustained successes recover toward
    /// `cfg.q`.
    pub fn with_degrade(mut self, policy: DegradePolicy) -> Self {
        self.degrade = Some(Mutex::new(DegradeState::new(policy, self.cfg.q)));
        self
    }

    /// Node metrics.
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Reshape-plan cache statistics.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        self.plan_cache.stats()
    }

    /// The Q the next compressed request will encode with (differs from
    /// `cfg.q` only while degraded).
    pub fn effective_q(&self) -> u8 {
        match &self.degrade {
            Some(d) => d.lock().unwrap().effective_q(),
            None => self.cfg.q,
        }
    }

    /// Current operating point under the degradation policy.
    fn operating_point(&self) -> (u8, bool) {
        match &self.degrade {
            Some(d) => {
                let st = d.lock().unwrap();
                (st.effective_q(), st.raw_mode())
            }
            None => (self.cfg.q, false),
        }
    }

    /// Feed one request outcome to the degradation state machine and
    /// count the transitions. Fatal errors don't advance it: a resend at
    /// a different Q cannot fix a corrupt artifact or a bad argument.
    fn note_outcome<R>(&self, result: Result<R>) -> Result<R> {
        let Some(d) = &self.degrade else {
            return result;
        };
        let event = {
            let mut st = d.lock().unwrap();
            match &result {
                Ok(_) => st.on_success(),
                Err(e) if e.is_retryable() => st.on_retryable_failure(),
                Err(_) => DegradeEvent::None,
            }
        };
        match event {
            DegradeEvent::SteppedDown(_) => self.metrics.incr("edge.degrade_total", 1),
            DegradeEvent::RawFallback => {
                self.metrics.incr("edge.degrade_total", 1);
                self.metrics.incr("edge.raw_fallback_total", 1);
            }
            DegradeEvent::Recovered(_) => self.metrics.incr("edge.recover_total", 1),
            DegradeEvent::None => {}
        }
        result
    }

    fn roundtrip(&self, kind: FrameKind) -> Result<Frame> {
        self.session.lock().unwrap().call(kind)
    }

    /// Compressed inference: head → AIQ symbols → CSR+rANS → cloud.
    ///
    /// Under a degradation policy the encode Q may sit below `cfg.q`,
    /// and in raw-fallback mode the request ships uncompressed.
    pub fn infer(&self, images: &[f32]) -> Result<InferOutcome> {
        let (q, raw) = self.operating_point();
        let result =
            if raw { self.infer_raw_inner(images) } else { self.infer_compressed(images, q) };
        self.note_outcome(result)
    }

    fn infer_compressed(&self, images: &[f32], q: u8) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let (symbols, params) = self.exec.run_head(images, q)?;
        let reshape = self.plan_cache.strategy(&symbols, &params)?;
        let pcfg = PipelineConfig {
            q,
            lanes: self.cfg.lanes,
            parallel: self.cfg.parallel,
            reshape,
            layout: self.cfg.layout,
        };
        let (container, stats) =
            self.engine.get().compress_quantized(&symbols, params, &pcfg)?;
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = container.len();
        let transfer_ms = self.channel.comm_latency_ms(payload_bytes);

        let reply = self.roundtrip(FrameKind::InferVision {
            model: self.cfg.model.clone(),
            sl: self.cfg.sl,
            batch: self.cfg.batch,
            payload: container,
        })?;
        let (logits, decode_ms, compute_ms) = expect_logits(reply)?;
        let breakdown = LatencyBreakdown {
            queue_ms: 0.0,
            encode_ms,
            transfer_ms,
            decode_ms: decode_ms as f64,
            compute_ms: compute_ms as f64,
        };
        self.metrics.record_breakdown("edge", &breakdown);
        self.metrics.incr("edge.requests", 1);
        self.metrics.incr("edge.bytes_sent", payload_bytes as u64);
        Ok(InferOutcome { logits, breakdown, stats: Some(stats), payload_bytes })
    }

    /// Uncompressed baseline inference (E-1 shape): raw float IF over
    /// the link.
    pub fn infer_raw(&self, images: &[f32]) -> Result<InferOutcome> {
        self.infer_raw_inner(images)
    }

    fn infer_raw_inner(&self, images: &[f32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let feat = self.exec.run_head_raw(images)?;
        let mut payload = Vec::with_capacity(feat.len() * 4);
        for &x in &feat {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = payload.len();
        let transfer_ms = self.channel.comm_latency_ms(payload_bytes);
        let reply = self.roundtrip(FrameKind::InferVisionRaw {
            model: self.cfg.model.clone(),
            sl: self.cfg.sl,
            batch: self.cfg.batch,
            dtype: Dtype::F32,
            payload,
        })?;
        let (logits, decode_ms, compute_ms) = expect_logits(reply)?;
        let breakdown = LatencyBreakdown {
            queue_ms: 0.0,
            encode_ms,
            transfer_ms,
            decode_ms: decode_ms as f64,
            compute_ms: compute_ms as f64,
        };
        self.metrics.record_breakdown("edge_raw", &breakdown);
        Ok(InferOutcome { logits, breakdown, stats: None, payload_bytes })
    }

    /// Liveness check.
    pub fn ping(&self) -> Result<()> {
        match self.roundtrip(FrameKind::Ping)?.kind {
            FrameKind::Pong => Ok(()),
            other => Err(Error::protocol(format!("unexpected {other:?}"))),
        }
    }

    /// Ask the cloud node to shut down its accept loop.
    pub fn shutdown_server(&self) -> Result<()> {
        let _ = self.roundtrip(FrameKind::Shutdown)?;
        Ok(())
    }
}

/// LM edge node bound to one transport (through the session layer).
pub struct LmEdgeNode<T: Transport> {
    /// Configuration (sl/batch come from the manifest entry).
    pub cfg: EdgeConfig,
    exec: Arc<LmSplitExec>,
    session: Mutex<Session<T>>,
    engine: EngineHandle,
    plan_cache: PlanCache,
    channel: OutageChannel,
    metrics: Arc<Registry>,
}

impl<T: Transport> LmEdgeNode<T> {
    /// Build an LM edge node on the shared engine pool (resolved lazily).
    pub fn new(exec: Arc<LmSplitExec>, transport: T, cfg: EdgeConfig) -> Self {
        let metrics = Arc::new(Registry::new());
        let session =
            Session::new(transport, default_session_config()).with_metrics(Arc::clone(&metrics));
        LmEdgeNode {
            cfg,
            exec,
            session: Mutex::new(session),
            engine: EngineHandle::shared(),
            plan_cache: PlanCache::default(),
            channel: OutageChannel::paper_default(),
            metrics,
        }
    }

    /// Compress on a dedicated engine instead of the shared one. Lane
    /// *threading* stays governed by `cfg.parallel` (explicit caller
    /// config) — set it to match the new engine's pool if desired.
    pub fn with_engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = EngineHandle::dedicated(engine);
        self
    }

    /// Override the channel model.
    pub fn with_channel(mut self, channel: OutageChannel) -> Self {
        self.channel = channel;
        self
    }

    /// Replace the session retry/deadline/heartbeat policy.
    pub fn with_session_config(self, scfg: SessionConfig) -> Self {
        self.session.lock().unwrap().set_config(scfg);
        self
    }

    /// Install a dialer the session uses to replace a dead transport.
    pub fn with_reconnect(mut self, connector: Box<dyn FnMut() -> Result<T> + Send>) -> Self {
        let session = self.session.into_inner().unwrap().with_connector(connector);
        self.session = Mutex::new(session);
        self
    }

    /// Pin requests to a registry `model_version` (the tag-15 header);
    /// a cloud serving a different version answers `VersionSkew`.
    pub fn with_model_version(mut self, model_version: u64) -> Self {
        let session = self.session.into_inner().unwrap().with_model_version(model_version);
        self.session = Mutex::new(session);
        self
    }

    /// Install the skew-recovery hook: on a `VersionSkew` reply the
    /// session re-fetches through it (once per request) and retries at
    /// the server's version instead of failing the call.
    pub fn with_resync(mut self, resync: Box<dyn FnMut(u64) -> Result<u64> + Send>) -> Self {
        let session = self.session.into_inner().unwrap().with_resync(resync);
        self.session = Mutex::new(session);
        self
    }

    /// The model version requests are currently pinned to, if any.
    pub fn model_version(&self) -> Option<u64> {
        self.session.lock().unwrap().model_version()
    }

    /// Node metrics (session robustness counters live here too).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    fn roundtrip(&self, kind: FrameKind) -> Result<Frame> {
        self.session.lock().unwrap().call(kind)
    }

    /// Reject tensors whose dtype disagrees with [`EdgeConfig::dtype`]
    /// (shared by both feature-level entry points).
    fn check_dtype(&self, features: &TensorRef<'_>) -> Result<()> {
        if features.dtype() != self.cfg.dtype {
            return Err(Error::invalid(format!(
                "edge configured for {} features, got {}",
                self.cfg.dtype,
                features.dtype()
            )));
        }
        Ok(())
    }

    /// Ship one request frame (whose link payload is `payload_bytes`
    /// long) and fold the logits reply into an [`InferOutcome`] — the
    /// single definition of the outcome/breakdown assembly all four
    /// inference entry points share.
    fn ship(
        &self,
        kind: FrameKind,
        encode_ms: f64,
        payload_bytes: usize,
        stats: Option<CompressStats>,
    ) -> Result<InferOutcome> {
        let transfer_ms = self.channel.comm_latency_ms(payload_bytes);
        let reply = self.roundtrip(kind)?;
        let (logits, decode_ms, compute_ms) = expect_logits(reply)?;
        Ok(InferOutcome {
            logits,
            breakdown: LatencyBreakdown {
                queue_ms: 0.0,
                encode_ms,
                transfer_ms,
                decode_ms: decode_ms as f64,
                compute_ms: compute_ms as f64,
            },
            stats,
            payload_bytes,
        })
    }

    /// Compress `symbols` (originating from a `dtype` tensor) through
    /// the plan cache + engine and ship the container. `sw` was started
    /// before the head/quantize step so `encode_ms` covers it.
    fn compress_and_ship(
        &self,
        symbols: &[u16],
        params: QuantParams,
        dtype: Dtype,
        sw: Stopwatch,
    ) -> Result<InferOutcome> {
        let reshape = self.plan_cache.strategy(symbols, &params)?;
        let pcfg = PipelineConfig {
            q: self.cfg.q,
            lanes: self.cfg.lanes,
            parallel: self.cfg.parallel,
            reshape,
            layout: self.cfg.layout,
        };
        let (container, stats) =
            self.engine.get().compress_quantized_dtype(symbols, params, dtype, &pcfg)?;
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = container.len();
        self.ship(
            FrameKind::InferLm { model: self.cfg.model.clone(), payload: container },
            encode_ms,
            payload_bytes,
            Some(stats),
        )
    }

    /// Compressed LM inference over one tokenized choice batch (the
    /// head artifact emits f32-derived AIQ symbols).
    pub fn infer(&self, tokens: &[i32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let (symbols, params) = self.exec.run_head(tokens, self.cfg.q)?;
        self.compress_and_ship(&symbols, params, Dtype::F32, sw)
    }

    /// Compressed LM inference over a caller-provided feature tensor —
    /// the dtype-generic edge entry point for half-precision (Llama2-
    /// style) hidden states. The borrowed tensor is quantized with
    /// conversion fused into the load
    /// ([`quant::fit_and_quantize_tensor`]): **no intermediate `f32`
    /// `Vec` is allocated on the quantize path for any dtype**. The
    /// emitted container carries the tensor's dtype tag, which the
    /// cloud decoder sniffs. Errors when the tensor's dtype disagrees
    /// with [`EdgeConfig::dtype`].
    pub fn infer_features(&self, features: TensorRef<'_>) -> Result<InferOutcome> {
        self.check_dtype(&features)?;
        let sw = Stopwatch::new();
        let (params, symbols) = quant::fit_and_quantize_tensor(self.cfg.q, &features)?;
        self.compress_and_ship(&symbols, params, features.dtype(), sw)
    }

    /// Uncompressed baseline over a caller-provided feature tensor: the
    /// raw little-endian bytes of the tensor's dtype cross the link
    /// (half-precision halves the baseline's wire bytes). Errors when
    /// the tensor's dtype disagrees with [`EdgeConfig::dtype`], so the
    /// baseline measures the same deployment the compressed path does.
    pub fn infer_raw_features(&self, features: TensorRef<'_>) -> Result<InferOutcome> {
        self.check_dtype(&features)?;
        let sw = Stopwatch::new();
        let payload = features.to_le_bytes();
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = payload.len();
        self.ship(
            FrameKind::InferLmRaw {
                model: self.cfg.model.clone(),
                dtype: features.dtype(),
                payload,
            },
            encode_ms,
            payload_bytes,
            None,
        )
    }

    /// Uncompressed baseline LM inference (f32 hidden states from the
    /// head artifact; `encode_ms` covers head compute + serialization,
    /// matching the compressed path's head + pipeline timing).
    pub fn infer_raw(&self, tokens: &[i32]) -> Result<InferOutcome> {
        let sw = Stopwatch::new();
        let hidden = self.exec.run_head_raw(tokens)?;
        let payload = TensorRef::from_f32(&hidden).to_le_bytes();
        let encode_ms = sw.elapsed_ms();
        let payload_bytes = payload.len();
        self.ship(
            FrameKind::InferLmRaw {
                model: self.cfg.model.clone(),
                dtype: Dtype::F32,
                payload,
            },
            encode_ms,
            payload_bytes,
            None,
        )
    }
}
