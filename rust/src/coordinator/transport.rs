//! Duplex frame transports.
//!
//! * [`TcpTransport`] — framed over `std::net::TcpStream` (the real
//!   deployment shape; the E2E example runs edge and cloud over
//!   loopback TCP).
//! * [`InProcTransport`] — mpsc channel pair for single-process tests
//!   and benches.
//! * [`SimulatedLink`] — wraps any transport with the ε-outage channel
//!   model: accounts (and optionally sleeps) the wireless latency for
//!   each payload and can inject outage-driven retransmissions.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::channel::OutageChannel;
use crate::error::{Error, Result};
use crate::util::prng::Rng;

use super::protocol::{Frame, MAX_FRAME};

/// A reliable, ordered duplex frame link.
pub trait Transport: Send {
    /// Send one frame.
    fn send(&mut self, frame: &Frame) -> Result<()>;
    /// Block for the next frame.
    fn recv(&mut self) -> Result<Frame>;
}

// ------------------------------------------------------------------ tcp

/// Frame transport over a TCP stream.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Wrap an accepted/connected stream (sets TCP_NODELAY).
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream
            .set_nodelay(true)
            .map_err(|e| Error::transport(format!("set_nodelay: {e}")))?;
        Ok(TcpTransport { stream })
    }
}

/// Connect to a cloud node at `addr`.
pub fn connect_tcp(addr: &str) -> Result<TcpTransport> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::transport(format!("connect {addr}: {e}")))?;
    TcpTransport::new(stream)
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let wire = frame.to_wire();
        self.stream
            .write_all(&wire)
            .map_err(|e| Error::transport(format!("send: {e}")))
    }

    fn recv(&mut self) -> Result<Frame> {
        let mut len_buf = [0u8; 4];
        self.stream
            .read_exact(&mut len_buf)
            .map_err(|e| Error::transport(format!("recv len: {e}")))?;
        let body_len = u32::from_le_bytes(len_buf) as usize;
        if body_len > MAX_FRAME {
            return Err(Error::protocol(format!("frame of {body_len} bytes exceeds cap")));
        }
        let mut rest = vec![0u8; body_len + 4];
        self.stream
            .read_exact(&mut rest)
            .map_err(|e| Error::transport(format!("recv body: {e}")))?;
        let mut wire = Vec::with_capacity(body_len + 8);
        wire.extend_from_slice(&len_buf);
        wire.extend_from_slice(&rest);
        let (frame, _) = Frame::from_wire(&wire)?;
        Ok(frame)
    }
}

// --------------------------------------------------------------- in-proc

/// In-process duplex transport over mpsc channels.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl InProcTransport {
    /// Create a connected pair (edge end, cloud end).
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (tx_a, rx_b) = channel();
        let (tx_b, rx_a) = channel();
        (InProcTransport { tx: tx_a, rx: rx_a }, InProcTransport { tx: tx_b, rx: rx_b })
    }
}

impl Transport for InProcTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.tx
            .send(frame.to_wire())
            .map_err(|_| Error::transport("peer closed"))
    }

    fn recv(&mut self) -> Result<Frame> {
        let wire = self
            .rx
            .recv()
            .map_err(|_| Error::transport("peer closed"))?;
        let (frame, _) = Frame::from_wire(&wire)?;
        Ok(frame)
    }
}

// --------------------------------------------------------------- simlink

/// Wraps a transport with the ε-outage wireless model.
///
/// `send` accounts the simulated transmission latency of the payload
/// (container bytes) and, when `stochastic` is set, samples per-attempt
/// outages with retransmission. The accumulated simulated latency is
/// retrievable via [`SimulatedLink::take_latency_ms`]; with `realtime`
/// the thread additionally sleeps it (for end-to-end demos whose
/// wall-clock should reflect the channel).
pub struct SimulatedLink<T: Transport> {
    inner: T,
    channel: OutageChannel,
    rng: Mutex<Rng>,
    stochastic: bool,
    realtime: bool,
    max_retries: u32,
    accum_ms: f64,
}

impl<T: Transport> SimulatedLink<T> {
    /// Wrap `inner` with `channel`.
    pub fn new(inner: T, channel: OutageChannel, seed: u64) -> Self {
        SimulatedLink {
            inner,
            channel,
            rng: Mutex::new(Rng::new(seed)),
            stochastic: false,
            realtime: false,
            max_retries: 16,
            accum_ms: 0.0,
        }
    }

    /// Enable per-attempt outage sampling + ARQ retransmission.
    pub fn stochastic(mut self, on: bool) -> Self {
        self.stochastic = on;
        self
    }

    /// Sleep the simulated latency for real.
    pub fn realtime(mut self, on: bool) -> Self {
        self.realtime = on;
        self
    }

    /// Drain the simulated latency accumulated since the last call.
    pub fn take_latency_ms(&mut self) -> f64 {
        std::mem::replace(&mut self.accum_ms, 0.0)
    }

    /// The underlying channel model.
    pub fn channel(&self) -> &OutageChannel {
        &self.channel
    }
}

impl<T: Transport> Transport for SimulatedLink<T> {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame.payload_len();
        let ms = if self.stochastic {
            let mut rng = self.rng.lock().unwrap();
            self.channel.transmit(bytes, &mut rng, self.max_retries)?.latency_s * 1e3
        } else {
            self.channel.comm_latency_ms(bytes)
        };
        self.accum_ms += ms;
        if self.realtime && ms > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Frame> {
        self.inner.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::FrameKind;

    fn ping(id: u64) -> Frame {
        Frame { request_id: id, kind: FrameKind::Ping }
    }

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&ping(1)).unwrap();
        assert_eq!(b.recv().unwrap(), ping(1));
        b.send(&ping(2)).unwrap();
        assert_eq!(a.recv().unwrap(), ping(2));
    }

    #[test]
    fn inproc_closed_peer_errors() {
        let (mut a, b) = InProcTransport::pair();
        drop(b);
        assert!(a.send(&ping(1)).is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let f = t.recv().unwrap();
            t.send(&f).unwrap(); // echo
        });
        let mut client = connect_tcp(&addr.to_string()).unwrap();
        let f = Frame {
            request_id: 9,
            kind: FrameKind::InferVision {
                model: "m".into(),
                sl: 2,
                batch: 1,
                payload: vec![3; 1000],
            },
        };
        client.send(&f).unwrap();
        assert_eq!(client.recv().unwrap(), f);
        server.join().unwrap();
    }

    #[test]
    fn simulated_link_accounts_latency() {
        let (a, mut b) = InProcTransport::pair();
        let mut sim = SimulatedLink::new(a, OutageChannel::paper_default(), 1);
        let f = Frame {
            request_id: 1,
            kind: FrameKind::InferLm { model: "m".into(), payload: vec![0; 10_000] },
        };
        sim.send(&f).unwrap();
        let ms = sim.take_latency_ms();
        let expect = OutageChannel::paper_default().comm_latency_ms(10_000);
        assert!((ms - expect).abs() < 1e-9);
        assert_eq!(sim.take_latency_ms(), 0.0);
        assert_eq!(b.recv().unwrap(), f);
    }

    #[test]
    fn stochastic_link_latency_at_least_deterministic() {
        let (a, _b) = InProcTransport::pair();
        let ch = OutageChannel::paper_default();
        let base = ch.comm_latency_ms(5_000);
        let mut sim = SimulatedLink::new(a, ch, 7).stochastic(true);
        for i in 0..50 {
            sim.send(&Frame {
                request_id: i,
                kind: FrameKind::InferLm { model: "m".into(), payload: vec![0; 5_000] },
            })
            .unwrap();
            let ms = sim.take_latency_ms();
            assert!(ms >= base - 1e-9);
        }
    }
}
