//! Duplex frame transports.
//!
//! * [`TcpTransport`] — framed over `std::net::TcpStream` (the real
//!   deployment shape; the E2E example runs edge and cloud over
//!   loopback TCP). Supports configurable read/write timeouts
//!   ([`TcpTransport::with_io_timeout`]) so a silent peer surfaces as a
//!   retryable [`Error::Timeout`] instead of hanging `recv` forever.
//! * [`InProcTransport`] — mpsc channel pair for single-process tests
//!   and benches.
//! * [`SimulatedLink`] — wraps any transport with the ε-outage channel
//!   model: accounts (and optionally sleeps) the wireless latency for
//!   each payload and can inject outage-driven retransmissions.
//!
//! The deterministic fault-injection combinator lives in
//! [`crate::coordinator::fault`].

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::Duration;

use crate::channel::OutageChannel;
use crate::error::{Error, Result};
use crate::util::prng::Rng;

use super::protocol::{Frame, MAX_FRAME};

/// A reliable, ordered duplex frame link.
pub trait Transport: Send {
    /// Send one frame.
    fn send(&mut self, frame: &Frame) -> Result<()>;
    /// Block for the next frame.
    fn recv(&mut self) -> Result<Frame>;
    /// Block at most `timeout` for the next frame; elapse surfaces as a
    /// retryable [`Error::Timeout`]. The default implementation falls
    /// back to a plain blocking [`Transport::recv`] for transports with
    /// no native timeout support — the session layer treats those as
    /// "trust the peer or the process supervisor".
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Frame> {
        let _ = timeout;
        self.recv()
    }
}

// ------------------------------------------------------------------ tcp

/// Classify an I/O error at the TCP framing boundary: elapsed read/write
/// timeouts become the retryable [`Error::Timeout`] class (both
/// `WouldBlock` and `TimedOut` appear, platform-dependent), everything
/// else is a transport fault.
fn classify_io(ctx: &str, e: std::io::Error) -> Error {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            Error::timeout(format!("{ctx}: {e}"))
        }
        _ => Error::transport(format!("{ctx}: {e}")),
    }
}

/// Frame transport over a TCP stream.
pub struct TcpTransport {
    stream: TcpStream,
    io_timeout: Option<Duration>,
}

impl TcpTransport {
    /// Wrap an accepted/connected stream (sets TCP_NODELAY, no
    /// timeouts — `recv` blocks until the peer sends or disconnects).
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream
            .set_nodelay(true)
            .map_err(|e| Error::transport(format!("set_nodelay: {e}")))?;
        Ok(TcpTransport { stream, io_timeout: None })
    }

    /// Bound every read and write by `timeout` (a zero duration means
    /// no timeout). An elapsed bound surfaces as a retryable
    /// [`Error::Timeout`]; note the stream may then be mid-frame, so
    /// the caller should reconnect rather than reuse it — the session
    /// layer does exactly that.
    pub fn with_io_timeout(self, timeout: Duration) -> Result<Self> {
        let t = if timeout.is_zero() { None } else { Some(timeout) };
        self.stream
            .set_read_timeout(t)
            .map_err(|e| Error::transport(format!("set_read_timeout: {e}")))?;
        self.stream
            .set_write_timeout(t)
            .map_err(|e| Error::transport(format!("set_write_timeout: {e}")))?;
        Ok(TcpTransport { io_timeout: t, ..self })
    }

    fn recv_wire(&mut self) -> Result<Frame> {
        let mut len_buf = [0u8; 4];
        self.stream
            .read_exact(&mut len_buf)
            .map_err(|e| classify_io("recv len", e))?;
        let body_len = u32::from_le_bytes(len_buf) as usize;
        if body_len > MAX_FRAME {
            return Err(Error::protocol(format!("frame of {body_len} bytes exceeds cap")));
        }
        let mut rest = vec![0u8; body_len + 4];
        self.stream
            .read_exact(&mut rest)
            .map_err(|e| classify_io("recv body", e))?;
        let mut wire = Vec::with_capacity(body_len + 8);
        wire.extend_from_slice(&len_buf);
        wire.extend_from_slice(&rest);
        let (frame, _) = Frame::from_wire(&wire)?;
        Ok(frame)
    }
}

/// Connect to a cloud node at `addr` (no I/O timeouts).
pub fn connect_tcp(addr: &str) -> Result<TcpTransport> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::transport(format!("connect {addr}: {e}")))?;
    TcpTransport::new(stream)
}

/// Connect to a cloud node at `addr` with read/write bounds of
/// `io_timeout` (zero = none) on the resulting transport.
pub fn connect_tcp_timeout(addr: &str, io_timeout: Duration) -> Result<TcpTransport> {
    connect_tcp(addr)?.with_io_timeout(io_timeout)
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let wire = frame.to_wire();
        self.stream.write_all(&wire).map_err(|e| classify_io("send", e))
    }

    fn recv(&mut self) -> Result<Frame> {
        self.recv_wire()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Frame> {
        // Tighten the read bound for this call, then restore the
        // configured steady-state timeout.
        let bound = timeout.max(Duration::from_millis(1));
        self.stream
            .set_read_timeout(Some(bound))
            .map_err(|e| Error::transport(format!("set_read_timeout: {e}")))?;
        let out = self.recv_wire();
        let _ = self.stream.set_read_timeout(self.io_timeout);
        out
    }
}

// --------------------------------------------------------------- in-proc

/// In-process duplex transport over mpsc channels.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl InProcTransport {
    /// Create a connected pair (edge end, cloud end).
    pub fn pair() -> (InProcTransport, InProcTransport) {
        let (tx_a, rx_b) = channel();
        let (tx_b, rx_a) = channel();
        (InProcTransport { tx: tx_a, rx: rx_a }, InProcTransport { tx: tx_b, rx: rx_b })
    }
}

impl Transport for InProcTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.tx
            .send(frame.to_wire())
            .map_err(|_| Error::transport("peer closed"))
    }

    fn recv(&mut self) -> Result<Frame> {
        let wire = self
            .rx
            .recv()
            .map_err(|_| Error::transport("peer closed"))?;
        let (frame, _) = Frame::from_wire(&wire)?;
        Ok(frame)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Frame> {
        let wire = self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => Error::timeout("recv deadline elapsed"),
            RecvTimeoutError::Disconnected => Error::transport("peer closed"),
        })?;
        let (frame, _) = Frame::from_wire(&wire)?;
        Ok(frame)
    }
}

// --------------------------------------------------------------- simlink

/// Wraps a transport with the ε-outage wireless model.
///
/// `send` accounts the simulated transmission latency of the payload
/// (container bytes) and, when `stochastic` is set, samples per-attempt
/// outages with retransmission. The accumulated simulated latency is
/// retrievable via [`SimulatedLink::take_latency_ms`]; with `realtime`
/// the thread additionally sleeps it (for end-to-end demos whose
/// wall-clock should reflect the channel).
pub struct SimulatedLink<T: Transport> {
    inner: T,
    channel: OutageChannel,
    rng: Mutex<Rng>,
    stochastic: bool,
    realtime: bool,
    max_retries: u32,
    accum_ms: f64,
    retransmissions: u64,
}

impl<T: Transport> SimulatedLink<T> {
    /// Wrap `inner` with `channel`.
    pub fn new(inner: T, channel: OutageChannel, seed: u64) -> Self {
        SimulatedLink {
            inner,
            channel,
            rng: Mutex::new(Rng::new(seed)),
            stochastic: false,
            realtime: false,
            max_retries: 16,
            accum_ms: 0.0,
            retransmissions: 0,
        }
    }

    /// Enable per-attempt outage sampling + ARQ retransmission.
    pub fn stochastic(mut self, on: bool) -> Self {
        self.stochastic = on;
        self
    }

    /// Sleep the simulated latency for real.
    pub fn realtime(mut self, on: bool) -> Self {
        self.realtime = on;
        self
    }

    /// Drain the simulated latency accumulated since the last call.
    pub fn take_latency_ms(&mut self) -> f64 {
        std::mem::replace(&mut self.accum_ms, 0.0)
    }

    /// Total outage-triggered ARQ retransmissions so far.
    pub fn retransmissions(&self) -> u64 {
        self.retransmissions
    }

    /// The underlying channel model.
    pub fn channel(&self) -> &OutageChannel {
        &self.channel
    }
}

impl<T: Transport> Transport for SimulatedLink<T> {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame.payload_len();
        let ms = if self.stochastic {
            let mut rng = self.rng.lock().unwrap();
            match self.channel.transmit(bytes, &mut rng, self.max_retries) {
                Ok(out) => {
                    self.retransmissions += out.retries as u64;
                    out.latency_s * 1e3
                }
                // Link-level ARQ ran out of budget on a *retryable*
                // fault: reclassify as a timeout so the session layer's
                // deadline/backoff owns the next attempt. Fatal errors
                // (nothing the channel model emits today, but the
                // classification is the contract) propagate untouched.
                Err(e) if e.is_retryable() => {
                    return Err(Error::timeout(format!("simulated link: {e}")));
                }
                Err(e) => return Err(e),
            }
        } else {
            self.channel.comm_latency_ms(bytes)
        };
        self.accum_ms += ms;
        if self.realtime && ms > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Frame> {
        self.inner.recv()
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Frame> {
        self.inner.recv_timeout(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::FrameKind;

    fn ping(id: u64) -> Frame {
        Frame::new(id, FrameKind::Ping)
    }

    #[test]
    fn inproc_roundtrip() {
        let (mut a, mut b) = InProcTransport::pair();
        a.send(&ping(1)).unwrap();
        assert_eq!(b.recv().unwrap(), ping(1));
        b.send(&ping(2)).unwrap();
        assert_eq!(a.recv().unwrap(), ping(2));
    }

    #[test]
    fn inproc_recv_timeout_classifies() {
        let (mut a, mut b) = InProcTransport::pair();
        let err = a.recv_timeout(Duration::from_millis(5)).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert!(err.is_retryable());
        b.send(&ping(1)).unwrap();
        assert_eq!(a.recv_timeout(Duration::from_millis(100)).unwrap(), ping(1));
    }

    #[test]
    fn inproc_closed_peer_errors() {
        let (mut a, b) = InProcTransport::pair();
        drop(b);
        assert!(a.send(&ping(1)).is_err());
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let f = t.recv().unwrap();
            t.send(&f).unwrap(); // echo
        });
        let mut client = connect_tcp(&addr.to_string()).unwrap();
        let f = Frame::new(
            9,
            FrameKind::InferVision {
                model: "m".into(),
                sl: 2,
                batch: 1,
                payload: vec![3; 1000],
            },
        );
        client.send(&f).unwrap();
        assert_eq!(client.recv().unwrap(), f);
        server.join().unwrap();
    }

    #[test]
    fn tcp_silent_peer_times_out_retryably() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Accept but never reply: pre-timeout code would hang here forever.
        let server = std::thread::spawn(move || {
            let (_stream, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(200));
        });
        let mut client = connect_tcp_timeout(&addr.to_string(), Duration::from_millis(30)).unwrap();
        let err = client.recv().unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert!(err.is_retryable());
        // The one-shot bound works without a configured steady-state timeout.
        let mut client2 = connect_tcp(&addr.to_string()).unwrap();
        let err = client2.recv_timeout(Duration::from_millis(30)).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        server.join().unwrap();
    }

    #[test]
    fn simulated_link_accounts_latency() {
        let (a, mut b) = InProcTransport::pair();
        let mut sim = SimulatedLink::new(a, OutageChannel::paper_default(), 1);
        let f = Frame::new(1, FrameKind::InferLm { model: "m".into(), payload: vec![0; 10_000] });
        sim.send(&f).unwrap();
        let ms = sim.take_latency_ms();
        let expect = OutageChannel::paper_default().comm_latency_ms(10_000);
        assert!((ms - expect).abs() < 1e-9);
        assert_eq!(sim.take_latency_ms(), 0.0);
        assert_eq!(b.recv().unwrap(), f);
    }

    #[test]
    fn stochastic_link_latency_at_least_deterministic() {
        let (a, _b) = InProcTransport::pair();
        let ch = OutageChannel::paper_default();
        let base = ch.comm_latency_ms(5_000);
        let mut sim = SimulatedLink::new(a, ch, 7).stochastic(true);
        for i in 0..50 {
            let f = Frame::new(i, FrameKind::InferLm { model: "m".into(), payload: vec![0; 5_000] });
            sim.send(&f).unwrap();
            let ms = sim.take_latency_ms();
            assert!(ms >= base - 1e-9);
        }
    }

    #[test]
    fn exhausted_link_retries_surface_as_retryable_timeout() {
        use crate::channel::ChannelParams;
        // ε = 0.5 with zero ARQ budget: roughly half the sends fail, and
        // each failure must classify as a retryable timeout (the session
        // layer's cue to back off and resend), never a fatal error.
        let ch = OutageChannel::new(ChannelParams { epsilon: 0.5, ..Default::default() }).unwrap();
        let (a, _b) = InProcTransport::pair();
        let mut sim = SimulatedLink::new(a, ch, 11).stochastic(true);
        sim.max_retries = 0;
        let mut failures = 0;
        for i in 0..100 {
            let f = Frame::new(i, FrameKind::InferLm { model: "m".into(), payload: vec![0; 100] });
            match sim.send(&f) {
                Ok(()) => {}
                Err(e) => {
                    assert!(matches!(e, Error::Timeout(_)), "{e}");
                    assert!(e.is_retryable());
                    failures += 1;
                }
            }
        }
        assert!(failures > 10, "expected frequent outage-budget exhaustion, saw {failures}");
    }
}
