//! Hot-reconfigurable serving limits.
//!
//! PR 7 introduced the serving bounds — [`ServerLimits::max_inflight`]
//! on the admission gate, `max_queue`/`max_wait` on the batcher — as
//! construction-time-only values. A feedback controller (or an
//! operator) cannot tune a running server that way, so this module
//! lifts them into a shared atomic handle: every component reads its
//! bound per decision, and whoever holds a clone of the [`Arc`] can
//! move the dial mid-flight without a restart.
//!
//! All loads/stores are `Relaxed`: the knobs are tuning hints read at
//! the top of each admission/dispatch decision, not synchronization
//! edges. A momentarily stale read admits (or sheds) one extra request,
//! which is exactly the tolerance any live-reconfigurable limit has.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use super::cloud::ServerLimits;

/// The shared dial box for a serving stack: admission cap, batch queue
/// bound, batch flush delay, adaptive batch ceiling, and per-tenant
/// quota. Cheap to clone behind an [`Arc`]; see
/// [`daemon`](super::daemon) for the controller that drives
/// `batch_limit` from observed tail latency.
///
/// [`Arc`]: std::sync::Arc
#[derive(Debug)]
pub struct ServingKnobs {
    max_inflight: AtomicUsize,
    max_queue: AtomicUsize,
    max_wait_us: AtomicU64,
    batch_limit: AtomicUsize,
    tenant_quota: AtomicUsize,
}

impl Default for ServingKnobs {
    fn default() -> Self {
        ServingKnobs::from_limits(&ServerLimits::default())
    }
}

impl ServingKnobs {
    /// Knobs seeded from the static [`ServerLimits`]; queue and batch
    /// bounds start unbounded, the flush delay at 2 ms. Seeds clamp
    /// like the setters do — `max_inflight: 0` would otherwise wedge
    /// the admission gate into permanent `Busy`.
    pub fn from_limits(limits: &ServerLimits) -> Self {
        ServingKnobs {
            max_inflight: AtomicUsize::new(limits.max_inflight.max(1)),
            max_queue: AtomicUsize::new(usize::MAX),
            max_wait_us: AtomicU64::new(2_000),
            batch_limit: AtomicUsize::new(usize::MAX),
            tenant_quota: AtomicUsize::new(usize::MAX),
        }
    }

    /// Global concurrent-inference cap (admission gate).
    pub fn max_inflight(&self) -> usize {
        self.max_inflight.load(Ordering::Relaxed)
    }

    pub fn set_max_inflight(&self, v: usize) {
        self.max_inflight.store(v.max(1), Ordering::Relaxed);
    }

    /// Batch queue-depth bound; submits beyond it are shed.
    pub fn max_queue(&self) -> usize {
        self.max_queue.load(Ordering::Relaxed)
    }

    pub fn set_max_queue(&self, v: usize) {
        self.max_queue.store(v.max(1), Ordering::Relaxed);
    }

    /// Longest a request waits for batch-mates before a partial batch
    /// is flushed.
    pub fn max_wait(&self) -> Duration {
        Duration::from_micros(self.max_wait_us.load(Ordering::Relaxed))
    }

    pub fn set_max_wait(&self, v: Duration) {
        let us = v.as_micros().min(u64::MAX as u128) as u64;
        self.max_wait_us.store(us.max(1), Ordering::Relaxed);
    }

    /// Current adaptive batch-size ceiling (the controller's output).
    /// Dispatch picks the largest compiled bucket that fits under it.
    pub fn batch_limit(&self) -> usize {
        self.batch_limit.load(Ordering::Relaxed)
    }

    pub fn set_batch_limit(&self, v: usize) {
        self.batch_limit.store(v.max(1), Ordering::Relaxed);
    }

    /// Per-tenant in-flight quota (on top of the global cap).
    pub fn tenant_quota(&self) -> usize {
        self.tenant_quota.load(Ordering::Relaxed)
    }

    pub fn set_tenant_quota(&self, v: usize) {
        self.tenant_quota.store(v.max(1), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_from_limits_and_reconfigures_live() {
        let k = ServingKnobs::from_limits(&ServerLimits { max_inflight: 7 });
        assert_eq!(k.max_inflight(), 7);
        assert_eq!(k.max_queue(), usize::MAX);
        k.set_max_inflight(3);
        k.set_max_queue(64);
        k.set_max_wait(Duration::from_millis(5));
        k.set_batch_limit(8);
        k.set_tenant_quota(2);
        assert_eq!(k.max_inflight(), 3);
        assert_eq!(k.max_queue(), 64);
        assert_eq!(k.max_wait(), Duration::from_millis(5));
        assert_eq!(k.batch_limit(), 8);
        assert_eq!(k.tenant_quota(), 2);
    }

    #[test]
    fn zero_clamps_to_one_instead_of_wedging_the_server() {
        let k = ServingKnobs::from_limits(&ServerLimits { max_inflight: 0 });
        assert_eq!(k.max_inflight(), 1, "from_limits clamps like the setter");
        let k = ServingKnobs::default();
        k.set_max_inflight(0);
        k.set_max_queue(0);
        k.set_batch_limit(0);
        k.set_tenant_quota(0);
        assert_eq!(k.max_inflight(), 1);
        assert_eq!(k.max_queue(), 1);
        assert_eq!(k.batch_limit(), 1);
        assert_eq!(k.tenant_quota(), 1);
    }
}
