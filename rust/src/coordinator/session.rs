//! Resilient request/response session on top of a raw [`Transport`].
//!
//! [`Session`] owns the failure semantics the bare transports do not:
//!
//! * **per-request IDs** — every attempt gets a fresh id, and replies
//!   whose id does not match the outstanding request (late answers to a
//!   timed-out attempt, duplicate deliveries from a lossy link) are
//!   counted and discarded instead of being handed to the caller;
//! * **deadlines** — an end-to-end budget rides the frame header
//!   (`Frame::with_deadline`) so the cloud can shed work it provably
//!   cannot finish in time, and the edge stops retrying once the budget
//!   is spent;
//! * **retry with capped exponential backoff + deterministic jitter** —
//!   only errors where [`Error::is_retryable`] holds are retried; the
//!   jitter is drawn from a [`Rng`] seeded by [`SessionConfig::seed`],
//!   so a failing schedule replays exactly;
//! * **heartbeat liveness + reconnect** — an idle session probes the
//!   peer with Ping/Pong before reusing the connection, and a
//!   [`Session::with_connector`] closure lets it transparently dial a
//!   fresh transport when the old one is dead;
//! * **explicit shed handling** — a [`FrameKind::Busy`] reply is turned
//!   into a bounded wait (honouring the peer's retry-after hint) or a
//!   clean [`Error::Rejected`] once attempts are exhausted;
//! * **model-version handshake** — when pinned via
//!   [`Session::with_model_version`], every request carries the
//!   registry's `model_version` header, and a [`FrameKind::VersionSkew`]
//!   reply is **fatal until resync**: with a
//!   [`Session::with_resync`] hook installed the session re-fetches
//!   (once per call) and retries at the server's version; without one it
//!   surfaces [`Error::VersionSkew`] — never a silent decode against the
//!   wrong tail.
//!
//! The module also hosts the edge-side graceful-degradation policy
//! ([`DegradePolicy`]/[`DegradeState`]): a pure state machine that steps
//! the quantization parameter Q down after consecutive retryable
//! failures (coarser features → fewer bytes → fewer link-budget
//! failures, per the paper's ε-outage model) and climbs back up after a
//! run of successes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::telemetry::metrics::Registry;
use crate::util::prng::Rng;

use super::protocol::{Frame, FrameKind};
use super::transport::Transport;

/// Tunables for [`Session`] retry/backoff/heartbeat behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionConfig {
    /// End-to-end budget per logical request, milliseconds. `0` means
    /// no deadline: attempts are bounded by `max_retries` only and no
    /// deadline header is attached to outgoing frames.
    pub deadline_ms: u64,
    /// Per-attempt receive budget, milliseconds (clamped to the
    /// remaining deadline).
    pub try_timeout_ms: u64,
    /// Retries after the first attempt (`3` → up to 4 attempts).
    pub max_retries: u32,
    /// First backoff step, milliseconds; doubles per retry.
    pub base_backoff_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub max_backoff_ms: u64,
    /// Idle threshold after which the connection is probed with a
    /// Ping/Pong before carrying a real request. `0` disables the
    /// heartbeat.
    pub heartbeat_ms: u64,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            deadline_ms: 30_000,
            try_timeout_ms: 2_000,
            max_retries: 3,
            base_backoff_ms: 10,
            max_backoff_ms: 500,
            heartbeat_ms: 0,
            seed: 0x5e55_10f1,
        }
    }
}

/// Capped exponential backoff with equal jitter.
///
/// The raw step for `attempt` (0-based) is `base << attempt`, capped at
/// `cap`; the returned delay is drawn uniformly from `[step/2, step]` so
/// concurrent clients decorrelate instead of retrying in lockstep.
/// Deterministic given the `rng` state.
pub fn backoff_with_jitter(attempt: u32, base_ms: u64, cap_ms: u64, rng: &mut Rng) -> Duration {
    let shift = attempt.min(62);
    let step = base_ms.saturating_mul(1u64 << shift).min(cap_ms.max(1)).max(1);
    let half = (step / 2).max(1);
    let jittered = half + rng.below(step - half + 1);
    Duration::from_millis(jittered)
}

/// A retrying, deadline-aware, reconnecting wrapper around a transport.
///
/// Telemetry (when wired via [`Session::with_metrics`]):
/// `session.retry_total`, `session.reconnect_total`,
/// `session.timeout_total`, `session.shed_total`,
/// `session.stale_replies`, `session.giveup_total`,
/// `session.skew_total`, `session.resync_total`, and the
/// `session.attempt_ms` latency histogram.
pub struct Session<T: Transport> {
    transport: T,
    connector: Option<Box<dyn FnMut() -> Result<T> + Send>>,
    cfg: SessionConfig,
    rng: Rng,
    next_id: u64,
    last_activity: Instant,
    metrics: Option<Arc<Registry>>,
    model_version: Option<u64>,
    resync: Option<Box<dyn FnMut(u64) -> Result<u64> + Send>>,
}

impl<T: Transport> Session<T> {
    /// Wrap `transport` with the given retry/deadline policy.
    pub fn new(transport: T, cfg: SessionConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        Session {
            transport,
            connector: None,
            cfg,
            rng,
            next_id: 1,
            last_activity: Instant::now(),
            metrics: None,
            model_version: None,
            resync: None,
        }
    }

    /// Record robustness counters into `registry`.
    pub fn with_metrics(mut self, registry: Arc<Registry>) -> Self {
        self.metrics = Some(registry);
        self
    }

    /// Install a dialer used to replace the transport after a
    /// connection-level failure (and after a failed heartbeat probe).
    pub fn with_connector(mut self, connector: Box<dyn FnMut() -> Result<T> + Send>) -> Self {
        self.connector = Some(connector);
        self
    }

    /// Pin the session to a registry `model_version`: every request
    /// carries the tag-15 header, and a mismatched server answers
    /// [`FrameKind::VersionSkew`] instead of decoding.
    pub fn with_model_version(mut self, model_version: u64) -> Self {
        self.model_version = Some(model_version);
        self
    }

    /// Re-pin (or unpin, with `None`) the declared model version —
    /// typically after a hot-swap on the edge side.
    pub fn set_model_version(&mut self, model_version: Option<u64>) {
        self.model_version = model_version;
    }

    /// Currently pinned model version, if any.
    pub fn model_version(&self) -> Option<u64> {
        self.model_version
    }

    /// Install the resync hook run on a [`FrameKind::VersionSkew`]
    /// reply: it receives the server's active version and returns the
    /// version to re-pin to after re-fetching from the registry (at most
    /// once per `call`; a second skew in the same call is fatal).
    pub fn with_resync(mut self, resync: Box<dyn FnMut(u64) -> Result<u64> + Send>) -> Self {
        self.resync = Some(resync);
        self
    }

    /// Replace the retry/deadline policy.
    pub fn set_config(&mut self, cfg: SessionConfig) {
        self.rng = Rng::new(cfg.seed);
        self.cfg = cfg;
    }

    /// Current policy.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    fn bump(&self, name: &str) {
        if let Some(m) = &self.metrics {
            m.incr(name, 1);
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Dial a replacement transport if a connector is installed.
    /// Returns true when the transport was actually replaced.
    fn reconnect(&mut self) -> bool {
        let Some(connector) = self.connector.as_mut() else {
            return false;
        };
        match connector() {
            Ok(t) => {
                self.transport = t;
                self.bump("session.reconnect_total");
                true
            }
            Err(_) => false, // keep the old transport; a later attempt retries
        }
    }

    /// Probe an idle connection with Ping/Pong; on failure, reconnect.
    fn heartbeat(&mut self) {
        if self.cfg.heartbeat_ms == 0 {
            return;
        }
        if self.last_activity.elapsed() < Duration::from_millis(self.cfg.heartbeat_ms) {
            return;
        }
        let id = self.fresh_id();
        let budget = Duration::from_millis(self.cfg.try_timeout_ms.max(1));
        let alive = self.transport.send(&Frame::new(id, FrameKind::Ping)).is_ok()
            && matches!(
                self.transport.recv_timeout(budget),
                Ok(Frame { request_id, kind: FrameKind::Pong, .. }) if request_id == id
            );
        if !alive {
            self.reconnect();
        }
        self.last_activity = Instant::now();
    }

    /// Remaining end-to-end budget, or `None` when deadlines are off.
    fn remaining(&self, started: Instant) -> Option<Duration> {
        if self.cfg.deadline_ms == 0 {
            return None;
        }
        let budget = Duration::from_millis(self.cfg.deadline_ms);
        Some(budget.saturating_sub(started.elapsed()))
    }

    /// One send + receive attempt. Discards replies whose id does not
    /// match (stale answers to earlier attempts, duplicate deliveries).
    fn attempt(&mut self, kind: &FrameKind, budget: Duration) -> Result<Frame> {
        let id = self.fresh_id();
        let mut request = Frame::new(id, kind.clone());
        if self.cfg.deadline_ms > 0 {
            let ms = budget.as_millis().min(u32::MAX as u128) as u32;
            request = request.with_deadline(ms.max(1));
        }
        if let Some(version) = self.model_version {
            request = request.with_model_version(version);
        }
        self.transport.send(&request)?;
        let per_try = Duration::from_millis(self.cfg.try_timeout_ms.max(1))
            .min(budget)
            .max(Duration::from_millis(1));
        let recv_deadline = Instant::now() + per_try;
        loop {
            let left = recv_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::timeout(format!("no reply to request {id} within budget")));
            }
            let reply = self.transport.recv_timeout(left)?;
            if reply.request_id != id {
                self.bump("session.stale_replies");
                continue;
            }
            return Ok(reply);
        }
    }

    /// Issue `kind` as a request and return the matching reply.
    ///
    /// Retries on retryable errors with capped exponential backoff and
    /// deterministic jitter, reconnects through the installed connector
    /// on connection-level failures, honours the end-to-end deadline,
    /// and converts a [`FrameKind::Busy`] shed into a bounded wait or a
    /// clean [`Error::Rejected`].
    pub fn call(&mut self, kind: FrameKind) -> Result<Frame> {
        self.heartbeat();
        let started = Instant::now();
        let mut attempt_no: u32 = 0;
        let mut resynced = false;
        loop {
            let budget = match self.remaining(started) {
                Some(left) if left.is_zero() => {
                    self.bump("session.timeout_total");
                    self.bump("session.giveup_total");
                    return Err(Error::timeout(format!(
                        "deadline of {} ms exhausted after {} attempts",
                        self.cfg.deadline_ms, attempt_no
                    )));
                }
                Some(left) => left,
                None => Duration::from_millis(self.cfg.try_timeout_ms.max(1)),
            };
            let t0 = Instant::now();
            let outcome = self.attempt(&kind, budget);
            if let Some(m) = &self.metrics {
                m.histogram("session.attempt_ms").record_ms(t0.elapsed().as_secs_f64() * 1e3);
            }
            self.last_activity = Instant::now();
            let err = match outcome {
                Ok(Frame { kind: FrameKind::Busy { retry_after_ms, message }, .. }) => {
                    self.bump("session.shed_total");
                    Error::rejected(retry_after_ms as u64, message)
                }
                Ok(Frame { kind: FrameKind::VersionSkew { active, offered, message }, .. }) => {
                    // Skew is fatal until resync: retrying the same
                    // version meets the same mismatched tail. At most
                    // one registry re-fetch per call; a second skew (or
                    // no hook) surfaces as Error::VersionSkew.
                    self.bump("session.skew_total");
                    if !resynced && self.resync.is_some() {
                        resynced = true;
                        let mut hook = self.resync.take().unwrap();
                        let refetched = hook(active);
                        self.resync = Some(hook);
                        match refetched {
                            Ok(version) => {
                                self.model_version = Some(version);
                                self.bump("session.resync_total");
                                continue;
                            }
                            Err(e) => return Err(e),
                        }
                    }
                    return Err(Error::version_skew(active, offered, message));
                }
                Ok(reply) => return Ok(reply),
                Err(e) => e,
            };
            if !err.is_retryable() || attempt_no >= self.cfg.max_retries {
                if matches!(err, Error::Timeout(_)) {
                    self.bump("session.timeout_total");
                }
                if err.is_retryable() {
                    self.bump("session.giveup_total");
                }
                return Err(err);
            }
            self.bump("session.retry_total");
            // A timed-out attempt may just mean a dropped frame, and a
            // shed means the peer is healthy but loaded — keep the
            // connection. Connection-class failures get a fresh dial.
            if matches!(err, Error::Transport(_) | Error::Io(_)) {
                self.reconnect();
            }
            let wait = match &err {
                Error::Rejected { retry_after_ms, .. } => Duration::from_millis(*retry_after_ms),
                _ => backoff_with_jitter(
                    attempt_no,
                    self.cfg.base_backoff_ms,
                    self.cfg.max_backoff_ms,
                    &mut self.rng,
                ),
            };
            let wait = match self.remaining(started) {
                Some(left) => wait.min(left),
                None => wait,
            };
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
            attempt_no += 1;
        }
    }
}

/// A [`ChunkSource`](crate::runtime::registry::ChunkSource) that pulls
/// manifests and chunks over a [`Session`] with the tag 17–20 registry
/// frames — inheriting the session's retries, deadlines, backoff, and
/// reconnect, so a delta sync rides the same failure semantics as
/// inference traffic.
///
/// Nothing received is trusted: a `ChunkReply` payload is re-hashed
/// against the requested address here, *before* the sync layer (which
/// verifies again) ever sees it, and a mismatch is fatal corruption —
/// retrying a tampering server cannot help.
pub struct WireSource<T: Transport> {
    session: Session<T>,
}

impl<T: Transport> WireSource<T> {
    pub fn new(session: Session<T>) -> Self {
        WireSource { session }
    }

    /// Hand the session back (e.g. to resume inference after a sync).
    pub fn into_session(self) -> Session<T> {
        self.session
    }
}

impl<T: Transport> crate::runtime::registry::ChunkSource for WireSource<T> {
    fn fetch_manifest(&mut self, model: &str, version: u64) -> Result<String> {
        let kind = FrameKind::FetchManifest { model: model.to_string(), version };
        match self.session.call(kind)?.kind {
            FrameKind::ManifestReply { json } => Ok(json),
            FrameKind::ServerError { message } => {
                Err(Error::artifact(format!("registry peer refused manifest: {message}")))
            }
            other => Err(Error::protocol(format!(
                "unexpected reply to FetchManifest: {other:?}"
            ))),
        }
    }

    fn fetch_chunk(&mut self, sha256: &str) -> Result<Vec<u8>> {
        let kind = FrameKind::FetchChunk { sha256: sha256.to_string() };
        match self.session.call(kind)?.kind {
            FrameKind::ChunkReply { payload } => {
                let got = crate::util::sha256::to_hex(&crate::util::sha256::hash(&payload));
                if got != sha256 {
                    return Err(Error::corrupt(format!(
                        "chunk {sha256}: peer served payload hashing to {got} \
                         (tampered server or link)"
                    )));
                }
                Ok(payload)
            }
            FrameKind::ServerError { message } => {
                Err(Error::artifact(format!("registry peer refused chunk: {message}")))
            }
            other => Err(Error::protocol(format!(
                "unexpected reply to FetchChunk: {other:?}"
            ))),
        }
    }
}

/// Tunables for the edge-side graceful-degradation policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradePolicy {
    /// Consecutive retryable failures before stepping Q down.
    pub timeouts_to_degrade: u32,
    /// How many Q levels one degradation step removes.
    pub q_step: u8,
    /// Lowest Q the policy will degrade to.
    pub q_floor: u8,
    /// Consecutive successes before stepping Q back up.
    pub successes_to_recover: u32,
    /// When already at `q_floor`, allow falling back to raw
    /// (uncompressed) frames as the last resort.
    pub raw_fallback: bool,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        DegradePolicy {
            timeouts_to_degrade: 3,
            q_step: 2,
            q_floor: 2,
            successes_to_recover: 16,
            raw_fallback: false,
        }
    }
}

/// Observable outcome of feeding one request result to [`DegradeState`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradeEvent {
    /// No state change.
    None,
    /// Q stepped down to the contained value.
    SteppedDown(u8),
    /// Entered raw-frame fallback (Q already at the floor).
    RawFallback,
    /// Q stepped back up to the contained value (or raw mode exited).
    Recovered(u8),
}

/// Pure state machine implementing [`DegradePolicy`].
///
/// Feed it `on_success` / `on_retryable_failure` per completed request
/// and read `effective_q` / `raw_mode` before building the next one.
#[derive(Debug, Clone)]
pub struct DegradeState {
    policy: DegradePolicy,
    base_q: u8,
    q: u8,
    raw: bool,
    consecutive_failures: u32,
    consecutive_successes: u32,
}

impl DegradeState {
    /// Start at `base_q` (the configured operating point).
    pub fn new(policy: DegradePolicy, base_q: u8) -> Self {
        let q_floor = policy.q_floor.min(base_q);
        DegradeState {
            policy: DegradePolicy { q_floor, ..policy },
            base_q,
            q: base_q,
            raw: false,
            consecutive_failures: 0,
            consecutive_successes: 0,
        }
    }

    /// Q the edge should encode with right now.
    pub fn effective_q(&self) -> u8 {
        self.q
    }

    /// True when the policy has fallen back to raw (uncompressed)
    /// frames.
    pub fn raw_mode(&self) -> bool {
        self.raw
    }

    /// True when any degradation (Q below base, or raw mode) is active.
    pub fn degraded(&self) -> bool {
        self.raw || self.q < self.base_q
    }

    /// Record a successful round trip.
    pub fn on_success(&mut self) -> DegradeEvent {
        self.consecutive_failures = 0;
        if !self.degraded() {
            return DegradeEvent::None;
        }
        self.consecutive_successes += 1;
        if self.consecutive_successes < self.policy.successes_to_recover {
            return DegradeEvent::None;
        }
        self.consecutive_successes = 0;
        if self.raw {
            self.raw = false;
        } else {
            self.q = self.q.saturating_add(self.policy.q_step).min(self.base_q);
        }
        DegradeEvent::Recovered(self.q)
    }

    /// Record a retryable failure (timeout / transport fault / shed)
    /// that survived the session layer's own retries.
    pub fn on_retryable_failure(&mut self) -> DegradeEvent {
        self.consecutive_successes = 0;
        self.consecutive_failures += 1;
        if self.consecutive_failures < self.policy.timeouts_to_degrade {
            return DegradeEvent::None;
        }
        self.consecutive_failures = 0;
        if self.q > self.policy.q_floor {
            self.q = self.q.saturating_sub(self.policy.q_step).max(self.policy.q_floor);
            DegradeEvent::SteppedDown(self.q)
        } else if self.policy.raw_fallback && !self.raw {
            self.raw = true;
            DegradeEvent::RawFallback
        } else {
            DegradeEvent::None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::{FaultSpec, FaultyTransport};
    use crate::coordinator::transport::{InProcTransport, Transport};

    #[test]
    fn backoff_doubles_and_caps() {
        let mut rng = Rng::new(42);
        for attempt in 0..20 {
            let d = backoff_with_jitter(attempt, 10, 500, &mut rng);
            let step = 10u64.saturating_mul(1u64 << attempt.min(62)).min(500);
            let ms = d.as_millis() as u64;
            assert!(ms >= (step / 2).max(1) && ms <= step, "attempt {attempt}: {ms} ms");
        }
        // Deterministic across runs with the same seed.
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for attempt in 0..8 {
            assert_eq!(
                backoff_with_jitter(attempt, 10, 500, &mut a),
                backoff_with_jitter(attempt, 10, 500, &mut b)
            );
        }
    }

    fn fast_cfg() -> SessionConfig {
        SessionConfig {
            deadline_ms: 5_000,
            try_timeout_ms: 50,
            max_retries: 10,
            base_backoff_ms: 1,
            max_backoff_ms: 4,
            heartbeat_ms: 0,
            seed: 99,
        }
    }

    /// Responder that answers every received frame with Pong, echoing
    /// the request id. Tolerates a bounded run of garbled frames (so an
    /// injected corruption does not kill the loop) but exits once errors
    /// repeat back-to-back, which is what a closed channel produces.
    fn pong_responder(mut server: impl Transport + Send + 'static) {
        std::thread::spawn(move || {
            let mut consecutive_errors = 0u32;
            loop {
                match server.recv() {
                    Ok(f) => {
                        consecutive_errors = 0;
                        let _ = server.send(&Frame::new(f.request_id, FrameKind::Pong));
                    }
                    Err(_) if consecutive_errors < 64 => consecutive_errors += 1,
                    Err(_) => break,
                }
            }
        });
    }

    #[test]
    fn call_succeeds_over_clean_transport() {
        let (client, server) = InProcTransport::pair();
        pong_responder(server);
        let mut s = Session::new(client, fast_cfg());
        for _ in 0..10 {
            let reply = s.call(FrameKind::Ping).unwrap();
            assert_eq!(reply.kind, FrameKind::Pong);
        }
    }

    #[test]
    fn retries_through_drops_and_counts_them() {
        let metrics = Arc::new(Registry::new());
        let (client, server) = FaultyTransport::pair(11, FaultSpec::drops(0.4), FaultSpec::none());
        pong_responder(server);
        let mut s = Session::new(client, fast_cfg()).with_metrics(Arc::clone(&metrics));
        for _ in 0..50 {
            let reply = s.call(FrameKind::Ping).unwrap();
            assert_eq!(reply.kind, FrameKind::Pong);
        }
        assert!(metrics.get("session.retry_total") > 0, "p=0.4 drops must force retries");
    }

    #[test]
    fn duplicate_replies_are_discarded_as_stale() {
        let metrics = Arc::new(Registry::new());
        let (client, server) =
            FaultyTransport::pair(13, FaultSpec::none(), FaultSpec::duplicates(1.0));
        pong_responder(server);
        let mut s = Session::new(client, fast_cfg()).with_metrics(Arc::clone(&metrics));
        for _ in 0..20 {
            let reply = s.call(FrameKind::Ping).unwrap();
            assert_eq!(reply.kind, FrameKind::Pong);
        }
        // Every duplicate arrives with the *previous* request's id and
        // must be skipped, not returned to the caller.
        assert!(metrics.get("session.stale_replies") > 0);
    }

    #[test]
    fn busy_reply_becomes_rejected_after_retries() {
        let metrics = Arc::new(Registry::new());
        let (client, mut server) = InProcTransport::pair();
        std::thread::spawn(move || {
            while let Ok(f) = server.recv() {
                let kind = FrameKind::Busy { retry_after_ms: 1, message: "inflight cap".into() };
                let _ = server.send(&Frame::new(f.request_id, kind));
            }
        });
        let cfg = SessionConfig { max_retries: 2, ..fast_cfg() };
        let mut s = Session::new(client, cfg).with_metrics(Arc::clone(&metrics));
        let err = s.call(FrameKind::Ping).unwrap_err();
        assert!(matches!(err, Error::Rejected { .. }), "{err}");
        assert_eq!(metrics.get("session.shed_total"), 3, "initial attempt + 2 retries");
    }

    /// Responder pinned to an active model version: answers Pong only
    /// when the request declares exactly that version, VersionSkew
    /// otherwise (the cloud node's pre-admission check in miniature).
    fn versioned_responder(mut server: impl Transport + Send + 'static, active: u64) {
        std::thread::spawn(move || {
            while let Ok(f) = server.recv() {
                let kind = match f.model_version {
                    Some(v) if v == active => FrameKind::Pong,
                    offered => FrameKind::VersionSkew {
                        active,
                        offered: offered.unwrap_or(0),
                        message: "serving a different deployment".into(),
                    },
                };
                let _ = server.send(&Frame::new(f.request_id, kind));
            }
        });
    }

    #[test]
    fn skew_without_resync_hook_is_fatal_not_retried() {
        let metrics = Arc::new(Registry::new());
        let (client, server) = InProcTransport::pair();
        versioned_responder(server, 5);
        let mut s = Session::new(client, fast_cfg())
            .with_metrics(Arc::clone(&metrics))
            .with_model_version(3);
        let err = s.call(FrameKind::Ping).unwrap_err();
        assert!(matches!(err, Error::VersionSkew { active: 5, offered: 3, .. }), "{err}");
        assert!(!err.is_retryable());
        assert_eq!(metrics.get("session.skew_total"), 1);
        assert_eq!(metrics.get("session.retry_total"), 0, "skew must not burn retries");
    }

    #[test]
    fn resync_hook_recovers_within_one_call() {
        let metrics = Arc::new(Registry::new());
        let (client, server) = InProcTransport::pair();
        versioned_responder(server, 5);
        let mut s = Session::new(client, fast_cfg())
            .with_metrics(Arc::clone(&metrics))
            .with_model_version(3)
            .with_resync(Box::new(|active| Ok(active)));
        let reply = s.call(FrameKind::Ping).unwrap();
        assert_eq!(reply.kind, FrameKind::Pong);
        assert_eq!(s.model_version(), Some(5), "session re-pinned to the server's version");
        assert_eq!(metrics.get("session.skew_total"), 1);
        assert_eq!(metrics.get("session.resync_total"), 1);
        // Subsequent calls are already in sync: no further skew.
        s.call(FrameKind::Ping).unwrap();
        assert_eq!(metrics.get("session.skew_total"), 1);
    }

    #[test]
    fn second_skew_in_same_call_is_fatal() {
        let metrics = Arc::new(Registry::new());
        let (client, server) = InProcTransport::pair();
        versioned_responder(server, 5);
        // A broken registry mirror hands back yet another stale version:
        // the session must not resync-loop forever.
        let mut s = Session::new(client, fast_cfg())
            .with_metrics(Arc::clone(&metrics))
            .with_model_version(3)
            .with_resync(Box::new(|_active| Ok(4)));
        let err = s.call(FrameKind::Ping).unwrap_err();
        assert!(matches!(err, Error::VersionSkew { active: 5, offered: 4, .. }), "{err}");
        assert_eq!(metrics.get("session.skew_total"), 2);
        assert_eq!(metrics.get("session.resync_total"), 1);
    }

    #[test]
    fn failed_resync_surfaces_the_registry_error() {
        let (client, server) = InProcTransport::pair();
        versioned_responder(server, 9);
        let mut s = Session::new(client, fast_cfg())
            .with_model_version(1)
            .with_resync(Box::new(|_| Err(Error::artifact("registry unreachable"))));
        let err = s.call(FrameKind::Ping).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
    }

    #[test]
    fn legacy_session_sends_no_version_header() {
        let (client, mut server) = InProcTransport::pair();
        std::thread::spawn(move || {
            while let Ok(f) = server.recv() {
                assert!(f.model_version.is_none(), "unpinned session leaked a version header");
                let _ = server.send(&Frame::new(f.request_id, FrameKind::Pong));
            }
        });
        let mut s = Session::new(client, fast_cfg());
        assert_eq!(s.model_version(), None);
        let reply = s.call(FrameKind::Ping).unwrap();
        assert_eq!(reply.kind, FrameKind::Pong);
    }

    #[test]
    fn deadline_exhaustion_is_a_clean_timeout() {
        let (client, server) = InProcTransport::pair();
        // Server never answers; drop it so nothing replies but the
        // channel stays open via the responder-less pair.
        let cfg = SessionConfig {
            deadline_ms: 60,
            try_timeout_ms: 25,
            max_retries: 100,
            base_backoff_ms: 1,
            max_backoff_ms: 2,
            heartbeat_ms: 0,
            seed: 1,
        };
        let mut s = Session::new(client, cfg);
        let t0 = Instant::now();
        let err = s.call(FrameKind::Ping).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline must bound the wait");
        drop(server);
    }

    #[test]
    fn reconnects_through_connector_after_peer_death() {
        let metrics = Arc::new(Registry::new());
        // First transport's peer is dropped immediately → dead link.
        let (client, server) = InProcTransport::pair();
        drop(server);
        let mut s = Session::new(client, fast_cfg())
            .with_metrics(Arc::clone(&metrics))
            .with_connector(Box::new(|| {
                let (c, srv) = InProcTransport::pair();
                pong_responder(srv);
                Ok(c)
            }));
        let reply = s.call(FrameKind::Ping).unwrap();
        assert_eq!(reply.kind, FrameKind::Pong);
        assert!(metrics.get("session.reconnect_total") >= 1);
        assert!(metrics.get("session.retry_total") >= 1);
    }

    #[test]
    fn heartbeat_probe_replaces_dead_connection() {
        let metrics = Arc::new(Registry::new());
        let (client, server) = InProcTransport::pair();
        drop(server); // connection dies while the session is idle
        let cfg = SessionConfig { heartbeat_ms: 1, ..fast_cfg() };
        let mut s = Session::new(client, cfg)
            .with_metrics(Arc::clone(&metrics))
            .with_connector(Box::new(|| {
                let (c, srv) = InProcTransport::pair();
                pong_responder(srv);
                Ok(c)
            }));
        std::thread::sleep(Duration::from_millis(5));
        let reply = s.call(FrameKind::Ping).unwrap();
        assert_eq!(reply.kind, FrameKind::Pong);
        assert!(metrics.get("session.reconnect_total") >= 1);
    }

    #[test]
    fn degrade_steps_down_then_recovers() {
        let policy = DegradePolicy {
            timeouts_to_degrade: 2,
            q_step: 2,
            q_floor: 2,
            successes_to_recover: 3,
            raw_fallback: true,
        };
        let mut st = DegradeState::new(policy, 8);
        assert_eq!(st.effective_q(), 8);
        assert!(!st.degraded());

        // Two consecutive failures → one step down.
        assert_eq!(st.on_retryable_failure(), DegradeEvent::None);
        assert_eq!(st.on_retryable_failure(), DegradeEvent::SteppedDown(6));
        // A success in between resets the failure streak.
        assert_eq!(st.on_success(), DegradeEvent::None);
        assert_eq!(st.on_retryable_failure(), DegradeEvent::None);
        assert_eq!(st.on_retryable_failure(), DegradeEvent::SteppedDown(4));
        // Down to the floor, then raw fallback as the last resort.
        st.on_retryable_failure();
        assert_eq!(st.on_retryable_failure(), DegradeEvent::SteppedDown(2));
        assert_eq!(st.effective_q(), 2);
        st.on_retryable_failure();
        assert_eq!(st.on_retryable_failure(), DegradeEvent::RawFallback);
        assert!(st.raw_mode());
        // Recovery: raw mode exits first, then Q climbs back to base.
        st.on_success();
        st.on_success();
        assert_eq!(st.on_success(), DegradeEvent::Recovered(2));
        assert!(!st.raw_mode());
        for _ in 0..2 {
            st.on_success();
            st.on_success();
            st.on_success();
        }
        assert_eq!(st.effective_q(), 6);
        st.on_success();
        st.on_success();
        assert_eq!(st.on_success(), DegradeEvent::Recovered(8));
        assert_eq!(st.effective_q(), 8);
        assert!(!st.degraded());
    }

    #[test]
    fn degrade_floor_never_undershoots() {
        let policy = DegradePolicy {
            timeouts_to_degrade: 1,
            q_step: 3,
            q_floor: 2,
            successes_to_recover: 1,
            raw_fallback: false,
        };
        let mut st = DegradeState::new(policy, 4);
        assert_eq!(st.on_retryable_failure(), DegradeEvent::SteppedDown(2));
        // At the floor with raw fallback disabled: nothing more to shed.
        assert_eq!(st.on_retryable_failure(), DegradeEvent::None);
        assert_eq!(st.effective_q(), 2);
        // Recovery never overshoots the base.
        assert_eq!(st.on_success(), DegradeEvent::Recovered(4));
        assert_eq!(st.effective_q(), 4);
        assert_eq!(st.on_success(), DegradeEvent::None);
    }
}
