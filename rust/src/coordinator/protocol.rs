//! Wire protocol between edge and cloud nodes.
//!
//! Frames are length-prefixed (u32 LE) and CRC-checked:
//!
//! ```text
//! [u32 body_len] [body] [u32 crc32(body)]
//! body = [u64 request_id] [u8 kind] [kind-specific fields]
//! ```
//!
//! Strings are varint-length-prefixed UTF-8; byte blobs are
//! varint-length-prefixed. The compressed IF payload is the
//! self-describing pipeline container (including its dtype tag), so the
//! cloud side needs no per-request metadata beyond the model route.
//!
//! Raw (uncompressed) frames carry a one-byte element-type tag
//! ([`Dtype::tag`]) ahead of the payload. When that byte was added the
//! raw frame tags were retired and reissued (3 → 11, 5 → 12), so a
//! mixed-version edge/cloud pair fails with an explicit
//! "peer predates dtype tagging" error instead of misparsing the
//! shifted body.
//!
//! Additive, version-gated extensions ride the same tag discipline:
//!
//! * **Deadline header (tag 13)** — a request may carry its remaining
//!   latency budget. On the wire the header *wraps* the kind:
//!   `[u64 request_id] [13] [u32 deadline_ms] [u8 kind] [fields]`.
//!   Frames without a deadline encode byte-identically to every earlier
//!   release; a pre-deadline peer receiving tag 13 fails with its
//!   explicit "unknown frame tag" error rather than misparsing.
//! * **[`FrameKind::Busy`] (tag 14)** — the explicit load-shed reply:
//!   the cloud's bounded queues refuse work they provably cannot finish
//!   inside the deadline and hint when to retry.
//! * **Model-version header (tag 15)** — the registry handshake: a
//!   request may declare which `model_version` its features were
//!   produced by (`[15] [u64 version]`, wrapping the kind like tag 13;
//!   headers parse in any order, duplicates rejected). Absent = legacy
//!   wire, byte-identical.
//! * **[`FrameKind::VersionSkew`] (tag 16)** — the cloud's reply when a
//!   declared version does not match its active deployment: fatal until
//!   the edge resyncs from the registry, never a silent decode with the
//!   wrong tail.
//! * **Registry delta-sync frames (tags 17–20)** — chunk-level model
//!   distribution over the same transport: [`FrameKind::FetchManifest`]
//!   / [`FrameKind::ManifestReply`] move the *signed* manifest text
//!   (the client verifies the HMAC itself — the wire is untrusted), and
//!   [`FrameKind::FetchChunk`] / [`FrameKind::ChunkReply`] move one
//!   content-addressed chunk payload (the client re-hashes the payload
//!   against the requested address before storing it). A pre-delta peer
//!   receiving any of these fails with its explicit "unknown frame tag"
//!   error.

use crate::error::{Error, Result};
use crate::tensor::Dtype;
use crate::util::{crc32, varint};

/// Maximum accepted frame body (64 MiB) — guards the allocator against
/// corrupt length prefixes.
pub const MAX_FRAME: usize = 64 << 20;

/// Body tag of the optional deadline header that wraps a frame's kind.
const DEADLINE_TAG: u8 = 13;

/// Body tag of the optional model-version header (registry handshake).
const MODEL_VERSION_TAG: u8 = 15;

/// Frame payload kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum FrameKind {
    /// Liveness probe.
    Ping,
    /// Probe reply.
    Pong,
    /// Vision inference: compressed IF container for `(model, sl, batch)`.
    InferVision {
        /// Manifest model name.
        model: String,
        /// Split layer.
        sl: usize,
        /// Batch the artifact was compiled for.
        batch: usize,
        /// Pipeline container bytes.
        payload: Vec<u8>,
    },
    /// Vision inference, uncompressed baseline: raw feature bytes of
    /// the declared element type.
    InferVisionRaw {
        /// Manifest model name.
        model: String,
        /// Split layer.
        sl: usize,
        /// Batch.
        batch: usize,
        /// Element type of `payload` (f32 for the classic baseline;
        /// f16/bf16 halve the raw link bytes for half-precision heads).
        dtype: Dtype,
        /// Little-endian feature tensor.
        payload: Vec<u8>,
    },
    /// LM inference: compressed hidden-state container. The container
    /// is self-describing (including its dtype tag), so no per-request
    /// metadata rides here.
    InferLm {
        /// Manifest model name.
        model: String,
        /// Pipeline container bytes.
        payload: Vec<u8>,
    },
    /// LM inference, uncompressed baseline.
    InferLmRaw {
        /// Manifest model name.
        model: String,
        /// Element type of `payload` — bf16 is the Llama2-style wire
        /// format for raw hidden states.
        dtype: Dtype,
        /// Little-endian hidden states.
        payload: Vec<u8>,
    },
    /// Successful inference reply: logits plus the cloud-side latency
    /// factors (iii) decode and (iv) tail compute, so the edge can
    /// assemble the paper's full four-factor breakdown.
    Logits {
        /// Row-major logits.
        data: Vec<f32>,
        /// Cloud decode time, ms.
        decode_ms: f32,
        /// Device transfer + tail compute time, ms.
        compute_ms: f32,
    },
    /// Request the cloud node's metrics snapshot.
    Stats,
    /// Metrics snapshot reply (JSON).
    StatsReply {
        /// JSON text.
        json: String,
    },
    /// Orderly shutdown of the serving loop.
    Shutdown,
    /// Error reply.
    ServerError {
        /// Human-readable message.
        message: String,
    },
    /// Explicit load-shed reply: the server's bounded queues cannot meet
    /// the request's deadline (or are full) and the edge should back off
    /// for at least `retry_after_ms` before retrying. Distinct from
    /// [`FrameKind::ServerError`] so the session layer can classify it
    /// as retryable without string matching.
    Busy {
        /// Suggested backoff before retrying, milliseconds.
        retry_after_ms: u32,
        /// Human-readable shed reason.
        message: String,
    },
    /// Model-version mismatch reply: the request declared a
    /// `model_version` (tag-15 header) the server is not serving. Fatal
    /// until the edge resyncs from the registry — decoding features
    /// against the wrong tail would silently produce garbage logits, so
    /// the server refuses before admission. Distinct from
    /// [`FrameKind::ServerError`] so the session layer maps it onto
    /// [`crate::error::Error::VersionSkew`] without string matching.
    VersionSkew {
        /// The server's currently active model version.
        active: u64,
        /// The version the request declared and the server rejected.
        offered: u64,
        /// Human-readable context.
        message: String,
    },
    /// Request a model's signed manifest from a registry-serving peer.
    FetchManifest {
        /// Manifest model name.
        model: String,
        /// Version slot to fetch; `0` means "latest published".
        version: u64,
    },
    /// Signed-manifest reply: the exact `SignedManifest` wrapper text.
    /// The requester verifies the signature and parses the inner
    /// document itself — nothing served over the wire is trusted.
    ManifestReply {
        /// SignedManifest wrapper JSON.
        json: String,
    },
    /// Request one content-addressed chunk payload by SHA-256 address.
    FetchChunk {
        /// Lowercase hex SHA-256 address of the chunk payload.
        sha256: String,
    },
    /// Chunk payload reply. Carries the raw payload only — the
    /// requester recomputes SHA-256 and rejects the reply if it does
    /// not match the address it asked for, so a tampering server (or
    /// link) cannot poison the local store.
    ChunkReply {
        /// Raw chunk payload bytes.
        payload: Vec<u8>,
    },
}

/// One framed message.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Correlates replies with requests.
    pub request_id: u64,
    /// Remaining end-to-end latency budget of the request, milliseconds
    /// (`None` = no deadline; encodes byte-identically to the
    /// pre-deadline wire format). Attached by the session layer so the
    /// cloud's admission control can shed provably unmeetable work.
    pub deadline_ms: Option<u32>,
    /// Model version the request's features were produced against
    /// (`None` = legacy wire, byte-identical to the pre-registry
    /// format). Attached by the session layer; a server pinned to a
    /// different version answers [`FrameKind::VersionSkew`] instead of
    /// decoding against the wrong tail.
    pub model_version: Option<u64>,
    /// Payload.
    pub kind: FrameKind,
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    varint::write_usize(buf, s.len());
    buf.extend_from_slice(s.as_bytes());
}

fn read_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = varint::read_usize(buf, pos)?;
    let end = pos.checked_add(len).filter(|&e| e <= buf.len())
        .ok_or_else(|| Error::protocol("string truncated"))?;
    let s = std::str::from_utf8(&buf[*pos..end])
        .map_err(|_| Error::protocol("invalid utf-8"))?
        .to_string();
    *pos = end;
    Ok(s)
}

fn write_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    varint::write_usize(buf, b.len());
    buf.extend_from_slice(b);
}

fn read_dtype(buf: &[u8], pos: &mut usize) -> Result<Dtype> {
    let tag = *buf
        .get(*pos)
        .ok_or_else(|| Error::protocol("dtype tag truncated"))?;
    *pos += 1;
    Dtype::from_tag(tag).map_err(|_| Error::protocol(format!("bad dtype tag {tag}")))
}

fn read_bytes(buf: &[u8], pos: &mut usize) -> Result<Vec<u8>> {
    let len = varint::read_usize(buf, pos)?;
    let end = pos.checked_add(len).filter(|&e| e <= buf.len())
        .ok_or_else(|| Error::protocol("bytes truncated"))?;
    let out = buf[*pos..end].to_vec();
    *pos = end;
    Ok(out)
}

impl Frame {
    /// A frame with no optional headers (byte-identical to the
    /// pre-deadline, pre-registry wire format).
    pub fn new(request_id: u64, kind: FrameKind) -> Self {
        Frame { request_id, deadline_ms: None, model_version: None, kind }
    }

    /// Attach a deadline header (remaining budget in milliseconds).
    pub fn with_deadline(mut self, deadline_ms: u32) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Attach a model-version header (registry handshake).
    pub fn with_model_version(mut self, model_version: u64) -> Self {
        self.model_version = Some(model_version);
        self
    }

    fn write_kind(kind: &FrameKind, body: &mut Vec<u8>) {
        match kind {
            FrameKind::Ping => body.push(0),
            FrameKind::Pong => body.push(1),
            FrameKind::InferVision { model, sl, batch, payload } => {
                body.push(2);
                write_str(body, model);
                varint::write_usize(body, *sl);
                varint::write_usize(body, *batch);
                write_bytes(body, payload);
            }
            FrameKind::InferVisionRaw { model, sl, batch, dtype, payload } => {
                body.push(11);
                write_str(body, model);
                varint::write_usize(body, *sl);
                varint::write_usize(body, *batch);
                body.push(dtype.tag());
                write_bytes(body, payload);
            }
            FrameKind::InferLm { model, payload } => {
                body.push(4);
                write_str(body, model);
                write_bytes(body, payload);
            }
            FrameKind::InferLmRaw { model, dtype, payload } => {
                body.push(12);
                write_str(body, model);
                body.push(dtype.tag());
                write_bytes(body, payload);
            }
            FrameKind::Logits { data, decode_ms, compute_ms } => {
                body.push(6);
                body.extend_from_slice(&decode_ms.to_le_bytes());
                body.extend_from_slice(&compute_ms.to_le_bytes());
                varint::write_usize(body, data.len());
                for &x in data {
                    body.extend_from_slice(&x.to_le_bytes());
                }
            }
            FrameKind::Stats => body.push(7),
            FrameKind::StatsReply { json } => {
                body.push(8);
                write_str(body, json);
            }
            FrameKind::Shutdown => body.push(9),
            FrameKind::ServerError { message } => {
                body.push(10);
                write_str(body, message);
            }
            FrameKind::Busy { retry_after_ms, message } => {
                body.push(14);
                body.extend_from_slice(&retry_after_ms.to_le_bytes());
                write_str(body, message);
            }
            FrameKind::VersionSkew { active, offered, message } => {
                body.push(16);
                body.extend_from_slice(&active.to_le_bytes());
                body.extend_from_slice(&offered.to_le_bytes());
                write_str(body, message);
            }
            FrameKind::FetchManifest { model, version } => {
                body.push(17);
                write_str(body, model);
                body.extend_from_slice(&version.to_le_bytes());
            }
            FrameKind::ManifestReply { json } => {
                body.push(18);
                write_str(body, json);
            }
            FrameKind::FetchChunk { sha256 } => {
                body.push(19);
                write_str(body, sha256);
            }
            FrameKind::ChunkReply { payload } => {
                body.push(20);
                write_bytes(body, payload);
            }
        }
    }

    /// Serialize to the on-wire representation (length prefix + crc).
    pub fn to_wire(&self) -> Vec<u8> {
        let mut body = Vec::new();
        body.extend_from_slice(&self.request_id.to_le_bytes());
        if let Some(deadline) = self.deadline_ms {
            body.push(DEADLINE_TAG);
            body.extend_from_slice(&deadline.to_le_bytes());
        }
        if let Some(version) = self.model_version {
            body.push(MODEL_VERSION_TAG);
            body.extend_from_slice(&version.to_le_bytes());
        }
        Self::write_kind(&self.kind, &mut body);
        let crc = crc32::hash(&body);
        let mut out = Vec::with_capacity(body.len() + 8);
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse a frame body (after length prefix and CRC have been
    /// stripped/validated by the transport).
    pub fn from_body(body: &[u8]) -> Result<Self> {
        if body.len() < 9 {
            return Err(Error::protocol("frame body too short"));
        }
        let request_id = u64::from_le_bytes(body[0..8].try_into().unwrap());
        let mut pos = 8usize;
        let mut deadline_ms = None;
        let mut model_version = None;
        // Optional headers wrap the kind and may appear in either order
        // (a peer is free to reorder); duplicates are a framing error.
        let tag = loop {
            let tag = *body
                .get(pos)
                .ok_or_else(|| Error::protocol("frame body too short"))?;
            pos += 1;
            match tag {
                DEADLINE_TAG => {
                    if deadline_ms.is_some() {
                        return Err(Error::protocol("nested deadline header"));
                    }
                    if pos + 4 > body.len() {
                        return Err(Error::protocol("deadline header truncated"));
                    }
                    deadline_ms =
                        Some(u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap()));
                    pos += 4;
                }
                MODEL_VERSION_TAG => {
                    if model_version.is_some() {
                        return Err(Error::protocol("nested model-version header"));
                    }
                    if pos + 8 > body.len() {
                        return Err(Error::protocol("model-version header truncated"));
                    }
                    model_version =
                        Some(u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap()));
                    pos += 8;
                }
                other => break other,
            }
        };
        let kind = match tag {
            0 => FrameKind::Ping,
            1 => FrameKind::Pong,
            2 => {
                let model = read_str(body, &mut pos)?;
                let sl = varint::read_usize(body, &mut pos)?;
                let batch = varint::read_usize(body, &mut pos)?;
                let payload = read_bytes(body, &mut pos)?;
                FrameKind::InferVision { model, sl, batch, payload }
            }
            11 => {
                let model = read_str(body, &mut pos)?;
                let sl = varint::read_usize(body, &mut pos)?;
                let batch = varint::read_usize(body, &mut pos)?;
                let dtype = read_dtype(body, &mut pos)?;
                let payload = read_bytes(body, &mut pos)?;
                FrameKind::InferVisionRaw { model, sl, batch, dtype, payload }
            }
            4 => {
                let model = read_str(body, &mut pos)?;
                let payload = read_bytes(body, &mut pos)?;
                FrameKind::InferLm { model, payload }
            }
            12 => {
                let model = read_str(body, &mut pos)?;
                let dtype = read_dtype(body, &mut pos)?;
                let payload = read_bytes(body, &mut pos)?;
                FrameKind::InferLmRaw { model, dtype, payload }
            }
            // The pre-dtype raw-frame tags: rejected explicitly so a
            // mixed-version edge/cloud pair fails with a clear message
            // instead of misparsing the shifted body.
            3 | 5 => {
                return Err(Error::protocol(
                    "raw frame from a peer that predates dtype tagging \
                     (frame tags 3/5 were retired; upgrade the peer)",
                ))
            }
            6 => {
                if pos + 8 > body.len() {
                    return Err(Error::protocol("logits header truncated"));
                }
                let decode_ms = f32::from_le_bytes(body[pos..pos + 4].try_into().unwrap());
                let compute_ms = f32::from_le_bytes(body[pos + 4..pos + 8].try_into().unwrap());
                pos += 8;
                let n = varint::read_usize(body, &mut pos)?;
                let need = pos + n * 4;
                if need > body.len() {
                    return Err(Error::protocol("logits truncated"));
                }
                let mut data = Vec::with_capacity(n);
                for chunk in body[pos..need].chunks_exact(4) {
                    data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
                }
                pos = need;
                FrameKind::Logits { data, decode_ms, compute_ms }
            }
            7 => FrameKind::Stats,
            8 => FrameKind::StatsReply { json: read_str(body, &mut pos)? },
            9 => FrameKind::Shutdown,
            10 => FrameKind::ServerError { message: read_str(body, &mut pos)? },
            14 => {
                if pos + 4 > body.len() {
                    return Err(Error::protocol("busy header truncated"));
                }
                let retry_after_ms = u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap());
                pos += 4;
                FrameKind::Busy { retry_after_ms, message: read_str(body, &mut pos)? }
            }
            16 => {
                if pos + 16 > body.len() {
                    return Err(Error::protocol("version-skew body truncated"));
                }
                let active = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap());
                let offered = u64::from_le_bytes(body[pos + 8..pos + 16].try_into().unwrap());
                pos += 16;
                FrameKind::VersionSkew { active, offered, message: read_str(body, &mut pos)? }
            }
            17 => {
                let model = read_str(body, &mut pos)?;
                if pos + 8 > body.len() {
                    return Err(Error::protocol("fetch-manifest version truncated"));
                }
                let version = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap());
                pos += 8;
                FrameKind::FetchManifest { model, version }
            }
            18 => FrameKind::ManifestReply { json: read_str(body, &mut pos)? },
            19 => FrameKind::FetchChunk { sha256: read_str(body, &mut pos)? },
            20 => FrameKind::ChunkReply { payload: read_bytes(body, &mut pos)? },
            t => return Err(Error::protocol(format!("unknown frame tag {t}"))),
        };
        if pos != body.len() {
            return Err(Error::protocol("trailing bytes in frame"));
        }
        Ok(Frame { request_id, deadline_ms, model_version, kind })
    }

    /// Parse a full wire message (length prefix + body + crc). Returns
    /// the frame and the total bytes consumed.
    pub fn from_wire(buf: &[u8]) -> Result<(Self, usize)> {
        if buf.len() < 8 {
            return Err(Error::protocol("wire message too short"));
        }
        let body_len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        if body_len > MAX_FRAME {
            return Err(Error::protocol(format!("frame of {body_len} bytes exceeds cap")));
        }
        let total = 4 + body_len + 4;
        if buf.len() < total {
            return Err(Error::protocol("wire message truncated"));
        }
        let body = &buf[4..4 + body_len];
        let crc = u32::from_le_bytes(buf[4 + body_len..total].try_into().unwrap());
        // CRC failure is the *corruption* class (fatal by default): on a
        // reliable byte stream garbled framing means an implementation
        // bug, not a link fault. Lossy transports that CAN garble bytes
        // in flight (`FaultyTransport`) reclassify at their framing
        // boundary, where a resend genuinely helps.
        if crc32::hash(body) != crc {
            return Err(Error::corrupt("frame crc mismatch"));
        }
        Ok((Self::from_body(body)?, total))
    }

    /// The payload size relevant for channel simulation (bytes that
    /// would cross the wireless link).
    pub fn payload_len(&self) -> usize {
        match &self.kind {
            FrameKind::InferVision { payload, .. }
            | FrameKind::InferVisionRaw { payload, .. }
            | FrameKind::InferLm { payload, .. }
            | FrameKind::InferLmRaw { payload, .. } => payload.len(),
            FrameKind::Logits { data, .. } => data.len() * 4,
            FrameKind::StatsReply { json } => json.len(),
            FrameKind::ManifestReply { json } => json.len(),
            FrameKind::ChunkReply { payload } => payload.len(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(kind: FrameKind) {
        let f = Frame::new(77, kind.clone());
        let wire = f.to_wire();
        let (back, used) = Frame::from_wire(&wire).unwrap();
        assert_eq!(used, wire.len());
        assert_eq!(back, f);
        // The same kind wrapped in a deadline header roundtrips too.
        let f = Frame::new(78, kind.clone()).with_deadline(12_345);
        let (back, _) = Frame::from_wire(&f.to_wire()).unwrap();
        assert_eq!(back, f);
        // And with a model-version header, alone and alongside the
        // deadline header.
        let f = Frame::new(79, kind.clone()).with_model_version(u64::MAX);
        let (back, _) = Frame::from_wire(&f.to_wire()).unwrap();
        assert_eq!(back, f);
        let f = Frame::new(80, kind).with_deadline(250).with_model_version(3);
        let (back, _) = Frame::from_wire(&f.to_wire()).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn roundtrip_all_kinds() {
        roundtrip(FrameKind::Ping);
        roundtrip(FrameKind::Pong);
        roundtrip(FrameKind::InferVision {
            model: "resnet_mini_synth_a".into(),
            sl: 2,
            batch: 1,
            payload: vec![1, 2, 3, 255],
        });
        roundtrip(FrameKind::InferVisionRaw {
            model: "m".into(),
            sl: 4,
            batch: 8,
            dtype: Dtype::F32,
            payload: vec![],
        });
        roundtrip(FrameKind::InferLm { model: "llama_mini_s".into(), payload: vec![9; 100] });
        for dtype in [Dtype::F32, Dtype::F16, Dtype::Bf16] {
            roundtrip(FrameKind::InferLmRaw {
                model: "llama_mini_m".into(),
                dtype,
                payload: vec![0, 1, 2, 3],
            });
        }
        roundtrip(FrameKind::Logits {
            data: vec![1.5, -2.5, f32::MIN, f32::MAX],
            decode_ms: 0.25,
            compute_ms: 1.5,
        });
        roundtrip(FrameKind::Stats);
        roundtrip(FrameKind::StatsReply { json: "{\"a\":1}".into() });
        roundtrip(FrameKind::Shutdown);
        roundtrip(FrameKind::ServerError { message: "boom".into() });
        roundtrip(FrameKind::Busy { retry_after_ms: 25, message: "inflight cap".into() });
        roundtrip(FrameKind::VersionSkew {
            active: 7,
            offered: 3,
            message: "resync from registry".into(),
        });
        roundtrip(FrameKind::FetchManifest { model: "resnet_mini_synth_a".into(), version: 0 });
        roundtrip(FrameKind::FetchManifest { model: "m".into(), version: u64::MAX });
        roundtrip(FrameKind::ManifestReply { json: "{\"algo\":\"hmac-sha256\"}".into() });
        roundtrip(FrameKind::FetchChunk { sha256: "ab".repeat(32) });
        roundtrip(FrameKind::ChunkReply { payload: vec![] });
        roundtrip(FrameKind::ChunkReply { payload: vec![0xA5; 4096] });
    }

    #[test]
    fn truncated_fetch_manifest_version_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(17);
        varint::write_usize(&mut body, 1);
        body.push(b'm');
        body.extend_from_slice(&[0u8, 0, 0]); // only 3 of 8 version bytes
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("fetch-manifest version truncated"), "{err}");
    }

    #[test]
    fn delta_sync_frames_bitflip_wall() {
        // Every single-bit flip anywhere in a delta-sync frame must be
        // rejected (CRC or field validation), same wall the inference
        // frames get.
        for kind in [
            FrameKind::FetchManifest { model: "m".into(), version: 3 },
            FrameKind::ManifestReply { json: "{\"k\":1}".into() },
            FrameKind::FetchChunk { sha256: "cd".repeat(32) },
            FrameKind::ChunkReply { payload: vec![7; 33] },
        ] {
            let wire = Frame::new(11, kind).to_wire();
            for i in 4..wire.len() {
                let mut bad = wire.clone();
                bad[i] ^= 0x01;
                assert!(Frame::from_wire(&bad).is_err(), "flip at {i}");
            }
        }
    }

    #[test]
    fn delta_sync_tags_are_unknown_to_a_pre_delta_parser_shape() {
        // The additive-tag discipline: tag 21 (one past ChunkReply) is
        // still a loud unknown, proving new tags didn't widen the
        // accepted set beyond what was assigned.
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(21);
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("unknown frame tag 21"), "{err}");
    }

    #[test]
    fn chunk_reply_payload_counts_as_link_bytes() {
        let f = Frame::new(0, FrameKind::ChunkReply { payload: vec![0; 777] });
        assert_eq!(f.payload_len(), 777);
        let f = Frame::new(0, FrameKind::ManifestReply { json: "x".repeat(20) });
        assert_eq!(f.payload_len(), 20);
        let f = Frame::new(0, FrameKind::FetchChunk { sha256: "ab".repeat(32) });
        assert_eq!(f.payload_len(), 0);
    }

    #[test]
    fn no_deadline_is_byte_identical_to_pre_deadline_format() {
        // `deadline_ms: None` must not change a single wire byte: the
        // old format is [u32 len][u64 id][u8 kind][crc], so a Ping body
        // is exactly 9 bytes with tag 0 at offset 12.
        let wire = Frame::new(5, FrameKind::Ping).to_wire();
        assert_eq!(wire.len(), 4 + 9 + 4);
        assert_eq!(u32::from_le_bytes(wire[0..4].try_into().unwrap()), 9);
        assert_eq!(wire[12], 0);
        // With a deadline the body grows by exactly the 5-byte header.
        let wire = Frame::new(5, FrameKind::Ping).with_deadline(250).to_wire();
        assert_eq!(wire.len(), 4 + 14 + 4);
        assert_eq!(wire[12], 13);
        assert_eq!(u32::from_le_bytes(wire[13..17].try_into().unwrap()), 250);
    }

    #[test]
    fn no_model_version_is_byte_identical_to_pre_registry_format() {
        // `model_version: None` must not change a single wire byte.
        let wire = Frame::new(5, FrameKind::Ping).to_wire();
        assert_eq!(wire.len(), 4 + 9 + 4);
        // With a version the body grows by exactly the 9-byte header.
        let wire = Frame::new(5, FrameKind::Ping).with_model_version(42).to_wire();
        assert_eq!(wire.len(), 4 + 18 + 4);
        assert_eq!(wire[12], 15);
        assert_eq!(u64::from_le_bytes(wire[13..21].try_into().unwrap()), 42);
        assert_eq!(wire[21], 0, "kind tag follows the header");
    }

    #[test]
    fn headers_parse_in_either_order() {
        // We always emit deadline-then-version, but a peer may reorder;
        // hand-build the opposite order and check it parses to the same
        // frame.
        let mut body = Vec::new();
        body.extend_from_slice(&9u64.to_le_bytes());
        body.push(15);
        body.extend_from_slice(&4u64.to_le_bytes());
        body.push(13);
        body.extend_from_slice(&777u32.to_le_bytes());
        body.push(1); // Pong
        let f = Frame::from_body(&body).unwrap();
        assert_eq!(f, Frame::new(9, FrameKind::Pong).with_deadline(777).with_model_version(4));
    }

    #[test]
    fn nested_model_version_header_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        for _ in 0..2 {
            body.push(15);
            body.extend_from_slice(&2u64.to_le_bytes());
        }
        body.push(0);
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("nested model-version"), "{err}");
    }

    #[test]
    fn truncated_model_version_header_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(15);
        body.extend_from_slice(&[0u8, 0, 0]); // only 3 of the 8 version bytes
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("model-version header truncated"), "{err}");
    }

    #[test]
    fn truncated_version_skew_body_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(16);
        body.extend_from_slice(&5u64.to_le_bytes()); // active only, offered missing
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("version-skew body truncated"), "{err}");
    }

    #[test]
    fn headers_without_kind_rejected() {
        // A body that ends after the headers (no kind tag) must be a
        // loud truncation error, not a panic or silent default.
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(15);
        body.extend_from_slice(&2u64.to_le_bytes());
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("frame body too short"), "{err}");
    }

    #[test]
    fn nested_deadline_header_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(13);
        body.extend_from_slice(&100u32.to_le_bytes());
        body.push(13); // a second deadline header where the kind belongs
        body.extend_from_slice(&100u32.to_le_bytes());
        body.push(0);
        let err = Frame::from_body(&body).unwrap_err();
        assert!(err.to_string().contains("nested deadline"), "{err}");
    }

    #[test]
    fn truncated_deadline_header_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(13);
        body.extend_from_slice(&[0u8, 0]); // only 2 of the 5 header bytes
        assert!(Frame::from_body(&body).is_err());
    }

    #[test]
    fn crc_mismatch_classifies_as_fatal_corruption() {
        let mut wire = Frame::new(1, FrameKind::Ping).to_wire();
        let last = wire.len() - 1;
        wire[last] ^= 0xFF; // break the CRC, keep the body parseable
        let err = Frame::from_wire(&wire).unwrap_err();
        assert!(matches!(err, crate::error::Error::Corrupt(_)), "{err}");
        assert!(!err.is_retryable());
    }

    #[test]
    fn crc_detects_flips() {
        let f = Frame::new(
            1,
            FrameKind::InferVision {
                model: "m".into(),
                sl: 1,
                batch: 1,
                payload: vec![7; 64],
            },
        );
        let wire = f.to_wire();
        for i in 4..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 0x01;
            assert!(Frame::from_wire(&bad).is_err(), "flip at {i}");
        }
    }

    #[test]
    fn bad_raw_dtype_tag_rejected() {
        let f = Frame::new(
            3,
            FrameKind::InferLmRaw {
                model: "m".into(),
                dtype: Dtype::Bf16,
                payload: vec![1, 2],
            },
        );
        let mut wire = f.to_wire();
        // The dtype byte sits right after the varint-framed model name;
        // corrupt it to an unknown tag and refresh the CRC so only the
        // dtype validation can object.
        let body_len = u32::from_le_bytes(wire[0..4].try_into().unwrap()) as usize;
        let dtype_pos = 4 + 8 + 1 + 1 + 1; // len prefix + id + kind + strlen + "m"
        assert_eq!(wire[dtype_pos], Dtype::Bf16.tag());
        wire[dtype_pos] = 9;
        let crc = crc32::hash(&wire[4..4 + body_len]);
        wire[4 + body_len..].copy_from_slice(&crc.to_le_bytes());
        assert!(Frame::from_wire(&wire).is_err());
    }

    #[test]
    fn retired_pre_dtype_raw_tags_rejected_explicitly() {
        // A frame body using the retired tag 5 (old InferLmRaw layout,
        // no dtype byte) must produce the explicit version-mismatch
        // error, not a shifted-field misparse.
        for tag in [3u8, 5] {
            let mut body = Vec::new();
            body.extend_from_slice(&7u64.to_le_bytes());
            body.push(tag);
            varint::write_usize(&mut body, 1);
            body.push(b'm');
            varint::write_usize(&mut body, 4); // old payload length field
            body.extend_from_slice(&[1, 2, 3, 4]);
            let err = Frame::from_body(&body).unwrap_err();
            assert!(
                err.to_string().contains("predates dtype tagging"),
                "tag {tag}: {err}"
            );
        }
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut wire = vec![0u8; 12];
        wire[0..4].copy_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(Frame::from_wire(&wire).is_err());
    }

    #[test]
    fn payload_len_accounts_transfer_bytes() {
        let f = Frame::new(
            0,
            FrameKind::InferVision { model: "m".into(), sl: 1, batch: 1, payload: vec![0; 123] },
        );
        assert_eq!(f.payload_len(), 123);
        let f = Frame::new(
            0,
            FrameKind::Logits { data: vec![0.0; 10], decode_ms: 0.0, compute_ms: 0.0 },
        );
        assert_eq!(f.payload_len(), 40);
        let f = Frame::new(0, FrameKind::Busy { retry_after_ms: 1, message: "full".into() });
        assert_eq!(f.payload_len(), 0);
    }
}
