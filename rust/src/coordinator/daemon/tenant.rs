//! Per-tenant in-flight quotas.
//!
//! The global admission gate caps total concurrent work, but says
//! nothing about *who* holds the slots: one noisy tenant retrying hard
//! can occupy the whole cap and starve everyone else. The
//! [`TenantGovernor`] layers a per-tenant in-flight quota (the
//! `tenant_quota` knob, read live per acquisition) on top: a tenant at
//! quota is shed with `Busy` while other tenants' requests keep
//! flowing. Tenants are identified at connection attach time (the
//! daemon names each attached transport), not on the wire — no
//! protocol change.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::super::knobs::ServingKnobs;

#[derive(Debug, Default)]
struct TenantState {
    inflight: AtomicUsize,
}

/// Quota accountant shared by every connection pump.
pub struct TenantGovernor {
    knobs: Arc<ServingKnobs>,
    tenants: Mutex<BTreeMap<String, Arc<TenantState>>>,
}

impl TenantGovernor {
    /// Governor reading `tenant_quota` from the shared knobs handle.
    pub fn new(knobs: Arc<ServingKnobs>) -> Self {
        TenantGovernor { knobs, tenants: Mutex::new(BTreeMap::new()) }
    }

    fn state(&self, tenant: &str) -> Arc<TenantState> {
        let mut map = self.tenants.lock().unwrap();
        Arc::clone(map.entry(tenant.to_string()).or_default())
    }

    /// Acquire one in-flight slot for `tenant`, or return the tenant's
    /// current in-flight count when it is at quota. The permit releases
    /// on drop and may travel with a queued job across threads.
    pub fn try_acquire(&self, tenant: &str) -> std::result::Result<TenantPermit, usize> {
        let state = self.state(tenant);
        let quota = self.knobs.tenant_quota();
        let held = state.inflight.fetch_add(1, Ordering::SeqCst);
        if held >= quota {
            state.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(held);
        }
        Ok(TenantPermit { state })
    }

    /// `tenant`'s current in-flight count (0 if unknown).
    pub fn inflight(&self, tenant: &str) -> usize {
        self.tenants
            .lock()
            .unwrap()
            .get(tenant)
            .map(|s| s.inflight.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Tenants seen so far.
    pub fn tenant_count(&self) -> usize {
        self.tenants.lock().unwrap().len()
    }

    /// Drop tenants with nothing in flight and no outstanding permits,
    /// returning how many were evicted. A long-running daemon accepting
    /// from the open internet sees one tenant per peer address; without
    /// eviction that map grows without bound. Safe against racing
    /// acquisitions: removal happens under the map lock and only when
    /// the map holds the sole reference to the state — an acquire that
    /// already cloned the `Arc` keeps its entry alive.
    pub fn evict_idle(&self) -> usize {
        let mut map = self.tenants.lock().unwrap();
        let before = map.len();
        map.retain(|_, s| {
            Arc::strong_count(s) > 1 || s.inflight.load(Ordering::SeqCst) > 0
        });
        before - map.len()
    }
}

/// One tenant in-flight slot; released on drop.
pub struct TenantPermit {
    state: Arc<TenantState>,
}

impl Drop for TenantPermit {
    fn drop(&mut self) {
        self.state.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn governor(quota: usize) -> TenantGovernor {
        let knobs = Arc::new(ServingKnobs::default());
        knobs.set_tenant_quota(quota);
        TenantGovernor::new(knobs)
    }

    #[test]
    fn quota_is_per_tenant_not_global() {
        let g = governor(2);
        let _a1 = g.try_acquire("a").unwrap();
        let _a2 = g.try_acquire("a").unwrap();
        assert_eq!(g.try_acquire("a").unwrap_err(), 2, "tenant a is at quota");
        // Tenant b is unaffected by a's saturation.
        let _b1 = g.try_acquire("b").unwrap();
        let _b2 = g.try_acquire("b").unwrap();
        assert_eq!(g.tenant_count(), 2);
    }

    #[test]
    fn permits_release_on_drop() {
        let g = governor(1);
        let p = g.try_acquire("a").unwrap();
        assert!(g.try_acquire("a").is_err());
        drop(p);
        assert_eq!(g.inflight("a"), 0);
        assert!(g.try_acquire("a").is_ok());
    }

    #[test]
    fn evicts_only_idle_unreferenced_tenants() {
        let g = governor(2);
        let busy = g.try_acquire("busy").unwrap();
        drop(g.try_acquire("idle").unwrap());
        assert_eq!(g.tenant_count(), 2);
        assert_eq!(g.evict_idle(), 1, "only the idle tenant goes");
        assert_eq!(g.tenant_count(), 1);
        assert_eq!(g.inflight("busy"), 1, "held permit keeps its tenant");
        drop(busy);
        assert_eq!(g.evict_idle(), 1);
        assert_eq!(g.tenant_count(), 0);
        // Eviction never breaks a later acquisition.
        assert!(g.try_acquire("busy").is_ok());
    }

    #[test]
    fn quota_reconfigures_live() {
        let g = governor(1);
        let _p1 = g.try_acquire("a").unwrap();
        assert!(g.try_acquire("a").is_err());
        g.knobs.set_tenant_quota(2);
        let _p2 = g.try_acquire("a").unwrap();
        g.knobs.set_tenant_quota(1);
        assert!(g.try_acquire("a").is_err(), "shrinking the quota takes effect immediately");
    }
}
