//! Minimal typed-mailbox actor runtime.
//!
//! The daemon is built from a handful of long-lived actors (connection
//! pumps, the batch former, executors) that communicate exclusively by
//! message passing over `std::sync::mpsc` — no shared mutable state
//! beyond the atomic [`ServingKnobs`](super::super::knobs::ServingKnobs)
//! dials. This module is the substrate: an [`Actor`] is a state machine
//! with a typed message; [`spawn`] runs it on a dedicated supervised
//! thread.
//!
//! Supervision semantics:
//!
//! * **Restart on panic** — a panic inside [`Actor::handle`] is caught;
//!   the supervisor rebuilds the actor from its factory closure (fresh
//!   state, same mailbox) and keeps consuming. The message that caused
//!   the panic is lost, so actors must answer callers *before* risky
//!   work or rely on the caller observing the severed reply channel.
//! * **Give up after `max_restarts`** — a crash-looping actor stops;
//!   its mailbox closes, so senders get an explicit error instead of
//!   enqueueing into a void.
//! * **Graceful drain on stop** — [`ActorHandle::stop`] enqueues a
//!   drain marker *behind* everything already in the mailbox: earlier
//!   messages are handled normally, anything that slips in after the
//!   marker is routed to [`Actor::on_drain`] (where the daemon's batch
//!   actor answers jobs with `Busy` rather than dropping them), then
//!   [`Actor::on_stop`] runs exactly once. A send that races the final
//!   drain sweep may be dropped *with* its payload — any reply channel
//!   inside severs loudly, so waiting callers observe a disconnect,
//!   never an eternal hang.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// What the actor wants after handling one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    /// Keep consuming the mailbox.
    Continue,
    /// Drain the mailbox (via [`Actor::on_drain`]) and exit.
    Stop,
}

/// A message-driven state machine run by [`spawn`].
pub trait Actor: Send + 'static {
    /// The mailbox message type.
    type Msg: Send + 'static;

    /// Handle one message. Panics are caught by the supervisor.
    fn handle(&mut self, msg: Self::Msg) -> Control;

    /// Called for each message still in the mailbox when the actor is
    /// draining. Default: drop it. Actors whose messages carry reply
    /// channels must answer here — that is the no-silent-drop contract.
    fn on_drain(&mut self, _msg: Self::Msg) {}

    /// Called exactly once when the actor exits (drain, stop, or
    /// supervisor give-up). Flush any internal queues here.
    fn on_stop(&mut self) {}
}

enum Envelope<M> {
    Msg(M),
    Drain,
}

/// Cloneable sending side of an actor's mailbox.
pub struct Mailbox<M> {
    tx: Sender<Envelope<M>>,
}

impl<M> Clone for Mailbox<M> {
    fn clone(&self) -> Self {
        Mailbox { tx: self.tx.clone() }
    }
}

impl<M> Mailbox<M> {
    /// Enqueue a message; errors if the actor has exited (its receiver
    /// is gone), so senders always learn about a dead peer.
    pub fn send(&self, msg: M) -> Result<()> {
        self.tx
            .send(Envelope::Msg(msg))
            .map_err(|_| Error::transport("actor mailbox closed"))
    }
}

/// Restart budget for a supervised actor.
#[derive(Debug, Clone, Copy)]
pub struct SupervisorPolicy {
    /// Panics tolerated before the supervisor gives up and the actor
    /// exits for good.
    pub max_restarts: u64,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        SupervisorPolicy { max_restarts: 8 }
    }
}

/// Owner's handle to a spawned actor: mailbox + lifecycle.
///
/// Dropping the handle stops and joins the actor (so tests and the
/// daemon cannot leak actor threads); use [`ActorHandle::mailbox`] to
/// keep extra senders alive independently.
pub struct ActorHandle<M> {
    name: String,
    mailbox: Mailbox<M>,
    join: Option<JoinHandle<()>>,
    restarts: Arc<AtomicU64>,
}

impl<M> ActorHandle<M> {
    /// The actor's diagnostic name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// A fresh sender for this actor's mailbox.
    pub fn mailbox(&self) -> Mailbox<M> {
        self.mailbox.clone()
    }

    /// Shorthand for `self.mailbox().send(msg)`.
    pub fn send(&self, msg: M) -> Result<()> {
        self.mailbox.send(msg)
    }

    /// How many times the supervisor has restarted this actor.
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::SeqCst)
    }

    /// Ask the actor to drain and exit (non-blocking). Messages sent
    /// before this call are still handled normally.
    pub fn stop(&self) {
        let _ = self.tx_drain();
    }

    fn tx_drain(&self) -> Result<()> {
        self.mailbox
            .tx
            .send(Envelope::Drain)
            .map_err(|_| Error::transport("actor already exited"))
    }

    /// Stop and wait for the actor thread; returns the restart count.
    pub fn join(mut self) -> u64 {
        self.stop_and_join();
        self.restarts()
    }

    fn stop_and_join(&mut self) {
        self.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl<M> Drop for ActorHandle<M> {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Spawn `factory()` as a supervised actor on its own named thread.
///
/// The factory is kept so a panicked actor can be rebuilt with fresh
/// state; the mailbox (and everything queued in it) survives restarts.
pub fn spawn<A, F>(name: &str, policy: SupervisorPolicy, mut factory: F) -> ActorHandle<A::Msg>
where
    A: Actor,
    F: FnMut() -> A + Send + 'static,
{
    let (tx, rx) = channel::<Envelope<A::Msg>>();
    let restarts = Arc::new(AtomicU64::new(0));
    let restarts_in = Arc::clone(&restarts);
    let join = std::thread::Builder::new()
        .name(format!("actor-{name}"))
        .spawn(move || supervise(rx, policy, &mut factory, &restarts_in))
        .expect("spawn actor thread");
    ActorHandle { name: name.to_string(), mailbox: Mailbox { tx }, join: Some(join), restarts }
}

fn supervise<A, F>(
    rx: Receiver<Envelope<A::Msg>>,
    policy: SupervisorPolicy,
    factory: &mut F,
    restarts: &AtomicU64,
) where
    A: Actor,
    F: FnMut() -> A,
{
    let mut actor = factory();
    loop {
        match rx.recv() {
            Ok(Envelope::Msg(msg)) => {
                match catch_unwind(AssertUnwindSafe(|| actor.handle(msg))) {
                    Ok(Control::Continue) => {}
                    Ok(Control::Stop) => return drain(&rx, &mut actor),
                    Err(_panic) => {
                        let n = restarts.fetch_add(1, Ordering::SeqCst) + 1;
                        if n > policy.max_restarts {
                            return drain(&rx, &mut actor);
                        }
                        // Fresh state, same mailbox: queued messages
                        // are handled by the restarted incarnation.
                        actor = factory();
                    }
                }
            }
            Ok(Envelope::Drain) => return drain(&rx, &mut actor),
            // Every mailbox clone dropped: nothing can arrive anymore.
            Err(_) => return actor.on_stop(),
        }
    }
}

/// Route everything still queued to `on_drain`, then `on_stop`.
fn drain<A: Actor>(rx: &Receiver<Envelope<A::Msg>>, actor: &mut A) {
    while let Ok(env) = rx.try_recv() {
        if let Envelope::Msg(m) = env {
            actor.on_drain(m);
        }
    }
    actor.on_stop();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Test actor: counts into a shared cell; `Boom` panics; `Get`
    /// replies with internal (restart-resettable) state.
    struct Counter {
        seen: usize,
        total: Arc<AtomicUsize>,
        drained: Arc<AtomicUsize>,
    }

    enum Msg {
        Incr,
        Boom,
        Get(Sender<usize>),
    }

    impl Actor for Counter {
        type Msg = Msg;
        fn handle(&mut self, msg: Msg) -> Control {
            match msg {
                Msg::Incr => {
                    self.seen += 1;
                    self.total.fetch_add(1, Ordering::SeqCst);
                    Control::Continue
                }
                Msg::Boom => panic!("injected actor crash"),
                Msg::Get(reply) => {
                    let _ = reply.send(self.seen);
                    Control::Continue
                }
            }
        }
        fn on_drain(&mut self, msg: Msg) {
            self.drained.fetch_add(1, Ordering::SeqCst);
            // Answer reply-carrying messages even while draining.
            if let Msg::Get(reply) = msg {
                let _ = reply.send(self.seen);
            }
        }
    }

    fn counter_factory(
        total: &Arc<AtomicUsize>,
        drained: &Arc<AtomicUsize>,
    ) -> impl FnMut() -> Counter + Send + 'static {
        let total = Arc::clone(total);
        let drained = Arc::clone(drained);
        move || Counter { seen: 0, total: Arc::clone(&total), drained: Arc::clone(&drained) }
    }

    #[test]
    fn messages_are_handled_in_order() {
        let total = Arc::new(AtomicUsize::new(0));
        let drained = Arc::new(AtomicUsize::new(0));
        let h = spawn("count", SupervisorPolicy::default(), counter_factory(&total, &drained));
        for _ in 0..100 {
            h.send(Msg::Incr).unwrap();
        }
        let (tx, rx) = channel();
        h.send(Msg::Get(tx)).unwrap();
        assert_eq!(rx.recv().unwrap(), 100, "all sends handled before the Get");
        h.join();
        assert_eq!(total.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn panic_restarts_with_fresh_state_and_keeps_serving() {
        let total = Arc::new(AtomicUsize::new(0));
        let drained = Arc::new(AtomicUsize::new(0));
        let h = spawn("crashy", SupervisorPolicy::default(), counter_factory(&total, &drained));
        for _ in 0..3 {
            h.send(Msg::Incr).unwrap();
        }
        h.send(Msg::Boom).unwrap();
        for _ in 0..2 {
            h.send(Msg::Incr).unwrap();
        }
        let (tx, rx) = channel();
        h.send(Msg::Get(tx)).unwrap();
        // Fresh incarnation: internal state restarted from zero, the
        // two post-crash messages were still consumed from the mailbox.
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(h.restarts(), 1);
        h.join();
        assert_eq!(total.load(Ordering::SeqCst), 5, "no message skipped besides the crasher");
    }

    #[test]
    fn supervisor_gives_up_after_max_restarts() {
        let total = Arc::new(AtomicUsize::new(0));
        let drained = Arc::new(AtomicUsize::new(0));
        let h = spawn(
            "loop-crash",
            SupervisorPolicy { max_restarts: 1 },
            counter_factory(&total, &drained),
        );
        let mailbox = h.mailbox();
        h.send(Msg::Boom).unwrap(); // restart 1
        h.send(Msg::Boom).unwrap(); // exceeds the budget → exit
        let restarts = h.join();
        assert_eq!(restarts, 2);
        assert!(mailbox.send(Msg::Incr).is_err(), "a dead actor's mailbox must error, not void");
        assert_eq!(total.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn stop_drains_queued_messages_through_on_drain() {
        let total = Arc::new(AtomicUsize::new(0));
        let drained = Arc::new(AtomicUsize::new(0));
        let h = spawn("drainer", SupervisorPolicy::default(), counter_factory(&total, &drained));
        let mailbox = h.mailbox();
        for _ in 0..50 {
            h.send(Msg::Incr).unwrap();
        }
        h.stop();
        // Race messages in behind the drain marker: they are either
        // drained (on_drain) or dropped with their payload — whose
        // reply channels sever loudly — but the 50 sent *before* stop
        // are guaranteed the normal handle() path.
        let mut late_accepted = 0usize;
        for _ in 0..50 {
            if mailbox.send(Msg::Incr).is_ok() {
                late_accepted += 1;
            }
        }
        drop(h); // joins
        let handled = total.load(Ordering::SeqCst);
        let drained_n = drained.load(Ordering::SeqCst);
        assert_eq!(handled, 50, "every pre-stop message is handled normally, none drained");
        assert!(drained_n <= late_accepted, "only post-stop messages may be drained");
        // A Get that raced the drain either answers or severs — both
        // are explicit; recv() must not block forever.
        let (tx, rx) = channel();
        let _ = mailbox.send(Msg::Get(tx));
        let _ = rx.recv_timeout(std::time::Duration::from_secs(1));
    }

    #[test]
    fn reply_channels_sever_rather_than_hang_when_actor_dies() {
        let total = Arc::new(AtomicUsize::new(0));
        let drained = Arc::new(AtomicUsize::new(0));
        let h = spawn(
            "dead-reply",
            SupervisorPolicy { max_restarts: 0 },
            counter_factory(&total, &drained),
        );
        h.send(Msg::Boom).unwrap();
        let restarts = h.join();
        assert_eq!(restarts, 1);
    }
}
