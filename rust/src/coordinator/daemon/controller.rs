//! Adaptive batch-size feedback controller.
//!
//! Static batching thresholds pick one point on the latency/throughput
//! curve at config time; real fleets move around that curve all day.
//! This controller closes the loop with an AIMD (additive-increase /
//! multiplicative-decrease) law over two observed signals:
//!
//! * **Tail latency** — when the windowed p99 of end-to-end request
//!   latency slips past `p99_target_ms`, the batch ceiling is *cut
//!   multiplicatively* (`shrink_factor`). Over-target tails mean the
//!   server is trading too much per-request latency for throughput;
//!   backing off fast restores the SLO in one or two windows.
//! * **Queue pressure** — when the tail is healthy but the average
//!   queue depth exceeds `queue_pressure ×` the current ceiling, the
//!   ceiling *grows additively* (`grow_step`). Deep queues with a
//!   healthy tail mean there is free throughput on the table.
//!
//! Decisions fire once per `window` observations, so one slow request
//! cannot whipsaw the dial. The controller is a **pure state machine**:
//! no clocks, no randomness — feed it the same observation sequence and
//! it emits the same decisions, which is exactly what the unit tests
//! pin. The daemon feeds it from completed requests and writes its
//! output into [`ServingKnobs::set_batch_limit`], which the batch
//! former re-reads per dispatch.
//!
//! [`ServingKnobs::set_batch_limit`]: super::super::knobs::ServingKnobs::set_batch_limit

/// Tuning for [`AdaptiveController`].
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Floor for the batch ceiling (shrink never goes below).
    pub min_batch: usize,
    /// Hard cap for the batch ceiling (grow never exceeds).
    pub max_batch: usize,
    /// Tail-latency SLO: windowed p99 above this triggers a shrink.
    pub p99_target_ms: f64,
    /// Additive increase applied on a grow decision.
    pub grow_step: usize,
    /// Multiplicative decrease applied on a shrink decision (0 < f < 1).
    pub shrink_factor: f64,
    /// Observations per decision; also the p99 sample window.
    pub window: usize,
    /// Grow only when average queue depth exceeds `ceiling × this`.
    pub queue_pressure: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            min_batch: 1,
            max_batch: 32,
            p99_target_ms: 25.0,
            grow_step: 2,
            shrink_factor: 0.5,
            window: 64,
            queue_pressure: 1.5,
        }
    }
}

/// One control decision, emitted at window boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Additive increase of the batch ceiling.
    Grow { from: usize, to: usize },
    /// Multiplicative decrease of the batch ceiling.
    Shrink { from: usize, to: usize },
    /// No change (mid-window, or both signals healthy/saturated).
    Hold,
}

/// The AIMD feedback controller; see the module docs for the law.
#[derive(Debug)]
pub struct AdaptiveController {
    cfg: ControllerConfig,
    ceiling: usize,
    window_lat_ms: Vec<f64>,
    window_depth_sum: u64,
}

impl AdaptiveController {
    /// Start at the floor: the controller must *earn* large batches
    /// from observed queue pressure, so an idle daemon serves with
    /// minimal batching latency.
    pub fn new(mut cfg: ControllerConfig) -> Self {
        cfg.min_batch = cfg.min_batch.max(1);
        cfg.max_batch = cfg.max_batch.max(cfg.min_batch);
        cfg.window = cfg.window.max(1);
        cfg.grow_step = cfg.grow_step.max(1);
        if !(cfg.shrink_factor > 0.0 && cfg.shrink_factor < 1.0) {
            cfg.shrink_factor = 0.5;
        }
        let ceiling = cfg.min_batch;
        AdaptiveController {
            cfg,
            ceiling,
            window_lat_ms: Vec::new(),
            window_depth_sum: 0,
        }
    }

    /// The current batch ceiling (what dispatch should respect).
    pub fn batch_limit(&self) -> usize {
        self.ceiling
    }

    /// The controller's tuning.
    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// Record one completed request: its end-to-end latency and the
    /// queue depth observed when it was dispatched. Returns the
    /// decision taken (non-`Hold` only at window boundaries).
    pub fn observe(&mut self, latency_ms: f64, queue_depth: usize) -> Decision {
        self.window_lat_ms.push(if latency_ms.is_finite() { latency_ms } else { 0.0 });
        self.window_depth_sum += queue_depth as u64;
        if self.window_lat_ms.len() < self.cfg.window {
            return Decision::Hold;
        }
        let p99 = tail_quantile(&mut self.window_lat_ms, 0.99);
        let avg_depth = self.window_depth_sum as f64 / self.cfg.window as f64;
        self.window_lat_ms.clear();
        self.window_depth_sum = 0;

        let from = self.ceiling;
        if p99 > self.cfg.p99_target_ms {
            let to = (((from as f64) * self.cfg.shrink_factor).floor() as usize)
                .max(self.cfg.min_batch);
            self.ceiling = to;
            if to < from {
                return Decision::Shrink { from, to };
            }
        } else if avg_depth > from as f64 * self.cfg.queue_pressure {
            let to = from.saturating_add(self.cfg.grow_step).min(self.cfg.max_batch);
            self.ceiling = to;
            if to > from {
                return Decision::Grow { from, to };
            }
        }
        Decision::Hold
    }
}

/// Upper-tail quantile by sorting the (small) window in place. With
/// windows below ~100 samples the 0.99 quantile is effectively the
/// window max — fine for a shrink trigger, which *should* react to the
/// worst request of a small window.
fn tail_quantile(xs: &mut [f64], q: f64) -> f64 {
    debug_assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = (((xs.len() - 1) as f64) * q).ceil() as usize;
    xs[idx.min(xs.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            min_batch: 1,
            max_batch: 16,
            p99_target_ms: 20.0,
            grow_step: 2,
            shrink_factor: 0.5,
            window: 8,
            queue_pressure: 1.5,
        }
    }

    /// Feed one full window of identical observations; return the
    /// boundary decision.
    fn feed_window(c: &mut AdaptiveController, lat_ms: f64, depth: usize) -> Decision {
        let mut last = Decision::Hold;
        for _ in 0..c.config().window {
            last = c.observe(lat_ms, depth);
        }
        last
    }

    #[test]
    fn queue_pressure_grows_batches_additively_to_the_cap() {
        let mut c = AdaptiveController::new(cfg());
        assert_eq!(c.batch_limit(), 1, "starts at the floor");
        // Healthy tail + deep queue: grow by +2 per window, 1 → 16.
        let mut limits = vec![c.batch_limit()];
        for _ in 0..12 {
            match feed_window(&mut c, 5.0, 64) {
                Decision::Grow { from, to } => assert_eq!(to, from + 2),
                Decision::Hold => {} // saturated at max_batch
                d => panic!("unexpected {d:?}"),
            }
            limits.push(c.batch_limit());
        }
        assert_eq!(
            limits,
            vec![1, 3, 5, 7, 9, 11, 13, 15, 16, 16, 16, 16, 16],
            "deterministic additive ramp, clamped at max_batch"
        );
    }

    #[test]
    fn over_target_p99_shrinks_multiplicatively_to_the_floor() {
        let mut c = AdaptiveController::new(cfg());
        for _ in 0..8 {
            feed_window(&mut c, 5.0, 64);
        }
        assert_eq!(c.batch_limit(), 16);
        // Tail blows the SLO: halve per window, 16 → 8 → 4 → 2 → 1.
        let mut limits = Vec::new();
        for _ in 0..5 {
            feed_window(&mut c, 80.0, 64);
            limits.push(c.batch_limit());
        }
        assert_eq!(limits, vec![8, 4, 2, 1, 1], "multiplicative backoff, floored at min_batch");
    }

    #[test]
    fn one_bad_request_in_a_window_triggers_the_shrink() {
        // Small-window p99 is the max: a single SLO-busting request is
        // enough. That is intentional — document-by-test.
        let mut c = AdaptiveController::new(cfg());
        feed_window(&mut c, 5.0, 64); // 1 → 3
        assert_eq!(c.batch_limit(), 3);
        for _ in 0..7 {
            assert_eq!(c.observe(5.0, 64), Decision::Hold);
        }
        match c.observe(500.0, 64) {
            Decision::Shrink { from: 3, to: 1 } => {}
            d => panic!("expected shrink, got {d:?}"),
        }
    }

    #[test]
    fn healthy_tail_and_shallow_queue_holds() {
        let mut c = AdaptiveController::new(cfg());
        feed_window(&mut c, 5.0, 64); // 1 → 3
        // Depth 4 < 3 × 1.5 = 4.5: no pressure, no SLO breach → hold.
        assert_eq!(feed_window(&mut c, 5.0, 4), Decision::Hold);
        assert_eq!(c.batch_limit(), 3);
    }

    #[test]
    fn mid_window_observations_never_decide() {
        let mut c = AdaptiveController::new(cfg());
        for _ in 0..7 {
            assert_eq!(c.observe(500.0, 1000), Decision::Hold, "decisions only at boundaries");
        }
        assert_eq!(c.batch_limit(), 1);
    }

    #[test]
    fn identical_observation_streams_give_identical_decision_streams() {
        let stream: Vec<(f64, usize)> = (0..200)
            .map(|i| (((i * 37) % 50) as f64, (i * 13) % 40))
            .collect();
        let run = || {
            let mut c = AdaptiveController::new(cfg());
            stream.iter().map(|&(l, d)| c.observe(l, d)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "pure state machine: no clocks, no randomness");
    }

    #[test]
    fn degenerate_configs_are_sanitized() {
        let c = AdaptiveController::new(ControllerConfig {
            min_batch: 0,
            max_batch: 0,
            window: 0,
            grow_step: 0,
            shrink_factor: 7.5,
            ..cfg()
        });
        assert_eq!(c.config().min_batch, 1);
        assert_eq!(c.config().max_batch, 1);
        assert_eq!(c.config().window, 1);
        assert_eq!(c.config().grow_step, 1);
        assert_eq!(c.config().shrink_factor, 0.5);
    }
}
