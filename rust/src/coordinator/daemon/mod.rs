//! Actor-based serving daemon with adaptive batching.
//!
//! The classic [`CloudNode::serve_tcp`](super::cloud::CloudNode::serve_tcp)
//! loop is thread-per-connection with static limits — fine for a lab
//! bench, not for a fleet. This module rebuilds the cloud side as a
//! long-running daemon of supervised, message-passing actors:
//!
//! ```text
//!  edge ──transport──▶ connection pump ──┐   (tenant quota, admission)
//!  edge ──transport──▶ connection pump ──┤
//!  edge ──transport──▶ connection pump ──┼─▶ [batch actor] ──▶ [exec actor 0]
//!     …hundreds more…                    │     │    ▲     └──▶ [exec actor k]
//!                                        │     ▼    │ Observed{latency, depth}
//!                    ticker ─── Tick ────┘  AIMD controller
//!                                           └─▶ ServingKnobs.batch_limit
//! ```
//!
//! * **Connection pumps** (one lightweight thread per attached
//!   transport) parse frames, answer control traffic inline (and
//!   forward node-servable frames such as registry delta-sync to an
//!   attached [`CloudNode`]), run the pre-admission preflight check
//!   (version skew), enforce the per-tenant quota ([`TenantGovernor`])
//!   and the global admission gate, then submit jobs to the batch
//!   actor's mailbox and relay the reply. Tenants are named at attach
//!   time — no wire change.
//! * **The batch actor** forms deadline-aware batches: dispatch fires
//!   when the queue covers the current adaptive ceiling, or when the
//!   oldest job has waited `max_wait` (ticker-driven), never later.
//! * **Exec actors** run the request handler, answer each job's reply
//!   channel, and report `(latency, depth)` observations back to the
//!   batch actor, which feeds the [`AdaptiveController`] — growing the
//!   ceiling under queue pressure, cutting it when the observed p99
//!   slips past target, and publishing the result through
//!   [`ServingKnobs`] for everyone to read.
//! * **Supervision** ([`actor`]) restarts a panicked actor with fresh
//!   state; jobs caught in the blast radius sever their reply channels
//!   and the pump answers the edge with an explicit `ServerError`.
//!
//! Every request gets an explicit outcome — a reply, `Busy`, or
//! `ServerError` — under load, chaos, restart, and shutdown alike.
//! The [`loadgen`](super::loadgen) module drives hundreds of simulated
//! edges against this daemon as the scale benchmark.

pub mod actor;
pub mod controller;
pub mod tenant;

use std::collections::HashSet;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::telemetry::{Registry, Scoped};

use super::cloud::{Admission, AdmitPermit, CloudNode, ServerLimits};
use super::knobs::ServingKnobs;
use super::protocol::{Frame, FrameKind};
use super::transport::{TcpTransport, Transport};

use actor::{Actor, ActorHandle, Control, Mailbox, SupervisorPolicy};
use controller::{AdaptiveController, ControllerConfig, Decision};
use tenant::{TenantGovernor, TenantPermit};

/// How often an idle connection pump wakes to check for shutdown.
const PUMP_POLL: Duration = Duration::from_millis(25);

/// Consecutive retryable receive errors tolerated per connection
/// before the pump declares the link dead (mirrors `serve_loop`).
const MAX_CONSECUTIVE_RECV_ERRORS: u32 = 8;

/// Request handler the daemon executes per frame (e.g.
/// [`CloudNode::handle`] or a synthetic responder in tests/benches).
pub type ExecFn = Arc<dyn Fn(&Frame) -> Frame + Send + Sync>;

/// Pre-admission check a pump runs on each inference frame before any
/// permit is taken: `Some(reply)` refuses the request outright.
/// [`Daemon::for_node`] wires [`CloudNode::check_skew`] here so a
/// version-skewed request is answered `VersionSkew` instead of being
/// decoded against the wrong deployment — matching
/// [`CloudNode::admit_and_handle`](super::cloud::CloudNode::admit_and_handle).
pub type PreflightFn = Arc<dyn Fn(&Frame) -> Option<Frame> + Send + Sync>;

/// Distinct per-tenant metric scopes the daemon will create before new
/// tenants aggregate under `tenant.overflow`: with tenant identity
/// derived from the peer address, an open listener must not be able to
/// grow the metric registry without bound.
const MAX_TENANT_SCOPES: usize = 1024;

/// Daemon tuning. Initial values for the queue/wait/inflight/quota
/// bounds; all of them are live-reconfigurable afterwards through
/// [`Daemon::knobs`].
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Compiled batch sizes, ascending (same meaning as
    /// [`BatcherConfig::buckets`](super::batcher::BatcherConfig)).
    pub buckets: Vec<usize>,
    /// Initial batch queue-depth bound (jobs beyond it are shed).
    pub max_queue: usize,
    /// Initial flush deadline for partial batches.
    pub max_wait: Duration,
    /// Initial global in-flight cap (admission gate).
    pub max_inflight: usize,
    /// Initial per-tenant in-flight quota.
    pub tenant_quota: usize,
    /// Executor actors (parallel batch lanes).
    pub executors: usize,
    /// Adaptive batch controller tuning.
    pub controller: ControllerConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            buckets: vec![1, 8],
            max_queue: 256,
            max_wait: Duration::from_millis(2),
            max_inflight: 64,
            tenant_quota: 16,
            executors: 2,
            controller: ControllerConfig::default(),
        }
    }
}

/// One admitted request travelling pump → batch actor → exec actor.
/// Carries its permits so the tenant/admission slots are held until the
/// reply is sent (the admission EWMA thus observes queue + service).
struct Job {
    frame: Frame,
    enqueued: Instant,
    reply: Sender<Frame>,
    _tenant: TenantPermit,
    _admit: AdmitPermit,
}

impl Job {
    fn answer_busy(&self, retry_after_ms: u64, message: &str) {
        let kind = FrameKind::Busy {
            retry_after_ms: retry_after_ms.min(u32::MAX as u64) as u32,
            message: message.to_string(),
        };
        let _ = self.reply.send(Frame::new(self.frame.request_id, kind));
    }
}

enum BatchMsg {
    Submit(Job),
    /// Periodic flush check from the ticker thread.
    Tick,
    /// Feedback from an exec actor: per-request end-to-end latencies of
    /// a finished batch and the queue depth seen at its dispatch.
    Observed { latencies_ms: Vec<f64>, depth: usize },
}

enum ExecMsg {
    Run { jobs: Vec<Job>, depth: usize },
}

/// The batch-forming actor: owns the job queue, the bucket choice, and
/// the adaptive controller.
struct BatchActor {
    queue: std::collections::VecDeque<Job>,
    buckets: Vec<usize>,
    knobs: Arc<ServingKnobs>,
    controller: AdaptiveController,
    execs: Vec<Mailbox<ExecMsg>>,
    next_exec: usize,
    metrics: Arc<Registry>,
}

impl BatchActor {
    /// Largest bucket under the live adaptive ceiling (floor: smallest
    /// bucket).
    fn effective_bucket(&self) -> usize {
        let limit = self.knobs.batch_limit();
        self.buckets.iter().rev().find(|&&b| b <= limit).copied().unwrap_or(self.buckets[0])
    }

    fn dispatch(&mut self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        let depth = self.queue.len();
        self.metrics.histogram("daemon.batch_size").record_ms(jobs.len() as f64);
        for job in &jobs {
            self.metrics
                .histogram("daemon.queue_ms")
                .record_ms(job.enqueued.elapsed().as_secs_f64() * 1e3);
        }
        self.metrics.incr("daemon.dispatch_total", 1);
        let lane = self.next_exec % self.execs.len();
        self.next_exec = self.next_exec.wrapping_add(1);
        if self.execs[lane].send(ExecMsg::Run { jobs, depth }).is_err() {
            // Exec lane gone for good (supervisor gave up): the jobs
            // inside the failed send are dropped with the message and
            // their reply channels sever — pumps answer ServerError.
            self.metrics.incr("daemon.exec_lane_lost", 1);
        }
    }

    /// Cut and dispatch every full batch; with `flush`, also push out a
    /// partial batch whose oldest job has exceeded `max_wait`.
    fn form_batches(&mut self, flush: bool) {
        loop {
            let bucket = self.effective_bucket();
            if self.queue.len() >= bucket {
                let batch: Vec<Job> = self.queue.drain(..bucket).collect();
                self.dispatch(batch);
                continue;
            }
            if flush && !self.queue.is_empty() {
                let oldest = self.queue.front().map(|j| j.enqueued.elapsed()).unwrap_or_default();
                if oldest >= self.knobs.max_wait() {
                    let take = self
                        .buckets
                        .iter()
                        .rev()
                        .find(|&&b| b <= self.queue.len())
                        .copied()
                        .unwrap_or(self.buckets[0])
                        .min(self.queue.len());
                    let batch: Vec<Job> = self.queue.drain(..take).collect();
                    self.dispatch(batch);
                    continue;
                }
            }
            return;
        }
    }
}

impl Actor for BatchActor {
    type Msg = BatchMsg;

    fn handle(&mut self, msg: BatchMsg) -> Control {
        match msg {
            BatchMsg::Submit(job) => {
                if self.queue.len() >= self.knobs.max_queue() {
                    let retry = (self.knobs.max_wait().as_millis() as u64).max(1);
                    job.answer_busy(retry, "daemon batch queue full");
                    self.metrics.incr("daemon.queue_shed_total", 1);
                } else {
                    self.queue.push_back(job);
                    self.form_batches(false);
                }
            }
            BatchMsg::Tick => self.form_batches(true),
            BatchMsg::Observed { latencies_ms, depth } => {
                for lat in latencies_ms {
                    match self.controller.observe(lat, depth) {
                        Decision::Grow { to, .. } => {
                            self.knobs.set_batch_limit(to);
                            self.metrics.incr("daemon.batch_grow_total", 1);
                        }
                        Decision::Shrink { to, .. } => {
                            self.knobs.set_batch_limit(to);
                            self.metrics.incr("daemon.batch_shrink_total", 1);
                        }
                        Decision::Hold => {}
                    }
                }
            }
        }
        Control::Continue
    }

    fn on_drain(&mut self, msg: BatchMsg) {
        if let BatchMsg::Submit(job) = msg {
            job.answer_busy(1, "daemon draining");
            self.metrics.incr("daemon.drain_shed_total", 1);
        }
    }

    fn on_stop(&mut self) {
        // The no-silent-drop contract at shutdown: everything still
        // queued is answered with an explicit Busy.
        for job in self.queue.drain(..) {
            let kind = FrameKind::Busy { retry_after_ms: 1, message: "daemon draining".into() };
            let _ = job.reply.send(Frame::new(job.frame.request_id, kind));
            self.metrics.incr("daemon.drain_shed_total", 1);
        }
    }
}

/// An executor lane: runs the handler over a batch, answers each job,
/// and reports observations to the batch actor.
struct ExecActor {
    exec: ExecFn,
    feedback: Mailbox<BatchMsg>,
    metrics: Arc<Registry>,
}

impl Actor for ExecActor {
    type Msg = ExecMsg;

    fn handle(&mut self, msg: ExecMsg) -> Control {
        let ExecMsg::Run { jobs, depth } = msg;
        let mut latencies = Vec::with_capacity(jobs.len());
        for job in jobs {
            let waited_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
            let reply = match job.frame.deadline_ms {
                // Deadline already blown in the queue: answering Busy
                // beats burning exec time on a reply the edge will
                // discard.
                Some(d) if waited_ms > d as f64 => {
                    self.metrics.incr("daemon.deadline_shed_total", 1);
                    Frame::new(
                        job.frame.request_id,
                        FrameKind::Busy {
                            retry_after_ms: 1,
                            message: "deadline exceeded while queued".into(),
                        },
                    )
                }
                _ => (self.exec)(&job.frame),
            };
            let _ = job.reply.send(reply);
            let total_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
            self.metrics.histogram("daemon.latency_ms").record_ms(total_ms);
            latencies.push(total_ms);
        }
        let _ = self.feedback.send(BatchMsg::Observed { latencies_ms: latencies, depth });
        Control::Continue
    }

    fn on_drain(&mut self, msg: ExecMsg) {
        let ExecMsg::Run { jobs, .. } = msg;
        for job in jobs {
            job.answer_busy(1, "daemon draining");
            self.metrics.incr("daemon.drain_shed_total", 1);
        }
    }
}

/// Everything the connection pumps share.
struct Inner {
    knobs: Arc<ServingKnobs>,
    admission: Arc<Admission>,
    tenants: TenantGovernor,
    metrics: Arc<Registry>,
    batch: Mailbox<BatchMsg>,
    stopping: AtomicBool,
    /// Handler for non-batched frames the pump does not answer itself
    /// (registry delta-sync and other node-servable control traffic);
    /// absent → `ServerError`.
    inline: Option<ExecFn>,
    /// Pre-admission refusal check (version skew for an attached node).
    preflight: Option<PreflightFn>,
    /// Tenants granted a dedicated metric scope so far (bounded by
    /// [`MAX_TENANT_SCOPES`]).
    scoped_tenants: Mutex<HashSet<String>>,
}

/// The long-running serving daemon. Attach transports (or run
/// [`Daemon::serve_tcp`]); drop or [`Daemon::shutdown`] to drain.
pub struct Daemon {
    inner: Arc<Inner>,
    // Field order is drop order: the batch actor drains (answering its
    // queue) before the exec handles join.
    batch: Option<ActorHandle<BatchMsg>>,
    execs: Vec<ActorHandle<ExecMsg>>,
    ticker: Option<JoinHandle<()>>,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Daemon {
    /// Build a daemon around an arbitrary request handler.
    pub fn new(cfg: DaemonConfig, exec: ExecFn) -> Self {
        Self::build(cfg, exec, None, None)
    }

    fn build(
        cfg: DaemonConfig,
        exec: ExecFn,
        inline: Option<ExecFn>,
        preflight: Option<PreflightFn>,
    ) -> Self {
        let mut buckets = if cfg.buckets.is_empty() { vec![1] } else { cfg.buckets.clone() };
        buckets.sort_unstable();

        let knobs = Arc::new(ServingKnobs::from_limits(&ServerLimits {
            max_inflight: cfg.max_inflight,
        }));
        knobs.set_max_queue(cfg.max_queue);
        knobs.set_max_wait(cfg.max_wait);
        knobs.set_tenant_quota(cfg.tenant_quota);
        let controller = AdaptiveController::new(cfg.controller.clone());
        knobs.set_batch_limit(controller.batch_limit());

        let metrics = Arc::new(Registry::new());
        let admission = Arc::new(Admission::with_knobs(Arc::clone(&knobs)));

        // The batch actor and the exec lanes reference each other
        // (jobs down, observations up), so the lane mailboxes arrive
        // through a one-shot handshake the factory caches — a restart
        // reuses the cached lanes instead of re-reading the channel.
        let (lane_tx, lane_rx) = channel::<Vec<Mailbox<ExecMsg>>>();
        let batch = {
            let knobs = Arc::clone(&knobs);
            let metrics = Arc::clone(&metrics);
            let buckets = buckets.clone();
            let controller_cfg = cfg.controller.clone();
            let lane_rx = Mutex::new(lane_rx);
            let lanes_cache: Mutex<Option<Vec<Mailbox<ExecMsg>>>> = Mutex::new(None);
            actor::spawn("daemon-batch", SupervisorPolicy::default(), move || {
                let mut cache = lanes_cache.lock().unwrap();
                if cache.is_none() {
                    *cache =
                        Some(lane_rx.lock().unwrap().recv().expect("exec lanes handed over"));
                }
                BatchActor {
                    queue: std::collections::VecDeque::new(),
                    buckets: buckets.clone(),
                    knobs: Arc::clone(&knobs),
                    controller: AdaptiveController::new(controller_cfg.clone()),
                    execs: cache.clone().expect("lanes cached"),
                    next_exec: 0,
                    metrics: Arc::clone(&metrics),
                }
            })
        };
        let execs: Vec<ActorHandle<ExecMsg>> = (0..cfg.executors.max(1))
            .map(|i| {
                let exec = Arc::clone(&exec);
                let feedback = batch.mailbox();
                let metrics = Arc::clone(&metrics);
                actor::spawn(&format!("daemon-exec-{i}"), SupervisorPolicy::default(), move || {
                    ExecActor {
                        exec: Arc::clone(&exec),
                        feedback: feedback.clone(),
                        metrics: Arc::clone(&metrics),
                    }
                })
            })
            .collect();
        lane_tx.send(execs.iter().map(|h| h.mailbox()).collect()).expect("batch actor alive");

        let inner = Arc::new(Inner {
            knobs: Arc::clone(&knobs),
            admission,
            tenants: TenantGovernor::new(Arc::clone(&knobs)),
            metrics: Arc::clone(&metrics),
            batch: batch.mailbox(),
            stopping: AtomicBool::new(false),
            inline,
            preflight,
            scoped_tenants: Mutex::new(HashSet::new()),
        });

        let ticker = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("daemon-ticker".into())
                .spawn(move || loop {
                    let wait = inner.knobs.max_wait();
                    std::thread::sleep((wait / 2).clamp(
                        Duration::from_micros(200),
                        Duration::from_millis(20),
                    ));
                    // The ticker deliberately outlives `stopping`:
                    // partial batches must keep flushing while the
                    // pumps drain, or a job younger than `max_wait` at
                    // shutdown would strand its pump in reply-wait and
                    // deadlock the join. It exits only once the batch
                    // actor's mailbox closes.
                    if inner.batch.send(BatchMsg::Tick).is_err() {
                        return;
                    }
                })
                .expect("spawn daemon ticker")
        };

        Daemon { inner, batch: Some(batch), execs, ticker: Some(ticker), conns: Mutex::new(Vec::new()) }
    }

    /// Daemon fronting a [`CloudNode`]: the node's pure `handle` runs
    /// behind the daemon's own admission/quota/batching (the node-side
    /// gate is bypassed so requests are not admitted twice), with the
    /// node's semantics preserved at the pump:
    ///
    /// * the pre-admission version-skew check
    ///   ([`CloudNode::check_skew`]) refuses mismatched requests with
    ///   `VersionSkew` before they consume quota or batch space, and
    /// * non-batched node-servable frames (registry delta-sync
    ///   `FetchManifest`/`FetchChunk`) are forwarded to the node
    ///   inline, off the batch path — fetch frames deliberately bypass
    ///   admission *and* the skew check, so a stale edge can pull the
    ///   very deployment that fixes its skew.
    pub fn for_node(cfg: DaemonConfig, node: Arc<CloudNode>) -> Self {
        let exec = {
            let node = Arc::clone(&node);
            Arc::new(move |frame: &Frame| node.handle(frame)) as ExecFn
        };
        let inline = {
            let node = Arc::clone(&node);
            Arc::new(move |frame: &Frame| node.handle(frame)) as ExecFn
        };
        let preflight = Arc::new(move |frame: &Frame| node.check_skew(frame)) as PreflightFn;
        Self::build(cfg, exec, Some(inline), Some(preflight))
    }

    /// The live-reconfigurable dials (inflight cap, queue bound, flush
    /// wait, adaptive ceiling, tenant quota).
    pub fn knobs(&self) -> Arc<ServingKnobs> {
        Arc::clone(&self.inner.knobs)
    }

    /// The daemon's metrics registry (`daemon.*` and `tenant.<id>.*`).
    pub fn metrics(&self) -> Arc<Registry> {
        Arc::clone(&self.inner.metrics)
    }

    /// Tenants observed so far.
    pub fn tenant_count(&self) -> usize {
        self.inner.tenants.tenant_count()
    }

    /// Attach one edge connection under `tenant`: spawns a pump thread
    /// that serves the transport until the peer goes away or the daemon
    /// drains.
    pub fn attach(&self, transport: Box<dyn Transport>, tenant: &str) {
        let inner = Arc::clone(&self.inner);
        // New connections are the only source of pump threads and
        // tenant state, so this is the natural bound point on a
        // long-running daemon: evict tenants with nothing in flight and
        // reap pumps that already exited, keeping memory proportional
        // to the *live* connection set rather than every peer ever
        // seen.
        inner.tenants.evict_idle();
        let tenant = tenant.to_string();
        let handle = std::thread::Builder::new()
            .name(format!("daemon-conn-{tenant}"))
            .spawn(move || pump(transport, tenant, inner))
            .expect("spawn daemon connection pump");
        let mut conns = self.conns.lock().unwrap();
        conns.retain(|h| !h.is_finished());
        conns.push(handle);
    }

    /// Accept loop over TCP: each connection becomes a pump under a
    /// tenant named for the peer address. Returns when `stop` is
    /// raised (checked between accepts).
    pub fn serve_tcp(&self, listener: TcpListener, stop: Arc<AtomicBool>) -> Result<()> {
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::transport(format!("nonblocking: {e}")))?;
        while !stop.load(Ordering::SeqCst) && !self.inner.stopping.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, addr)) => {
                    stream
                        .set_nonblocking(false)
                        .map_err(|e| Error::transport(format!("blocking: {e}")))?;
                    match TcpTransport::new(stream) {
                        Ok(t) => self.attach(Box::new(t), &format!("ip-{}", addr.ip())),
                        Err(_) => continue,
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(Error::transport(format!("accept: {e}"))),
            }
        }
        Ok(())
    }

    /// Graceful drain: stop accepting, finish in-flight work, answer
    /// everything queued, join every thread. (Dropping the daemon does
    /// the same.)
    pub fn shutdown(self) {
        drop(self);
    }

    fn stop_everything(&mut self) {
        self.inner.stopping.store(true, Ordering::SeqCst);
        // Pumps first, while the actors AND the ticker are still alive:
        // ticks keep flushing partial batches, so a pump whose job is
        // parked in a batch younger than `max_wait` still gets its
        // reply (after at most `max_wait`) and exits at its next poll.
        let conns: Vec<JoinHandle<()>> = self.conns.lock().unwrap().drain(..).collect();
        for c in conns {
            let _ = c.join();
        }
        // Batch actor drains (answering its queue); its mailbox closing
        // then stops the ticker at the next tick, and the lanes drain
        // last.
        if let Some(b) = self.batch.take() {
            b.join();
        }
        if let Some(t) = self.ticker.take() {
            let _ = t.join();
        }
        self.execs.clear();
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.stop_everything();
    }
}

fn busy_frame(request_id: u64, retry_after_ms: u64, message: &str) -> Frame {
    Frame::new(
        request_id,
        FrameKind::Busy {
            retry_after_ms: retry_after_ms.min(u32::MAX as u64) as u32,
            message: message.to_string(),
        },
    )
}

/// Per-tenant metric scope, capped: beyond [`MAX_TENANT_SCOPES`]
/// distinct tenants, new ones share the `tenant.overflow` scope instead
/// of minting fresh registry keys forever.
fn tenant_scope(inner: &Inner, tenant: &str) -> Scoped {
    let mut seen = inner.scoped_tenants.lock().unwrap();
    if seen.contains(tenant) || seen.len() < MAX_TENANT_SCOPES {
        seen.insert(tenant.to_string());
        inner.metrics.scoped(&format!("tenant.{tenant}"))
    } else {
        inner.metrics.incr("daemon.tenant_scope_overflow", 1);
        inner.metrics.scoped("tenant.overflow")
    }
}

/// One connection's serve loop: transport in, mailbox out.
fn pump(mut t: Box<dyn Transport>, tenant: String, inner: Arc<Inner>) {
    let mut consecutive_errors = 0u32;
    // Per-tenant series: `tenant.<id>.requests` / `.ok` / `.shed` /
    // `.errors` / `.quota_rejected` / `.skew_rejected`, all in the
    // shared snapshot.
    let scope = tenant_scope(&inner, &tenant);
    loop {
        if inner.stopping.load(Ordering::SeqCst) {
            return;
        }
        let frame = match t.recv_timeout(PUMP_POLL) {
            Ok(f) => {
                consecutive_errors = 0;
                f
            }
            // Idle poll: loop around and re-check the stop flag.
            Err(Error::Timeout(_)) => continue,
            Err(e) if e.is_retryable() && consecutive_errors < MAX_CONSECUTIVE_RECV_ERRORS => {
                consecutive_errors += 1;
                inner.metrics.incr("daemon.recv_errors", 1);
                continue;
            }
            Err(_) => return, // peer closed or the link is dead
        };
        let needs_batching = matches!(
            frame.kind,
            FrameKind::InferVision { .. }
                | FrameKind::InferVisionRaw { .. }
                | FrameKind::InferLm { .. }
                | FrameKind::InferLmRaw { .. }
        );
        if !needs_batching {
            let reply = match frame.kind {
                FrameKind::Ping => Frame::new(frame.request_id, FrameKind::Pong),
                FrameKind::Stats => Frame::new(
                    frame.request_id,
                    FrameKind::StatsReply { json: inner.metrics.snapshot_json() },
                ),
                FrameKind::Shutdown => {
                    let _ = t.send(&Frame::new(frame.request_id, FrameKind::Pong));
                    return;
                }
                // Anything else node-servable (registry delta-sync
                // `FetchManifest`/`FetchChunk`, …) forwards to the
                // attached node inline, off the batch path; without a
                // node the refusal stays loud.
                ref other => match inner.inline.as_deref() {
                    Some(inline) => inline(&frame),
                    None => Frame::new(
                        frame.request_id,
                        FrameKind::ServerError {
                            message: format!("daemon does not serve {other:?}"),
                        },
                    ),
                },
            };
            if t.send(&reply).is_err() {
                return;
            }
            continue;
        }

        scope.incr("requests", 1);
        inner.metrics.incr("daemon.requests_total", 1);
        let retry_hint = (inner.knobs.max_wait().as_millis() as u64).max(1);

        // Pre-admission refusal (version skew against an attached
        // node): runs before any permit so a mismatched request neither
        // consumes quota nor ever reaches the decoder.
        if let Some(preflight) = inner.preflight.as_deref() {
            if let Some(reply) = preflight(&frame) {
                scope.incr("skew_rejected", 1);
                inner.metrics.incr("daemon.preflight_rejected_total", 1);
                if t.send(&reply).is_err() {
                    return;
                }
                continue;
            }
        }

        // Tenant quota before the global gate: a noisy tenant is shed
        // on its own budget without ever touching shared slots.
        let tenant_permit = match inner.tenants.try_acquire(&tenant) {
            Ok(p) => p,
            Err(_held) => {
                scope.incr("quota_rejected", 1);
                inner.metrics.incr("daemon.quota_shed_total", 1);
                let reply =
                    busy_frame(frame.request_id, retry_hint, "tenant quota exhausted");
                if t.send(&reply).is_err() {
                    return;
                }
                continue;
            }
        };
        let admit_permit = match inner.admission.try_admit_owned(frame.deadline_ms) {
            Ok(p) => p,
            Err(retry_after_ms) => {
                scope.incr("shed", 1);
                inner.metrics.incr("daemon.shed_total", 1);
                let reply = busy_frame(
                    frame.request_id,
                    retry_after_ms,
                    "daemon inflight cap reached or deadline unmeetable",
                );
                if t.send(&reply).is_err() {
                    return;
                }
                continue;
            }
        };

        let request_id = frame.request_id;
        let (reply_tx, reply_rx) = channel();
        let job = Job {
            frame,
            enqueued: Instant::now(),
            reply: reply_tx,
            _tenant: tenant_permit,
            _admit: admit_permit,
        };
        if inner.batch.send(BatchMsg::Submit(job)).is_err() {
            // Batch actor gone (drain or crash-loop): explicit answer.
            let reply = busy_frame(request_id, retry_hint, "daemon draining");
            if t.send(&reply).is_err() {
                return;
            }
            continue;
        }
        let reply = match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => {
                // The job was lost to an actor restart mid-batch: the
                // severed reply channel is the signal; answer loudly.
                inner.metrics.incr("daemon.orphaned_total", 1);
                Frame::new(
                    request_id,
                    FrameKind::ServerError {
                        message: "request lost to an internal restart; safe to retry".into(),
                    },
                )
            }
        };
        match reply.kind {
            FrameKind::Busy { .. } => scope.incr("shed", 1),
            FrameKind::ServerError { .. } => scope.incr("errors", 1),
            _ => scope.incr("ok", 1),
        }
        if t.send(&reply).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::InProcTransport;

    fn echo_exec() -> ExecFn {
        Arc::new(|frame: &Frame| {
            let kind = match &frame.kind {
                FrameKind::InferLm { payload, .. } => FrameKind::Logits {
                    data: vec![payload.iter().map(|&b| b as u64).sum::<u64>() as f32],
                    decode_ms: 0.0,
                    compute_ms: 0.0,
                },
                other => FrameKind::ServerError { message: format!("unexpected {other:?}") },
            };
            Frame::new(frame.request_id, kind)
        })
    }

    fn infer(id: u64, payload: Vec<u8>) -> Frame {
        Frame::new(id, FrameKind::InferLm { model: "m".into(), payload })
    }

    #[test]
    fn roundtrips_inference_and_control_frames() {
        let daemon = Daemon::new(DaemonConfig::default(), echo_exec());
        let (mut client, server) = InProcTransport::pair();
        daemon.attach(Box::new(server), "t0");

        client.send(&Frame::new(1, FrameKind::Ping)).unwrap();
        assert!(matches!(client.recv().unwrap().kind, FrameKind::Pong));

        client.send(&infer(2, vec![1, 2, 3])).unwrap();
        let reply = client.recv().unwrap();
        assert_eq!(reply.request_id, 2);
        match reply.kind {
            FrameKind::Logits { ref data, .. } => assert_eq!(data[0], 6.0),
            ref other => panic!("unexpected {other:?}"),
        }

        client.send(&Frame::new(3, FrameKind::Stats)).unwrap();
        match client.recv().unwrap().kind {
            FrameKind::StatsReply { ref json } => {
                assert!(json.contains("tenant.t0.ok"), "per-tenant counters in stats: {json}")
            }
            ref other => panic!("unexpected {other:?}"),
        }
        daemon.shutdown();
    }

    #[test]
    fn many_edges_all_get_explicit_outcomes() {
        let daemon = Daemon::new(
            DaemonConfig { max_wait: Duration::from_micros(300), ..Default::default() },
            echo_exec(),
        );
        let mut clients = Vec::new();
        for i in 0..16 {
            let (client, server) = InProcTransport::pair();
            daemon.attach(Box::new(server), &format!("t{}", i % 4));
            clients.push(client);
        }
        std::thread::scope(|s| {
            for (i, client) in clients.iter_mut().enumerate() {
                s.spawn(move || {
                    for r in 0..20u64 {
                        let payload = vec![(i as u8).wrapping_add(r as u8); 4];
                        let want: f32 = payload.iter().map(|&b| b as u64).sum::<u64>() as f32;
                        client.send(&infer(r, payload)).unwrap();
                        let reply = client.recv().expect("every request answered");
                        assert_eq!(reply.request_id, r);
                        match reply.kind {
                            FrameKind::Logits { ref data, .. } => assert_eq!(data[0], want),
                            FrameKind::Busy { .. } => {} // explicit shed is a valid outcome
                            ref other => panic!("unexpected {other:?}"),
                        }
                    }
                });
            }
        });
        let metrics = daemon.metrics();
        assert_eq!(daemon.tenant_count(), 4);
        assert!(metrics.get("daemon.dispatch_total") > 0);
        daemon.shutdown();
    }

    #[test]
    fn noisy_tenant_is_quota_shed_while_quiet_tenant_flows() {
        // Slow exec + tiny quota: the noisy tenant's burst must be shed
        // on its own budget, never starving the quiet tenant.
        let slow: ExecFn = Arc::new(|frame: &Frame| {
            std::thread::sleep(Duration::from_millis(2));
            Frame::new(
                frame.request_id,
                FrameKind::Logits { data: vec![1.0], decode_ms: 0.0, compute_ms: 0.0 },
            )
        });
        let daemon = Daemon::new(
            DaemonConfig {
                tenant_quota: 2,
                max_inflight: 64,
                max_wait: Duration::from_micros(200),
                ..Default::default()
            },
            slow,
        );
        let quota_shed = {
            // Noisy tenant: 8 connections firing concurrently.
            let mut noisy = Vec::new();
            for _ in 0..8 {
                let (client, server) = InProcTransport::pair();
                daemon.attach(Box::new(server), "noisy");
                noisy.push(client);
            }
            let (mut quiet, server) = InProcTransport::pair();
            daemon.attach(Box::new(server), "quiet");
            std::thread::scope(|s| {
                for client in noisy.iter_mut() {
                    s.spawn(move || {
                        for r in 0..10u64 {
                            client.send(&infer(r, vec![1])).unwrap();
                            let reply = client.recv().expect("noisy requests still answered");
                            assert!(
                                matches!(
                                    reply.kind,
                                    FrameKind::Logits { .. } | FrameKind::Busy { .. }
                                ),
                                "explicit outcome required"
                            );
                        }
                    });
                }
                s.spawn(move || {
                    for r in 0..10u64 {
                        quiet.send(&infer(r, vec![2])).unwrap();
                        let reply = quiet.recv().expect("quiet tenant must not starve");
                        assert!(
                            matches!(reply.kind, FrameKind::Logits { .. }),
                            "quota 2 with one connection: quiet tenant never sheds, got {:?}",
                            reply.kind
                        );
                    }
                });
            });
            daemon.metrics().get("tenant.noisy.quota_rejected")
        };
        assert!(quota_shed > 0, "8 concurrent noisy connections over quota 2 must shed");
        assert_eq!(daemon.metrics().get("tenant.quiet.quota_rejected"), 0);
        daemon.shutdown();
    }

    #[test]
    fn shutdown_flushes_partial_batch_instead_of_deadlocking() {
        // Regression: a request parked in a partial batch younger than
        // `max_wait` at the moment shutdown starts. The ticker must
        // keep flushing while the pumps drain — if it exits on
        // `stopping` the pump wedges in reply-wait and the join hangs
        // forever.
        let daemon = Daemon::new(
            DaemonConfig {
                buckets: vec![4],                     // one request never fills a batch
                max_wait: Duration::from_millis(150), // stays partial across shutdown()
                ..Default::default()
            },
            echo_exec(),
        );
        let (mut client, server) = InProcTransport::pair();
        daemon.attach(Box::new(server), "t");
        client.send(&infer(1, vec![7])).unwrap();
        // Let the job reach the batch actor's queue before stopping.
        std::thread::sleep(Duration::from_millis(20));
        let waiter = std::thread::spawn(move || client.recv());
        daemon.shutdown(); // must complete, not hang
        let reply = waiter.join().unwrap().expect("queued request answered across shutdown");
        assert_eq!(reply.request_id, 1);
        match reply.kind {
            FrameKind::Logits { ref data, .. } => assert_eq!(data[0], 7.0),
            FrameKind::Busy { .. } => {} // explicit shed is also a valid outcome
            ref other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn preflight_rejects_before_any_permit_is_taken() {
        // Emulates CloudNode::check_skew: active version 7.
        let preflight: PreflightFn = Arc::new(|frame: &Frame| match frame.model_version {
            Some(v) if v != 7 => Some(Frame::new(
                frame.request_id,
                FrameKind::VersionSkew { active: 7, offered: v, message: "resync".into() },
            )),
            _ => None,
        });
        let daemon = Daemon::build(DaemonConfig::default(), echo_exec(), None, Some(preflight));
        let (mut client, server) = InProcTransport::pair();
        daemon.attach(Box::new(server), "t");
        client.send(&infer(1, vec![1, 2, 3]).with_model_version(3)).unwrap();
        match client.recv().unwrap().kind {
            FrameKind::VersionSkew { active, offered, .. } => {
                assert_eq!((active, offered), (7, 3))
            }
            ref other => panic!("unexpected {other:?}"),
        }
        // A matching version flows through to the exec as before.
        client.send(&infer(2, vec![1, 2, 3]).with_model_version(7)).unwrap();
        assert!(matches!(client.recv().unwrap().kind, FrameKind::Logits { .. }));
        let metrics = daemon.metrics();
        assert_eq!(metrics.get("daemon.preflight_rejected_total"), 1);
        assert_eq!(metrics.get("tenant.t.skew_rejected"), 1);
        assert_eq!(daemon.inner.tenants.inflight("t"), 0, "no quota slot was consumed");
        daemon.shutdown();
    }

    #[test]
    fn inline_handler_serves_node_control_frames() {
        let inline: ExecFn = Arc::new(|frame: &Frame| {
            let kind = match &frame.kind {
                FrameKind::FetchManifest { model, version } => FrameKind::ManifestReply {
                    json: format!("{{\"model\":\"{model}\",\"version\":{version}}}"),
                },
                other => FrameKind::ServerError { message: format!("unexpected {other:?}") },
            };
            Frame::new(frame.request_id, kind)
        });
        let daemon = Daemon::build(DaemonConfig::default(), echo_exec(), Some(inline), None);
        let (mut client, server) = InProcTransport::pair();
        daemon.attach(Box::new(server), "t");
        client
            .send(&Frame::new(1, FrameKind::FetchManifest { model: "m".into(), version: 2 }))
            .unwrap();
        match client.recv().unwrap().kind {
            FrameKind::ManifestReply { ref json } => assert!(json.contains("\"version\":2")),
            ref other => panic!("unexpected {other:?}"),
        }
        daemon.shutdown();

        // Without an attached node the same frame is still refused
        // loudly instead of hanging or being dropped.
        let bare = Daemon::new(DaemonConfig::default(), echo_exec());
        let (mut client, server) = InProcTransport::pair();
        bare.attach(Box::new(server), "t");
        client
            .send(&Frame::new(2, FrameKind::FetchManifest { model: "m".into(), version: 2 }))
            .unwrap();
        assert!(matches!(client.recv().unwrap().kind, FrameKind::ServerError { .. }));
        bare.shutdown();
    }

    #[test]
    fn finished_pumps_and_idle_tenants_are_reaped_at_attach() {
        let daemon = Daemon::new(DaemonConfig::default(), echo_exec());
        for i in 0..8 {
            let (mut client, server) = InProcTransport::pair();
            daemon.attach(Box::new(server), &format!("ephemeral-{i}"));
            client.send(&infer(0, vec![1])).unwrap();
            let _ = client.recv().unwrap();
            // Dropping the client severs the link; the pump exits.
        }
        // Give the pumps a beat to observe their dead peers.
        std::thread::sleep(Duration::from_millis(100));
        let (_client, server) = InProcTransport::pair();
        daemon.attach(Box::new(server), "live");
        assert_eq!(daemon.tenant_count(), 0, "idle tenants evicted at attach");
        assert!(
            daemon.conns.lock().unwrap().len() < 9,
            "finished pump handles reaped at attach"
        );
        daemon.shutdown();
    }

    #[test]
    fn shutdown_answers_rather_than_drops() {
        let daemon = Daemon::new(DaemonConfig::default(), echo_exec());
        let (mut client, server) = InProcTransport::pair();
        daemon.attach(Box::new(server), "t");
        client.send(&infer(1, vec![9])).unwrap();
        let reply = client.recv().unwrap();
        assert!(matches!(reply.kind, FrameKind::Logits { .. }));
        daemon.shutdown();
        // The connection is closed after drain: a post-shutdown call
        // fails loudly instead of hanging.
        let _ = client.send(&infer(2, vec![9]));
        assert!(client.recv().is_err());
    }
}
