//! The split-computing serving system (Layer 3).
//!
//! Topology (Fig. 1a of the paper): an **edge node** runs the head
//! artifact, compresses the intermediate feature through the rANS
//! pipeline, and ships it over a **transport** (TCP, in-process, or the
//! ε-outage simulated link) to a **cloud node**, which decompresses and
//! runs the tail artifact, returning logits. A **batcher** groups
//! concurrent edge requests into the bucket sizes the artifacts were
//! compiled for.
//!
//! Between the nodes and the raw transport sits a **session layer**
//! ([`session`]) that owns the failure semantics: per-request IDs and
//! deadlines in the frame header, retry with capped exponential backoff
//! and deterministic jitter, heartbeat liveness with automatic
//! reconnect, explicit load-shed handling, and an edge-side
//! graceful-degradation policy. [`fault`] provides the deterministic
//! fault-injection transport the chaos soak drives.
//!
//! For fleet-scale serving, the [`daemon`] module rebuilds the cloud
//! side as a long-running actor system — supervised connection pumps
//! feeding an adaptively batching core with per-tenant quotas — whose
//! queue/wait/inflight/quota dials live in the shared, hot-swappable
//! [`knobs::ServingKnobs`] handle. [`loadgen`] drives that daemon with
//! a seeded synthetic fleet (hundreds of sessions × chaos links) as
//! the scale benchmark.
//!
//! * [`protocol`] — length-prefixed, CRC-checked wire frames.
//! * [`transport`] — TCP / in-proc duplex links + the simulated channel.
//! * [`session`] — retry/deadline/heartbeat/reconnect over a transport.
//! * [`fault`] — seeded fault-injection transport for chaos testing.
//! * [`cloud`] — the cloud server loop with bounded admission.
//! * [`edge`] — the edge client pipeline with its reshape-plan cache.
//! * [`batcher`] — bucketed dynamic batching.
//! * [`knobs`] — live-reconfigurable serving limits (atomics).
//! * [`daemon`] — actor-based serving daemon with adaptive batching.
//! * [`loadgen`] — synthetic fleet load generator for the daemon.

pub mod batcher;
pub mod cloud;
pub mod daemon;
pub mod edge;
pub mod fault;
pub mod knobs;
pub mod loadgen;
pub mod protocol;
pub mod router;
pub mod session;
pub mod transport;

pub use batcher::{Batcher, BatcherConfig};
pub use cloud::{Admission, AdmitPermit, CloudNode, RegistryProvider, ServerLimits};
pub use daemon::{Daemon, DaemonConfig};
pub use edge::{EdgeConfig, EdgeNode, InferOutcome, LmEdgeNode};
pub use fault::{FaultSpec, FaultStats, FaultyTransport};
pub use knobs::ServingKnobs;
pub use loadgen::{LoadgenConfig, LoadReport};
pub use protocol::{Frame, FrameKind};
pub use router::{RouteInput, Router};
pub use session::{
    DegradeEvent, DegradePolicy, DegradeState, Session, SessionConfig, WireSource,
};
pub use transport::{
    connect_tcp, connect_tcp_timeout, InProcTransport, SimulatedLink, TcpTransport, Transport,
};
