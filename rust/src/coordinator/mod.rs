//! The split-computing serving system (Layer 3).
//!
//! Topology (Fig. 1a of the paper): an **edge node** runs the head
//! artifact, compresses the intermediate feature through the rANS
//! pipeline, and ships it over a **transport** (TCP, in-process, or the
//! ε-outage simulated link) to a **cloud node**, which decompresses and
//! runs the tail artifact, returning logits. A **batcher** groups
//! concurrent edge requests into the bucket sizes the artifacts were
//! compiled for.
//!
//! * [`protocol`] — length-prefixed, CRC-checked wire frames.
//! * [`transport`] — TCP / in-proc duplex links + the simulated channel.
//! * [`cloud`] — the cloud server loop.
//! * [`edge`] — the edge client pipeline with its reshape-plan cache.
//! * [`batcher`] — bucketed dynamic batching.

pub mod batcher;
pub mod cloud;
pub mod edge;
pub mod protocol;
pub mod router;
pub mod transport;

pub use batcher::{Batcher, BatcherConfig};
pub use cloud::CloudNode;
pub use edge::{EdgeConfig, EdgeNode, InferOutcome, LmEdgeNode};
pub use protocol::{Frame, FrameKind};
pub use router::{RouteInput, Router};
pub use transport::{connect_tcp, InProcTransport, SimulatedLink, TcpTransport, Transport};
