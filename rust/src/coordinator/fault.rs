//! Deterministic fault injection for the serving path.
//!
//! [`FaultyTransport`] is a message-framed in-process transport (like
//! [`crate::coordinator::transport::InProcTransport`]) whose *send* side
//! injects seeded link faults on the raw wire bytes:
//!
//! * **drops** — the frame silently never arrives;
//! * **bit corruption** — one bit of the CRC-covered wire image flips,
//!   so the receiver's framing check rejects it (the same detection
//!   path a real garbled link exercises);
//! * **duplicate delivery** — the frame arrives twice, exercising the
//!   session layer's stale-reply filtering;
//! * **mid-frame disconnects** — only a prefix of the frame arrives;
//! * **delays** — bounded extra latency before delivery.
//!
//! Every fault is sampled from a [`Rng`] fork of the caller's seed, so a
//! chaos schedule replays bit-for-bit. Faults ride the *wire bytes*, not
//! the parsed frames: corruption really is caught by the protocol CRC
//! and truncation really is caught by the length prefix, which is what
//! makes the soak test a proof of the framing layer rather than a
//! simulation of one.
//!
//! Because a lossy link is the one place where garbled framing is an
//! expected *link* fault (not an implementation bug), this transport
//! reclassifies receive-side parse failures as retryable
//! [`Error::Transport`] — in contrast to `TcpTransport`, where a CRC
//! mismatch stays in the fatal corruption class.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::prng::Rng;

use super::protocol::Frame;
use super::transport::Transport;

/// Per-direction fault probabilities (all independent per frame).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// P(frame silently dropped).
    pub drop_prob: f64,
    /// P(one random bit of the wire image flipped).
    pub corrupt_prob: f64,
    /// P(frame delivered twice).
    pub duplicate_prob: f64,
    /// P(only a strict prefix of the frame delivered — the mid-frame
    /// disconnect shape).
    pub truncate_prob: f64,
    /// P(delivery delayed by a uniform amount below `max_delay`).
    pub delay_prob: f64,
    /// Upper bound of the injected delay.
    pub max_delay: Duration,
}

impl FaultSpec {
    /// No faults — behaves like a clean in-process link.
    pub fn none() -> Self {
        FaultSpec {
            drop_prob: 0.0,
            corrupt_prob: 0.0,
            duplicate_prob: 0.0,
            truncate_prob: 0.0,
            delay_prob: 0.0,
            max_delay: Duration::ZERO,
        }
    }

    /// Drop-only schedule.
    pub fn drops(p: f64) -> Self {
        FaultSpec { drop_prob: p, ..FaultSpec::none() }
    }

    /// Bit-corruption-only schedule.
    pub fn corruption(p: f64) -> Self {
        FaultSpec { corrupt_prob: p, ..FaultSpec::none() }
    }

    /// Duplicate-delivery-only schedule.
    pub fn duplicates(p: f64) -> Self {
        FaultSpec { duplicate_prob: p, ..FaultSpec::none() }
    }

    /// Mid-frame-disconnect-only schedule.
    pub fn truncations(p: f64) -> Self {
        FaultSpec { truncate_prob: p, ..FaultSpec::none() }
    }

    /// Delay-only schedule (uniform below `max_delay`).
    pub fn delays(p: f64, max_delay: Duration) -> Self {
        FaultSpec { delay_prob: p, max_delay, ..FaultSpec::none() }
    }

    /// Everything at once, each fault at probability `p`.
    pub fn chaos(p: f64, max_delay: Duration) -> Self {
        FaultSpec {
            drop_prob: p,
            corrupt_prob: p,
            duplicate_prob: p,
            truncate_prob: p,
            delay_prob: p,
            max_delay,
        }
    }
}

/// Counts of injected faults (per endpoint, send side).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Frames silently dropped.
    pub dropped: u64,
    /// Frames delivered with a flipped bit.
    pub corrupted: u64,
    /// Frames delivered twice.
    pub duplicated: u64,
    /// Frames delivered as a strict prefix.
    pub truncated: u64,
    /// Frames delivered late.
    pub delayed: u64,
}

/// An in-process transport endpoint that injects seeded faults on send.
pub struct FaultyTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    rng: Rng,
    spec: FaultSpec,
    stats: FaultStats,
}

impl FaultyTransport {
    /// Create a connected pair. `a_spec` governs faults on frames sent
    /// by the first endpoint, `b_spec` on frames sent by the second;
    /// each endpoint samples from its own decorrelated fork of `seed`.
    pub fn pair(seed: u64, a_spec: FaultSpec, b_spec: FaultSpec) -> (Self, Self) {
        let mut root = Rng::new(seed);
        let (tx_a, rx_b) = channel();
        let (tx_b, rx_a) = channel();
        let a = FaultyTransport {
            tx: tx_a,
            rx: rx_a,
            rng: root.fork(0),
            spec: a_spec,
            stats: FaultStats::default(),
        };
        let b = FaultyTransport {
            tx: tx_b,
            rx: rx_b,
            rng: root.fork(1),
            spec: b_spec,
            stats: FaultStats::default(),
        };
        (a, b)
    }

    /// Faults injected by this endpoint so far.
    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// All receive-side parse failures on this transport stem from
    /// injected link faults, so they classify as retryable transport
    /// errors — a resend genuinely helps.
    fn link_fault(e: Error) -> Error {
        Error::transport(format!("injected link fault: {e}"))
    }

    fn parse(wire: Vec<u8>) -> Result<Frame> {
        match Frame::from_wire(&wire) {
            Ok((frame, _)) => Ok(frame),
            Err(e) => Err(Self::link_fault(e)),
        }
    }
}

impl Transport for FaultyTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let mut wire = frame.to_wire();
        if self.rng.bool_with(self.spec.drop_prob) {
            self.stats.dropped += 1;
            return Ok(()); // the link ate it; the sender cannot tell
        }
        if self.rng.bool_with(self.spec.delay_prob) && !self.spec.max_delay.is_zero() {
            self.stats.delayed += 1;
            let nanos = self.spec.max_delay.as_nanos().min(u64::MAX as u128) as u64;
            std::thread::sleep(Duration::from_nanos(self.rng.below(nanos.max(1))));
        }
        if self.rng.bool_with(self.spec.truncate_prob) && wire.len() > 1 {
            self.stats.truncated += 1;
            let keep = 1 + self.rng.below_usize(wire.len() - 1);
            wire.truncate(keep);
            let _ = self.tx.send(wire);
            return Ok(()); // the connection died mid-frame
        }
        if self.rng.bool_with(self.spec.corrupt_prob) {
            self.stats.corrupted += 1;
            let bit = self.rng.below_usize(wire.len() * 8);
            wire[bit / 8] ^= 1 << (bit % 8);
        }
        let duplicate = self.rng.bool_with(self.spec.duplicate_prob);
        self.tx.send(wire.clone()).map_err(|_| Error::transport("peer closed"))?;
        if duplicate {
            self.stats.duplicated += 1;
            let _ = self.tx.send(wire);
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame> {
        let wire = self.rx.recv().map_err(|_| Error::transport("peer closed"))?;
        Self::parse(wire)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Frame> {
        let wire = self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => Error::timeout("recv deadline elapsed"),
            RecvTimeoutError::Disconnected => Error::transport("peer closed"),
        })?;
        Self::parse(wire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::FrameKind;

    fn ping(id: u64) -> Frame {
        Frame::new(id, FrameKind::Ping)
    }

    fn pair(seed: u64, spec: FaultSpec) -> (FaultyTransport, FaultyTransport) {
        FaultyTransport::pair(seed, spec, FaultSpec::none())
    }

    #[test]
    fn clean_spec_behaves_like_inproc() {
        let (mut a, mut b) = FaultyTransport::pair(1, FaultSpec::none(), FaultSpec::none());
        for i in 0..100 {
            a.send(&ping(i)).unwrap();
            assert_eq!(b.recv().unwrap(), ping(i));
        }
        assert_eq!(a.stats(), FaultStats::default());
    }

    #[test]
    fn drops_are_silent_and_seeded() {
        let run = |seed| {
            let (mut a, mut b) = pair(seed, FaultSpec::drops(0.3));
            for i in 0..200 {
                a.send(&ping(i)).unwrap();
            }
            let mut arrived = 0u64;
            while b.recv_timeout(Duration::from_millis(1)).is_ok() {
                arrived += 1;
            }
            (arrived, a.stats().dropped)
        };
        let (arrived, dropped) = run(7);
        assert_eq!(arrived + dropped, 200);
        assert!(dropped > 20, "p=0.3 over 200 sends should drop often, saw {dropped}");
        // Same seed → bit-identical schedule.
        assert_eq!(run(7), (arrived, dropped));
    }

    #[test]
    fn corruption_is_caught_by_the_frame_crc() {
        let (mut a, mut b) = pair(3, FaultSpec::corruption(1.0));
        a.send(&ping(1)).unwrap();
        let err = b.recv().unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(err.is_retryable(), "a garbled in-flight frame must invite a resend");
    }

    #[test]
    fn truncation_is_caught_by_the_length_prefix() {
        let (mut a, mut b) = pair(5, FaultSpec::truncations(1.0));
        a.send(&ping(1)).unwrap();
        let err = b.recv().unwrap_err();
        assert!(err.is_retryable(), "{err}");
        assert_eq!(a.stats().truncated, 1);
    }

    #[test]
    fn duplicates_deliver_twice() {
        let (mut a, mut b) = pair(9, FaultSpec::duplicates(1.0));
        a.send(&ping(42)).unwrap();
        assert_eq!(b.recv().unwrap(), ping(42));
        assert_eq!(b.recv().unwrap(), ping(42));
        assert_eq!(a.stats().duplicated, 1);
    }
}
