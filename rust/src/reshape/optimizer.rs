//! Algorithm 1 — approximate enumeration for the optimal reshape `Ñ`.
//!
//! Domain restrictions (§3.3):
//! 1. `N > √T` (more rows than columns preserves row-compression),
//! 2. `K = T/N ≤ 2^Q` (otherwise the alphabet of `c` inflates),
//! 3. `N | T`.
//!
//! Candidates are walked in *descending* N; the loop stops early once
//! `T_tot(N)` increases relative to the previous iteration (the cost is
//! empirically near-unimodal over the constrained domain). A `patience`
//! knob generalizes the paper's immediate break (`patience = 1`) for the
//! ablation bench.

use crate::error::{Error, Result};

use super::cost::{evaluate, LatencyTerms, ReshapeCost};
use super::divisors::{divisors, isqrt};

/// Configuration of the Algorithm-1 search.
#[derive(Debug, Clone)]
pub struct OptimizerConfig {
    /// Quantization bit-width `Q` (bounds `K ≤ 2^Q`).
    pub q: u8,
    /// Consecutive cost increases tolerated before stopping (paper: 1).
    pub patience: usize,
    /// Enforce restriction 1 (`N > √T`). On by default; the ablation
    /// bench disables it to measure what the restriction buys.
    pub enforce_tall: bool,
    /// Enforce restriction 2 (`K ≤ 2^Q`).
    pub enforce_alphabet_cap: bool,
    /// Latency terms of Eq. 7 (default zero).
    pub latency: LatencyTerms,
}

impl OptimizerConfig {
    /// Paper-default configuration for bit-width `q`.
    pub fn paper(q: u8) -> Self {
        OptimizerConfig {
            q,
            patience: 1,
            enforce_tall: true,
            enforce_alphabet_cap: true,
            latency: LatencyTerms::default(),
        }
    }
}

/// Result of a reshape search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// The selected reshape and its cost breakdown.
    pub best: ReshapeCost,
    /// Number of candidate `N` values actually evaluated.
    pub evaluated: usize,
    /// Number of candidates in the (constrained) domain.
    pub domain_size: usize,
    /// Every evaluated candidate, in visit order (for Fig. 4 curves).
    pub trace: Vec<ReshapeCost>,
}

/// Lower bound of the constrained domain:
/// `N_min = max(⌊√T⌋ + 1, ⌈T / 2^Q⌉)` (Algorithm 1 line 2).
pub fn n_min(t: usize, q: u8, enforce_tall: bool, enforce_cap: bool) -> usize {
    let mut lo = 1usize;
    if enforce_tall {
        lo = lo.max(isqrt(t) + 1);
    }
    if enforce_cap {
        let cap = 1usize << q;
        lo = lo.max(t.div_ceil(cap));
    }
    lo
}

/// The constrained candidate list for `t`, ascending.
pub fn candidate_domain(t: usize, cfg: &OptimizerConfig) -> Vec<usize> {
    let lo = n_min(t, cfg.q, cfg.enforce_tall, cfg.enforce_alphabet_cap);
    divisors(t).into_iter().filter(|&n| n >= lo).collect()
}

/// Algorithm 1: approximate search for `Ñ`.
///
/// `symbols` is the AIQ-quantized flat tensor, `background` its zero
/// symbol. Returns the best candidate found before early stopping.
pub fn optimize(symbols: &[u16], background: u16, cfg: &OptimizerConfig) -> Result<SearchOutcome> {
    let t = symbols.len();
    if t == 0 {
        return Err(Error::invalid("cannot optimize reshape of empty tensor"));
    }
    let value_alphabet = 1usize << cfg.q;
    let domain = candidate_domain(t, cfg);
    if domain.is_empty() {
        return Err(Error::invalid(format!(
            "no valid reshape for T={t}, Q={}: domain empty",
            cfg.q
        )));
    }

    let mut best: Option<ReshapeCost> = None;
    let mut prev_cost = f64::INFINITY;
    let mut rises = 0usize;
    let mut trace = Vec::new();

    // Descending N (Algorithm 1 line 4).
    for &n in domain.iter().rev() {
        let cost = evaluate(symbols, n, background, value_alphabet, &cfg.latency)?;
        let tt = cost.t_tot_bits;
        trace.push(cost.clone());
        if best.as_ref().map_or(true, |b| tt < b.t_tot_bits) {
            best = Some(cost);
        }
        if tt > prev_cost {
            rises += 1;
            if rises >= cfg.patience {
                break;
            }
        } else {
            rises = 0;
        }
        prev_cost = tt;
    }

    Ok(SearchOutcome {
        best: best.expect("domain nonempty implies at least one candidate"),
        evaluated: trace.len(),
        domain_size: domain.len(),
        trace,
    })
}

/// Exhaustive oracle `N*`: evaluates *every* divisor in the (optionally
/// constrained) domain. Used by Fig. 4 to measure the `Ñ` vs `N*` gap.
pub fn exhaustive_search(
    symbols: &[u16],
    background: u16,
    cfg: &OptimizerConfig,
    constrained: bool,
) -> Result<SearchOutcome> {
    let t = symbols.len();
    if t == 0 {
        return Err(Error::invalid("cannot optimize reshape of empty tensor"));
    }
    let value_alphabet = 1usize << cfg.q;
    let domain: Vec<usize> = if constrained {
        candidate_domain(t, cfg)
    } else {
        divisors(t)
    };
    if domain.is_empty() {
        return Err(Error::invalid("empty search domain"));
    }
    let mut best: Option<ReshapeCost> = None;
    let mut trace = Vec::with_capacity(domain.len());
    for &n in domain.iter().rev() {
        let cost = evaluate(symbols, n, background, value_alphabet, &cfg.latency)?;
        if best.as_ref().map_or(true, |b| cost.t_tot_bits < b.t_tot_bits) {
            best = Some(cost.clone());
        }
        trace.push(cost);
    }
    Ok(SearchOutcome {
        best: best.unwrap(),
        evaluated: trace.len(),
        domain_size: domain.len(),
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, QuantParams};
    use crate::util::prng::Rng;

    fn quantized_feature(seed: u64, c: usize, h: usize, w: usize, q: u8) -> (Vec<u16>, u16) {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; c * h * w];
        for ch in 0..c {
            let act = rng.next_f64();
            for i in 0..h * w {
                if rng.next_f64() < 0.35 * act * 2.0 {
                    x[ch * h * w + i] = (rng.normal().abs() as f32) * (0.5 + act as f32);
                }
            }
        }
        let p = QuantParams::fit(q, &x).unwrap();
        (quantize(&x, &p), p.zero_symbol())
    }

    #[test]
    fn domain_respects_restrictions() {
        let t = 128 * 28 * 28; // 100352
        let cfg = OptimizerConfig::paper(4);
        let domain = candidate_domain(t, &cfg);
        let sqrt_t = isqrt(t);
        for &n in &domain {
            assert!(n > sqrt_t, "N={n} violates N > √T");
            assert!(t / n <= 16, "K={} violates K ≤ 2^Q", t / n);
            assert_eq!(t % n, 0);
        }
        // T/2^Q = 6272 dominates √T here.
        assert_eq!(*domain.first().unwrap(), 6272);
    }

    #[test]
    fn n_min_both_branches() {
        // Small Q: alphabet cap binds. Large Q: √T binds.
        assert_eq!(n_min(100, 2, true, true), 25); // ceil(100/4)=25 > 11
        assert_eq!(n_min(100, 8, true, true), 11); // √100+1
        assert_eq!(n_min(100, 8, false, true), 1);
        assert_eq!(n_min(100, 8, false, false), 1);
    }

    #[test]
    fn optimizer_matches_oracle_closely() {
        // The paper reports Ñ within 2–3% of N* on compression size.
        for seed in 0..4u64 {
            let (syms, bg) = quantized_feature(seed, 32, 14, 14, 4);
            let cfg = OptimizerConfig::paper(4);
            let approx = optimize(&syms, bg, &cfg).unwrap();
            let oracle = exhaustive_search(&syms, bg, &cfg, true).unwrap();
            let gap = approx.best.t_tot_bits / oracle.best.t_tot_bits.max(1e-9);
            assert!(gap <= 1.05, "seed {seed}: gap {gap}");
            assert!(approx.evaluated <= oracle.evaluated);
        }
    }

    #[test]
    fn early_stopping_prunes_work() {
        let (syms, bg) = quantized_feature(99, 64, 14, 14, 4);
        let cfg = OptimizerConfig::paper(4);
        let approx = optimize(&syms, bg, &cfg).unwrap();
        // Must have terminated before scanning the whole domain in the
        // typical case; tolerate equality for unusually monotone costs.
        assert!(approx.evaluated <= approx.domain_size);
    }

    #[test]
    fn patience_increases_coverage() {
        let (syms, bg) = quantized_feature(5, 32, 8, 8, 4);
        let mut c1 = OptimizerConfig::paper(4);
        c1.patience = 1;
        let mut c3 = OptimizerConfig::paper(4);
        c3.patience = 3;
        let r1 = optimize(&syms, bg, &c1).unwrap();
        let r3 = optimize(&syms, bg, &c3).unwrap();
        assert!(r3.evaluated >= r1.evaluated);
        assert!(r3.best.t_tot_bits <= r1.best.t_tot_bits);
    }

    #[test]
    fn empty_tensor_rejected() {
        assert!(optimize(&[], 0, &OptimizerConfig::paper(4)).is_err());
    }

    #[test]
    fn prime_t_still_has_trivial_reshape() {
        // T prime → only N = T survives the constraints (K = 1).
        let (syms, bg) = quantized_feature(7, 1, 1, 97, 4);
        let out = optimize(&syms, bg, &OptimizerConfig::paper(4)).unwrap();
        assert_eq!(out.best.n, 97);
        assert_eq!(out.best.k, 1);
    }

    #[test]
    fn trace_is_descending_in_n() {
        let (syms, bg) = quantized_feature(11, 16, 8, 8, 4);
        let out = optimize(&syms, bg, &OptimizerConfig::paper(4)).unwrap();
        for w in out.trace.windows(2) {
            assert!(w[0].n > w[1].n);
        }
    }
}
