//! The approximate cost model `T_tot(N) = ℓ_D · H(p(N))` (Eq. 7).
//!
//! For a candidate reshape `N`, the model performs the actual CSR
//! encoding of the quantized symbols (O(T), as in Algorithm 1 line 8),
//! histograms the concatenated stream `D = v ⊕ c ⊕ r`, and evaluates the
//! Shannon entropy. `α_enc`/`α_dec` from Eq. 7 are carried for
//! completeness but default to the paper's Algorithm-1 setting of 0 —
//! Fig. 3 shows encode/decode latency is N-invariant on parallel
//! hardware, so they do not move the argmin.

use crate::error::Result;
use crate::sparse::ModCsr;
use crate::util::stats;

/// Cost-model evaluation at one reshape dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct ReshapeCost {
    /// Rows `N`.
    pub n: usize,
    /// Columns `K = T/N`.
    pub k: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Length of the concatenated stream `ℓ_D = 2·nnz + N`.
    pub ell_d: usize,
    /// Alphabet of `D` (`max(2^Q, K, max row count + 1)`).
    pub alphabet: usize,
    /// Shannon entropy of `D`, bits/symbol.
    pub entropy: f64,
    /// `T_tot(N)` in bits: `ℓ_D · H` plus the (default-zero) latency terms.
    pub t_tot_bits: f64,
}

impl ReshapeCost {
    /// Model-predicted compressed size in bytes (excluding headers).
    pub fn predicted_bytes(&self) -> f64 {
        self.t_tot_bits / 8.0
    }
}

/// Latency constants of Eq. 7. Defaults reproduce Algorithm 1
/// (`α_enc = α_dec = 0`).
#[derive(Debug, Clone, Copy)]
pub struct LatencyTerms {
    /// Weight on the encode-time term.
    pub alpha_enc: f64,
    /// Weight on the decode-time term.
    pub alpha_dec: f64,
    /// Measured per-call encode latency proxy, bits-equivalent.
    pub t_enc: f64,
    /// Measured per-call decode latency proxy, bits-equivalent.
    pub t_dec: f64,
}

impl Default for LatencyTerms {
    fn default() -> Self {
        LatencyTerms { alpha_enc: 0.0, alpha_dec: 0.0, t_enc: 0.0, t_dec: 0.0 }
    }
}

/// Evaluate the cost model at reshape `N` for quantized `symbols`.
///
/// * `background` — the AIQ zero symbol (implicit zero of the CSR).
/// * `value_alphabet` — `2^Q`.
pub fn evaluate(
    symbols: &[u16],
    n: usize,
    background: u16,
    value_alphabet: usize,
    lat: &LatencyTerms,
) -> Result<ReshapeCost> {
    let t = symbols.len();
    let k = t / n.max(1);
    let csr = ModCsr::encode(symbols, n, k, background)?;
    let d = csr.concat();
    let alphabet = csr.concat_alphabet(value_alphabet);
    let freqs = stats::histogram(&d, alphabet);
    let entropy = stats::shannon_entropy(&freqs);
    let ell_d = d.len();
    let t_tot_bits =
        ell_d as f64 * entropy + lat.alpha_enc * lat.t_enc + lat.alpha_dec * lat.t_dec;
    Ok(ReshapeCost { n, k, nnz: csr.nnz(), ell_d, alphabet, entropy, t_tot_bits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, QuantParams};
    use crate::util::prng::Rng;

    /// Synthesize a post-ReLU-like IF: sparse, positive, channel-skewed.
    pub(crate) fn synth_feature(seed: u64, c: usize, h: usize, w: usize, density: f64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut out = vec![0.0f32; c * h * w];
        for ch in 0..c {
            // Per-channel activity level: some channels nearly silent.
            let act = rng.next_f64();
            for i in 0..h * w {
                if rng.next_f64() < density * act * 2.0 {
                    out[ch * h * w + i] = (rng.normal().abs() as f32) * (0.5 + act as f32);
                }
            }
        }
        out
    }

    #[test]
    fn ell_d_formula_holds() {
        let x = synth_feature(1, 16, 8, 8, 0.4);
        let p = QuantParams::fit(4, &x).unwrap();
        let syms = quantize(&x, &p);
        let cost = evaluate(&syms, 64, p.zero_symbol(), p.alphabet(), &LatencyTerms::default())
            .unwrap();
        assert_eq!(cost.ell_d, 2 * cost.nnz + 64);
        assert_eq!(cost.k, 16);
    }

    #[test]
    fn entropy_zero_for_constant_tensor() {
        let syms = vec![0u16; 256];
        let cost =
            evaluate(&syms, 16, 0, 16, &LatencyTerms::default()).unwrap();
        // All background → D = r only (all zero counts) → zero entropy.
        assert_eq!(cost.nnz, 0);
        assert_eq!(cost.t_tot_bits, 0.0);
    }

    #[test]
    fn non_divisor_reshape_fails() {
        let syms = vec![1u16; 100];
        assert!(evaluate(&syms, 7, 0, 16, &LatencyTerms::default()).is_err());
    }

    #[test]
    fn cost_varies_with_n() {
        // The whole point of §3.2: different N, different T_tot.
        let x = synth_feature(2, 32, 14, 14, 0.3);
        let p = QuantParams::fit(4, &x).unwrap();
        let syms = quantize(&x, &p);
        let t = syms.len();
        let lat = LatencyTerms::default();
        let costs: Vec<f64> = [t / 128, t / 16, t / 4]
            .iter()
            .map(|&n| evaluate(&syms, n, p.zero_symbol(), 16, &lat).unwrap().t_tot_bits)
            .collect();
        assert!(
            costs.windows(2).any(|w| (w[0] - w[1]).abs() > 1.0),
            "cost should depend on N: {costs:?}"
        );
    }

    #[test]
    fn model_tracks_actual_rans_size() {
        // The predicted size must be within ~15% of the real bitstream
        // (paper reports close tracking in Fig. 4).
        let x = synth_feature(3, 64, 14, 14, 0.35);
        let p = QuantParams::fit(4, &x).unwrap();
        let syms = quantize(&x, &p);
        let n = syms.len() / 16;
        let cost =
            evaluate(&syms, n, p.zero_symbol(), p.alphabet(), &LatencyTerms::default()).unwrap();

        let csr = ModCsr::encode(&syms, n, 16, p.zero_symbol()).unwrap();
        let d = csr.concat();
        let table = crate::rans::FreqTable::from_symbols(&d, cost.alphabet);
        let bytes = crate::rans::encode(&d, &table).unwrap();
        let actual_bits = bytes.len() as f64 * 8.0;
        let ratio = actual_bits / cost.t_tot_bits.max(1.0);
        assert!(
            (0.85..1.15).contains(&ratio),
            "model {} bits vs actual {} bits (ratio {ratio})",
            cost.t_tot_bits,
            actual_bits
        );
    }
}
