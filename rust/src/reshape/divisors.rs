//! Divisor enumeration for the reshape search domain.
//!
//! Both `N` and `K = T/N` must be integers, so candidates are exactly
//! the divisors of `T`. `|D(T)|` is tiny relative to `T` (the paper's
//! complexity analysis leans on this), so trial division to `√T` is
//! more than fast enough for IF-sized tensors.

/// All divisors of `t` in ascending order. `divisors(0)` is empty.
pub fn divisors(t: usize) -> Vec<usize> {
    if t == 0 {
        return Vec::new();
    }
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1usize;
    while d * d <= t {
        if t % d == 0 {
            small.push(d);
            if d != t / d {
                large.push(t / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Divisors of `t` inside `[lo, hi]`, ascending.
pub fn divisors_in(t: usize, lo: usize, hi: usize) -> Vec<usize> {
    divisors(t).into_iter().filter(|&d| d >= lo && d <= hi).collect()
}

/// Integer square root (floor).
pub fn isqrt(t: usize) -> usize {
    if t == 0 {
        return 0;
    }
    let mut x = (t as f64).sqrt() as usize;
    // Correct float rounding in both directions.
    while x.saturating_mul(x) > t {
        x -= 1;
    }
    while (x + 1).saturating_mul(x + 1) <= t {
        x += 1;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_of_small_numbers() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(13), vec![1, 13]);
        assert!(divisors(0).is_empty());
    }

    #[test]
    fn divisors_are_sorted_and_complete() {
        for t in [36usize, 100, 97, 1024, 100352] {
            let ds = divisors(t);
            assert!(ds.windows(2).all(|w| w[0] < w[1]));
            for &d in &ds {
                assert_eq!(t % d, 0);
            }
            // Complete: brute force check.
            let brute: Vec<usize> = (1..=t).filter(|d| t % d == 0).collect();
            assert_eq!(ds, brute, "t={t}");
        }
    }

    #[test]
    fn paper_example_tensor() {
        // Fig. 2 uses T = 128·28·28 = 100352 with N ∈ {784, 1792, 6272, 14336}.
        let t = 128 * 28 * 28;
        let ds = divisors(t);
        for n in [784usize, 1792, 6272, 14336] {
            assert!(ds.contains(&n), "N={n} should divide {t}");
        }
    }

    #[test]
    fn range_filter() {
        assert_eq!(divisors_in(12, 3, 6), vec![3, 4, 6]);
        assert!(divisors_in(12, 13, 20).is_empty());
    }

    #[test]
    fn isqrt_exact() {
        for t in 0..2000usize {
            let r = isqrt(t);
            assert!(r * r <= t && (r + 1) * (r + 1) > t, "t={t} r={r}");
        }
        assert_eq!(isqrt(100352), 316);
    }
}
