//! Reshape-dimension optimization (§3.2–3.3).
//!
//! Reshaping the flat IF tensor `X ∈ R^T` to `X' ∈ R^{N×K}` changes the
//! distribution of the CSR arrays (`c` spans `{0..K-1}`, `r` spans
//! `{0..K}`), hence the entropy of the concatenated stream `D` and the
//! rANS bitstream size. This module implements:
//!
//! * [`divisors`] — enumeration of valid `N` (`N | T`),
//! * [`cost`] — the cost model `T_tot(N) = ℓ_D · H(p(N))` (Eq. 7),
//! * [`optimizer`] — Algorithm 1 (approximate enumeration with domain
//!   restrictions `N > √T`, `K ≤ 2^Q` and early stopping) plus the
//!   exhaustive oracle `N*` used to validate the `Ñ ≈ N*` claim (Fig. 4).

pub mod cost;
pub mod divisors;
pub mod optimizer;

pub use cost::{evaluate, ReshapeCost};
pub use divisors::divisors;
pub use optimizer::{exhaustive_search, optimize, OptimizerConfig, SearchOutcome};
