//! General-purpose compressors as extra comparators.
//!
//! Not part of the paper's Table 1, but useful context in
//! EXPERIMENTS.md: how far a tuned entropy pipeline is from what a
//! deployment would get by simply piping the tensor through zstd or
//! deflate.

use std::io::{Read, Write};

use crate::error::{Error, Result};
use crate::util::varint;

use super::TensorCodec;

fn to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn from_bytes(bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    if bytes.len() != n * 4 {
        return Err(Error::corrupt("decompressed payload length mismatch"));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// zstd at a configurable level (default 3, the library default).
#[derive(Debug, Clone, Copy)]
pub struct ZstdCodec {
    /// Compression level (1–22).
    pub level: i32,
}

impl Default for ZstdCodec {
    fn default() -> Self {
        ZstdCodec { level: 3 }
    }
}

impl TensorCodec for ZstdCodec {
    fn name(&self) -> &'static str {
        "zstd"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let raw = to_bytes(data);
        let compressed = zstd::bulk::compress(&raw, self.level)
            .map_err(|e| Error::codec(format!("zstd: {e}")))?;
        let mut out = Vec::with_capacity(compressed.len() + 8);
        varint::write_usize(&mut out, data.len());
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let n = varint::read_usize(bytes, &mut pos)?;
        let raw = zstd::bulk::decompress(&bytes[pos..], n * 4 + 64)
            .map_err(|e| Error::corrupt(format!("zstd: {e}")))?;
        from_bytes(&raw, n)
    }
}

/// DEFLATE via flate2 (zlib format).
#[derive(Debug, Clone, Copy)]
pub struct DeflateCodec {
    /// Compression level (0–9).
    pub level: u32,
}

impl Default for DeflateCodec {
    fn default() -> Self {
        DeflateCodec { level: 6 }
    }
}

impl TensorCodec for DeflateCodec {
    fn name(&self) -> &'static str {
        "deflate"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let raw = to_bytes(data);
        let mut enc = flate2::write::ZlibEncoder::new(
            Vec::new(),
            flate2::Compression::new(self.level),
        );
        enc.write_all(&raw)?;
        let compressed = enc.finish()?;
        let mut out = Vec::with_capacity(compressed.len() + 8);
        varint::write_usize(&mut out, data.len());
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let n = varint::read_usize(bytes, &mut pos)?;
        let mut dec = flate2::read::ZlibDecoder::new(&bytes[pos..]);
        let mut raw = Vec::with_capacity(n * 4);
        dec.read_to_end(&mut raw)?;
        from_bytes(&raw, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::relu_feature;

    #[test]
    fn zstd_roundtrip_and_compression() {
        let data = relu_feature(31, 30_000);
        let codec = ZstdCodec::default();
        let bytes = codec.encode(&data).unwrap();
        let back = codec.decode(&bytes).unwrap();
        assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(bytes.len() < data.len() * 4);
    }

    #[test]
    fn deflate_roundtrip_and_compression() {
        let data = relu_feature(32, 30_000);
        let codec = DeflateCodec::default();
        let bytes = codec.encode(&data).unwrap();
        let back = codec.decode(&bytes).unwrap();
        assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(bytes.len() < data.len() * 4);
    }

    #[test]
    fn corrupt_zstd_rejected() {
        let data = relu_feature(33, 1000);
        let codec = ZstdCodec::default();
        let mut bytes = codec.encode(&data).unwrap();
        let mid = bytes.len() / 2;
        bytes.truncate(mid);
        assert!(codec.decode(&bytes).is_err());
    }
}
