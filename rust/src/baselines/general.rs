//! General-purpose compressors as extra comparators.
//!
//! Not part of the paper's Table 1, but useful context in
//! EXPERIMENTS.md: how far the tuned entropy pipeline is from what a
//! deployment would get by piping the raw tensor bytes through a
//! general-purpose codec. The offline build cannot link zstd/flate2, so
//! the two comparators here are self-contained stand-ins for the same
//! two codec families:
//!
//! * [`Lz77Codec`] — greedy hash-head LZ77 (the dictionary/match half of
//!   an LZ4/deflate-class codec) over the little-endian f32 byte stream.
//! * [`ByteRansCodec`] — order-0 rANS over the raw byte stream (the
//!   entropy-coding half), reusing the crate's own coder with a
//!   256-symbol alphabet.

use crate::error::{Error, Result};
use crate::rans::{decode, encode, FreqTable};
use crate::util::varint;

use super::TensorCodec;

fn to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn from_bytes(bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    if bytes.len() != n * 4 {
        return Err(Error::corrupt("decompressed payload length mismatch"));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ------------------------------------------------------------------ lz77

/// Minimum match length the LZ77 encoder emits (below this a literal is
/// cheaper than the tag + length + distance varints).
const MIN_MATCH: usize = 4;
/// Hash-table size (power of two) for 4-byte prefix heads.
const HASH_BITS: u32 = 15;

#[inline]
fn lz_hash(key: u32) -> usize {
    (key.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// Greedy LZ77 with a single-head prefix hash (LZ4-fast style matching),
/// varint-framed tokens, unlimited window.
///
/// Token stream, after a varint element count: repeated ops, each either
/// `0x00, varint len, len raw bytes` (literal run) or `0x01, varint len,
/// varint distance` (match, `len ≥ MIN_MATCH`, `distance ≥ 1`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Lz77Codec;

impl Lz77Codec {
    fn compress_bytes(raw: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(raw.len() / 2 + 16);
        let mut heads = vec![usize::MAX; 1 << HASH_BITS];
        let mut pos = 0usize;
        let mut lit_start = 0usize;

        let flush_literals = |out: &mut Vec<u8>, lit: &[u8]| {
            if !lit.is_empty() {
                out.push(0x00);
                varint::write_usize(out, lit.len());
                out.extend_from_slice(lit);
            }
        };

        while pos + MIN_MATCH <= raw.len() {
            let key = u32::from_le_bytes([raw[pos], raw[pos + 1], raw[pos + 2], raw[pos + 3]]);
            let slot = lz_hash(key);
            let candidate = heads[slot];
            heads[slot] = pos;
            if candidate != usize::MAX
                && candidate < pos
                && raw[candidate..candidate + MIN_MATCH] == raw[pos..pos + MIN_MATCH]
            {
                // Extend the match as far as it goes.
                let mut len = MIN_MATCH;
                while pos + len < raw.len() && raw[candidate + len] == raw[pos + len] {
                    len += 1;
                }
                flush_literals(&mut out, &raw[lit_start..pos]);
                out.push(0x01);
                varint::write_usize(&mut out, len);
                varint::write_usize(&mut out, pos - candidate);
                pos += len;
                lit_start = pos;
            } else {
                pos += 1;
            }
        }
        flush_literals(&mut out, &raw[lit_start..]);
        out
    }

    fn decompress_bytes(bytes: &[u8], pos: &mut usize, expect: usize) -> Result<Vec<u8>> {
        // `expect` is attacker-declared; cap the reservation (growth is
        // amortized) so a forged element count cannot abort the allocator.
        let mut out = Vec::with_capacity(expect.min(1 << 20));
        while *pos < bytes.len() {
            let tag = bytes[*pos];
            *pos += 1;
            match tag {
                0x00 => {
                    let len = varint::read_usize(bytes, pos)?;
                    let end = pos
                        .checked_add(len)
                        .filter(|&e| e <= bytes.len())
                        .ok_or_else(|| Error::corrupt("lz77 literal run truncated"))?;
                    out.extend_from_slice(&bytes[*pos..end]);
                    *pos = end;
                }
                0x01 => {
                    let len = varint::read_usize(bytes, pos)?;
                    let dist = varint::read_usize(bytes, pos)?;
                    if len < MIN_MATCH {
                        return Err(Error::corrupt("lz77 match below minimum length"));
                    }
                    if dist == 0 || dist > out.len() {
                        return Err(Error::corrupt("lz77 match distance out of range"));
                    }
                    // Bound *before* copying: `len` is attacker-controlled,
                    // so a corrupt stream must fail cleanly instead of
                    // allocating `len` bytes first.
                    if len > expect - out.len() {
                        return Err(Error::corrupt("lz77 match overruns declared length"));
                    }
                    // Byte-wise copy: matches may overlap their own output
                    // (dist < len encodes an RLE-style repetition).
                    let start = out.len() - dist;
                    for i in 0..len {
                        let b = out[start + i];
                        out.push(b);
                    }
                }
                t => return Err(Error::corrupt(format!("lz77 bad op tag {t}"))),
            }
            if out.len() > expect {
                return Err(Error::corrupt("lz77 output exceeds declared length"));
            }
        }
        Ok(out)
    }
}

impl TensorCodec for Lz77Codec {
    fn name(&self) -> &'static str {
        "lz77"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let raw = to_bytes(data);
        let compressed = Self::compress_bytes(&raw);
        let mut out = Vec::with_capacity(compressed.len() + 8);
        varint::write_usize(&mut out, data.len());
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let n = varint::read_usize(bytes, &mut pos)?;
        // The per-op bound below is relative to `expect`, so `expect`
        // itself must be plausible or a forged count re-opens the
        // match-copy bomb.
        if n > crate::pipeline::container::MAX_DECODE_SYMBOLS {
            return Err(Error::corrupt(format!(
                "lz77 declared element count {n} exceeds decode cap"
            )));
        }
        let expect = n
            .checked_mul(4)
            .ok_or_else(|| Error::corrupt("lz77 element count overflow"))?;
        let raw = Self::decompress_bytes(bytes, &mut pos, expect)?;
        from_bytes(&raw, n)
    }
}

// -------------------------------------------------------------- byte-rans

/// Order-0 rANS over the little-endian f32 byte stream (alphabet 256).
///
/// Layout: varint element count, serialized frequency table, rANS
/// payload to the end of the buffer.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteRansCodec;

impl TensorCodec for ByteRansCodec {
    fn name(&self) -> &'static str {
        "byte-rans"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let raw = to_bytes(data);
        let symbols: Vec<u32> = raw.iter().map(|&b| b as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 256);
        let payload = encode(&symbols, &table)?;
        let mut out = Vec::with_capacity(payload.len() + 64);
        varint::write_usize(&mut out, data.len());
        table.serialize(&mut out);
        out.extend_from_slice(&payload);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let n = varint::read_usize(bytes, &mut pos)?;
        // A degenerate (single-symbol) table legally decodes any declared
        // count from a 4-byte stream, so the count must be bounded before
        // the decode loop runs — same class as the container-level cap.
        if n > crate::pipeline::container::MAX_DECODE_SYMBOLS {
            return Err(Error::corrupt(format!(
                "byte-rans declared element count {n} exceeds decode cap"
            )));
        }
        let table = FreqTable::deserialize(bytes, &mut pos)?;
        let count = n
            .checked_mul(4)
            .ok_or_else(|| Error::corrupt("byte-rans element count overflow"))?;
        let symbols = decode(&bytes[pos..], count, &table)?;
        // `symbols.len() == count` only after a successful decode, so this
        // reservation is bounded by real data, not the declared header.
        let mut raw = Vec::with_capacity(symbols.len());
        for s in symbols {
            let b =
                u8::try_from(s).map_err(|_| Error::corrupt("byte-rans symbol outside u8"))?;
            raw.push(b);
        }
        from_bytes(&raw, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::relu_feature;

    #[test]
    fn lz77_roundtrip_and_compression() {
        let data = relu_feature(31, 30_000);
        let codec = Lz77Codec;
        let bytes = codec.encode(&data).unwrap();
        let back = codec.decode(&bytes).unwrap();
        assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(bytes.len() < data.len() * 4, "{} !< {}", bytes.len(), data.len() * 4);
    }

    #[test]
    fn byte_rans_roundtrip_and_compression() {
        let data = relu_feature(32, 30_000);
        let codec = ByteRansCodec;
        let bytes = codec.encode(&data).unwrap();
        let back = codec.decode(&bytes).unwrap();
        assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(bytes.len() < data.len() * 4, "{} !< {}", bytes.len(), data.len() * 4);
    }

    #[test]
    fn lz77_handles_incompressible_and_tiny_inputs() {
        // Tiny and irregular tensors must roundtrip even when no match
        // is ever found (pure literal runs).
        for data in [vec![], vec![1.5f32], vec![1.0f32, -2.0, 3.25, -4.75, 0.125]] {
            let codec = Lz77Codec;
            let back = codec.decode(&codec.encode(&data).unwrap()).unwrap();
            assert_eq!(back.len(), data.len());
            assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn byte_rans_empty_tensor() {
        let codec = ByteRansCodec;
        let bytes = codec.encode(&[]).unwrap();
        assert!(codec.decode(&bytes).unwrap().is_empty());
    }

    #[test]
    fn byte_rans_huge_declared_count_rejected_without_decoding() {
        // A degenerate table + forged count must fail on the cap, not
        // decode trillions of symbols from a 4-byte stream.
        let data = vec![0.0f32; 16];
        let bytes = ByteRansCodec.encode(&data).unwrap();
        let mut forged = Vec::new();
        varint::write_usize(&mut forged, 1usize << 40);
        // Reuse the real table+payload tail from a legit container.
        let mut pos = 0usize;
        varint::read_usize(&bytes, &mut pos).unwrap();
        forged.extend_from_slice(&bytes[pos..]);
        assert!(ByteRansCodec.decode(&forged).is_err());
    }

    #[test]
    fn corrupt_streams_rejected() {
        let data = relu_feature(33, 1000);
        for codec in [&Lz77Codec as &dyn TensorCodec, &ByteRansCodec] {
            let bytes = codec.encode(&data).unwrap();
            let truncated = &bytes[..bytes.len() / 2];
            assert!(codec.decode(truncated).is_err(), "{} truncation", codec.name());
        }
    }

    #[test]
    fn lz77_huge_match_length_rejected_without_allocating() {
        // Craft: element count 1 (expect 4 bytes), a 4-byte literal, then
        // a match whose length claims 2^50 bytes. Must be a clean error,
        // not a byte-by-byte multi-terabyte copy.
        let mut bytes = Vec::new();
        varint::write_usize(&mut bytes, 1); // n = 1 f32 → expect 4 bytes
        bytes.push(0x00);
        varint::write_usize(&mut bytes, 4);
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        bytes.push(0x01);
        varint::write_usize(&mut bytes, 1usize << 50); // absurd match len
        varint::write_usize(&mut bytes, 1); // dist
        assert!(Lz77Codec.decode(&bytes).is_err());
    }

    #[test]
    fn lz77_compresses_repetitive_data_hard() {
        // A constant tensor is one literal run plus one giant match.
        let data = vec![7.125f32; 10_000];
        let bytes = Lz77Codec.encode(&data).unwrap();
        assert!(bytes.len() < 64, "constant tensor should collapse: {} B", bytes.len());
        assert_eq!(Lz77Codec.decode(&bytes).unwrap(), data);
    }
}
