//! Baseline codecs for Table 1 (E-1, E-2, E-3) plus extra comparators.
//!
//! All baselines implement [`TensorCodec`] over raw `f32` intermediate
//! features so the Table-1 bench can sweep them uniformly:
//!
//! * **E-1** [`binary::BinaryCodec`] — plain binary serialization
//!   (lossless, no compression; the paper's 401 KB reference point).
//! * **E-2** [`tans_codec::TansTensorCodec`] — table-based ANS over the
//!   byte stream (lossless; compresses well, encodes slowly).
//! * **E-3** [`dietgpu_like::DietGpuLikeCodec`] — byte-plane interleaved
//!   rANS in the style of DietGPU's general float mode (lossless,
//!   GPU-decomposable; fast but weaker than the quantized pipeline).
//! * [`general::Lz77Codec`] / [`general::ByteRansCodec`] — self-contained
//!   general-purpose comparators (dictionary half and entropy half of a
//!   deflate-class codec; not in the paper's table, reported alongside
//!   in EXPERIMENTS.md).

pub mod binary;
pub mod dietgpu_like;
pub mod general;
pub mod tans_codec;

use crate::error::Result;

/// A whole-tensor codec (baseline interface for Table 1).
pub trait TensorCodec {
    /// Display name used in bench output.
    fn name(&self) -> &'static str;
    /// Compress the tensor.
    fn encode(&self, data: &[f32]) -> Result<Vec<u8>>;
    /// Decompress; must invert `encode` exactly for lossless codecs.
    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>>;
    /// Whether decode(encode(x)) == x bit-exactly.
    fn lossless(&self) -> bool {
        true
    }
}

/// All paper baselines in Table-1 order.
pub fn paper_baselines() -> Vec<Box<dyn TensorCodec + Send + Sync>> {
    vec![
        Box::new(binary::BinaryCodec),
        Box::new(tans_codec::TansTensorCodec),
        Box::new(dietgpu_like::DietGpuLikeCodec::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Synthetic post-ReLU IF slab shared by baseline tests.
    pub(crate) fn relu_feature(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len)
            .map(|_| {
                if rng.next_f64() < 0.55 {
                    0.0
                } else {
                    (rng.normal().abs() as f32) * 1.5
                }
            })
            .collect()
    }

    #[test]
    fn all_baselines_roundtrip() {
        let data = relu_feature(1, 10_000);
        for codec in paper_baselines() {
            let bytes = codec.encode(&data).unwrap();
            let back = codec.decode(&bytes).unwrap();
            assert_eq!(back.len(), data.len(), "{}", codec.name());
            if codec.lossless() {
                assert!(
                    data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{} must be bit-exact",
                    codec.name()
                );
            }
        }
    }

    #[test]
    fn compressors_beat_binary_on_sparse_data() {
        let data = relu_feature(2, 50_000);
        let baselines = paper_baselines();
        let sizes: Vec<(String, usize)> = baselines
            .iter()
            .map(|c| (c.name().to_string(), c.encode(&data).unwrap().len()))
            .collect();
        let binary = sizes.iter().find(|(n, _)| n.contains("binary")).unwrap().1;
        for (name, size) in &sizes {
            if !name.contains("binary") {
                assert!(size < &binary, "{name}: {size} !< {binary}");
            }
        }
    }

    #[test]
    fn empty_tensor_roundtrips() {
        for codec in paper_baselines() {
            let bytes = codec.encode(&[]).unwrap();
            assert!(codec.decode(&bytes).unwrap().is_empty(), "{}", codec.name());
        }
    }
}
