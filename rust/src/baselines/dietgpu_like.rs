//! E-3: DietGPU-style byte-plane interleaved rANS.
//!
//! DietGPU compresses numerical data with massively parallel rANS over
//! byte planes, trading a little ratio for GPU-speed lossless coding.
//! This baseline reproduces the algorithmic shape on CPU threads: the
//! `f32` stream is transposed into 4 byte planes, each plane gets its
//! own frequency table and multi-lane interleaved rANS stream. Post-ReLU
//! IF tensors are ~half exact zeros, so every plane is highly skewed and
//! the codec lands between E-1 and the quantized pipeline — the ordering
//! Table 1 reports.

use crate::error::{Error, Result};
use crate::rans::{decode_interleaved, encode_interleaved, FreqTable};
use crate::util::varint;

use super::TensorCodec;

/// Byte-plane interleaved-rANS codec.
#[derive(Debug, Clone, Copy)]
pub struct DietGpuLikeCodec {
    /// rANS lanes per plane.
    pub lanes: usize,
    /// Thread the lanes (hot-path default) or run serially.
    pub parallel: bool,
}

impl Default for DietGpuLikeCodec {
    fn default() -> Self {
        DietGpuLikeCodec { lanes: 4, parallel: true }
    }
}

impl TensorCodec for DietGpuLikeCodec {
    fn name(&self) -> &'static str {
        "E-3 dietgpu-like"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let n = data.len();
        let mut out = Vec::new();
        varint::write_usize(&mut out, n);
        // Transpose into byte planes.
        let mut planes: [Vec<u32>; 4] = Default::default();
        for p in planes.iter_mut() {
            p.reserve(n);
        }
        for &x in data {
            let b = x.to_le_bytes();
            for (i, plane) in planes.iter_mut().enumerate() {
                plane.push(b[i] as u32);
            }
        }
        for plane in &planes {
            let table = FreqTable::from_symbols(plane, 256);
            let mut tbuf = Vec::new();
            table.serialize(&mut tbuf);
            let stream = encode_interleaved(plane, &table, self.lanes, self.parallel)?;
            varint::write_usize(&mut out, tbuf.len());
            out.extend_from_slice(&tbuf);
            varint::write_usize(&mut out, stream.len());
            out.extend_from_slice(&stream);
        }
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let n = varint::read_usize(bytes, &mut pos)?;
        let mut planes: Vec<Vec<u32>> = Vec::with_capacity(4);
        for _ in 0..4 {
            let tlen = varint::read_usize(bytes, &mut pos)?;
            let tend = pos
                .checked_add(tlen)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| Error::corrupt("plane table truncated"))?;
            let mut tpos = pos;
            let table = FreqTable::deserialize(bytes, &mut tpos)?;
            if tpos != tend {
                return Err(Error::corrupt("plane table length mismatch"));
            }
            pos = tend;
            let slen = varint::read_usize(bytes, &mut pos)?;
            let send = pos
                .checked_add(slen)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| Error::corrupt("plane stream truncated"))?;
            let plane = decode_interleaved(&bytes[pos..send], &table, self.parallel)?;
            if plane.len() != n {
                return Err(Error::corrupt("plane symbol count mismatch"));
            }
            planes.push(plane);
            pos = send;
        }
        if pos != bytes.len() {
            return Err(Error::corrupt("trailing bytes after planes"));
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let b = [
                planes[0][i] as u8,
                planes[1][i] as u8,
                planes[2][i] as u8,
                planes[3][i] as u8,
            ];
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::relu_feature;

    #[test]
    fn roundtrip_bit_exact() {
        let data = relu_feature(11, 20_000);
        let codec = DietGpuLikeCodec::default();
        let back = codec.decode(&codec.encode(&data).unwrap()).unwrap();
        assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn compresses_sparse_floats_substantially() {
        let data = relu_feature(12, 100_000);
        let codec = DietGpuLikeCodec::default();
        let bytes = codec.encode(&data).unwrap();
        let raw = data.len() * 4;
        let ratio = raw as f64 / bytes.len() as f64;
        assert!(ratio > 1.5, "ratio {ratio:.2} too weak for 55%-sparse data");
    }

    #[test]
    fn serial_and_parallel_agree() {
        let data = relu_feature(13, 5_000);
        let a = DietGpuLikeCodec { lanes: 4, parallel: false }.encode(&data).unwrap();
        let b = DietGpuLikeCodec { lanes: 4, parallel: true }.encode(&data).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn corrupt_payload_rejected() {
        let data = relu_feature(14, 1000);
        let codec = DietGpuLikeCodec::default();
        let bytes = codec.encode(&data).unwrap();
        assert!(codec.decode(&bytes[..bytes.len() - 3]).is_err());
    }
}
