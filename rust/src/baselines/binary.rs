//! E-1: binary serialization (no compression).
//!
//! The transmission format most SC deployments start from: the raw
//! little-endian `f32` tensor plus a varint length header. Encode and
//! decode are memcpy-bound — the paper's Table 1 lists it as the fastest
//! codec and the largest payload.

use crate::error::{Error, Result};
use crate::util::varint;

use super::TensorCodec;

/// Plain binary serialization codec.
#[derive(Debug, Clone, Copy, Default)]
pub struct BinaryCodec;

impl TensorCodec for BinaryCodec {
    fn name(&self) -> &'static str {
        "E-1 binary"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(4 + data.len() * 4);
        varint::write_usize(&mut out, data.len());
        for &x in data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let len = varint::read_usize(bytes, &mut pos)?;
        let need = len
            .checked_mul(4)
            .and_then(|n| n.checked_add(pos))
            .ok_or_else(|| Error::corrupt("length overflow"))?;
        if bytes.len() != need {
            return Err(Error::corrupt(format!(
                "binary payload {} bytes, expected {need}",
                bytes.len()
            )));
        }
        let mut out = Vec::with_capacity(len);
        for chunk in bytes[pos..].chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_is_4n_plus_header() {
        let data = vec![1.5f32; 1000];
        let bytes = BinaryCodec.encode(&data).unwrap();
        assert_eq!(bytes.len(), 2 + 4000); // varint(1000) = 2 bytes
    }

    #[test]
    fn preserves_nan_and_inf_bits() {
        let data = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0];
        let back = BinaryCodec.decode(&BinaryCodec.encode(&data).unwrap()).unwrap();
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn length_mismatch_rejected() {
        let mut bytes = BinaryCodec.encode(&[1.0, 2.0]).unwrap();
        bytes.pop();
        assert!(BinaryCodec.decode(&bytes).is_err());
        bytes.push(0);
        bytes.push(0);
        assert!(BinaryCodec.decode(&bytes).is_err());
    }
}
