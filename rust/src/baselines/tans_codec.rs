//! E-2: tANS over the raw byte stream.
//!
//! Matches how the paper benchmarks Duda's table-based ANS as a
//! whole-tensor baseline: one pass to gather byte statistics, a state
//! table built from them, then a scalar table-driven encode. The table
//! build plus the single-threaded walk is what makes E-2's encode time
//! balloon in Table 1 while its compressed size stays competitive.

use crate::error::Result;
use crate::rans::FreqTable;
use crate::tans::{tans_decode, tans_encode};
use crate::util::varint;

use super::TensorCodec;

/// Whole-tensor tANS baseline.
#[derive(Debug, Clone, Copy, Default)]
pub struct TansTensorCodec;

impl TensorCodec for TansTensorCodec {
    fn name(&self) -> &'static str {
        "E-2 tANS"
    }

    fn encode(&self, data: &[f32]) -> Result<Vec<u8>> {
        let symbols: Vec<u32> = data
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .map(|b| b as u32)
            .collect();
        let table = FreqTable::from_symbols(&symbols, 256);
        let mut out = Vec::new();
        varint::write_usize(&mut out, data.len());
        table.serialize(&mut out);
        let stream = tans_encode(&symbols, &table)?;
        out.extend_from_slice(&stream);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let n = varint::read_usize(bytes, &mut pos)?;
        let table = FreqTable::deserialize(bytes, &mut pos)?;
        let symbols = tans_decode(&bytes[pos..], n * 4, &table)?;
        let mut out = Vec::with_capacity(n);
        for chunk in symbols.chunks_exact(4) {
            out.push(f32::from_le_bytes([
                chunk[0] as u8,
                chunk[1] as u8,
                chunk[2] as u8,
                chunk[3] as u8,
            ]));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::tests::relu_feature;

    #[test]
    fn roundtrip_bit_exact() {
        let data = relu_feature(21, 8_000);
        let codec = TansTensorCodec;
        let back = codec.decode(&codec.encode(&data).unwrap()).unwrap();
        assert!(data.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn compresses_sparse_data() {
        // Single shared byte table across all four planes: mantissa bytes
        // of live activations are near-random, so the win comes from the
        // ~55% exact-zero floats. Expect a solid but not dramatic ratio.
        let data = relu_feature(22, 50_000);
        let bytes = TansTensorCodec.encode(&data).unwrap();
        assert!(bytes.len() < data.len() * 4 * 3 / 4, "{} bytes", bytes.len());
    }
}
