//! Dtype-tagged, zero-copy tensor views for the codec API.
//!
//! The paper validates the framework on Llama2-class models whose
//! intermediate features are half-precision, so the public codec
//! surface is dtype-generic: a [`TensorRef`] borrows the caller's
//! storage (f32 slices, f16/bf16 bit-pattern slices, or raw
//! little-endian wire bytes) without copying, and quantization converts
//! **on load** — an f16/bf16 tensor is never materialized as an `f32`
//! `Vec` on the compress path. Symmetrically, [`TensorMut`] lets
//! `decompress_into` dequantize straight into a caller-owned buffer of
//! the container's dtype, removing the per-request output allocation
//! from the serving hot path.
//!
//! Half-precision conversions are hand-rolled in [`half`] (the build is
//! fully offline — no `half` crate) and pinned against the Python
//! oracle's reference implementation by exhaustive sweeps and CRC
//! golden vectors.

pub mod half;

use std::fmt;

use crate::error::{Error, Result};

/// Element type of a feature tensor.
///
/// The discriminant doubles as the on-wire dtype tag in the dtyped
/// RSC1/RSC2 container headers ([`Dtype::tag`]); `F32` containers keep
/// the legacy header with no tag byte, so pre-dtype containers remain
/// byte-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// IEEE 754 binary32.
    F32,
    /// IEEE 754 binary16.
    F16,
    /// bfloat16 (truncated binary32 exponent range).
    Bf16,
}

impl Dtype {
    /// Bytes per element.
    #[inline]
    pub const fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 | Dtype::Bf16 => 2,
        }
    }

    /// The wire tag stored in dtyped container headers.
    #[inline]
    pub const fn tag(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F16 => 1,
            Dtype::Bf16 => 2,
        }
    }

    /// Parse a wire tag back into a dtype.
    pub fn from_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(Dtype::F32),
            1 => Ok(Dtype::F16),
            2 => Ok(Dtype::Bf16),
            t => Err(Error::corrupt(format!("unknown dtype tag {t}"))),
        }
    }

    /// True for the two half-precision element types.
    pub const fn is_half(self) -> bool {
        matches!(self, Dtype::F16 | Dtype::Bf16)
    }

    /// Canonical lowercase name (`"f32"`, `"f16"`, `"bf16"`).
    pub const fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Parse a canonical name (as accepted by the `dtype` config key).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f16" => Ok(Dtype::F16),
            "bf16" => Ok(Dtype::Bf16),
            other => Err(Error::config(format!(
                "unknown dtype '{other}' (expected f32, f16, or bf16)"
            ))),
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Borrowed storage behind a [`TensorRef`] / [`TensorMut`].
///
/// Typed slices keep their native in-memory representation; `Bytes` is
/// the little-endian wire representation (what the coordinator's raw
/// frames carry), decoded element-wise on access.
enum Storage<'a> {
    F32(&'a [f32]),
    Bits16(&'a [u16]),
    Bytes(&'a [u8]),
}

/// A borrowed, dtype-tagged view of one flat feature tensor.
///
/// `TensorRef` is the input half of the zero-copy codec API:
/// [`crate::engine::Engine::compress_tensor`] quantizes any dtype with
/// conversion fused into the load, so non-f32 tensors never produce an
/// intermediate `f32` `Vec`. Construction is free — no bytes are copied
/// or converted until the codec iterates.
pub struct TensorRef<'a> {
    dtype: Dtype,
    data: Storage<'a>,
}

impl<'a> TensorRef<'a> {
    /// View an `f32` slice.
    pub fn from_f32(data: &'a [f32]) -> Self {
        TensorRef { dtype: Dtype::F32, data: Storage::F32(data) }
    }

    /// View a slice of f16 bit patterns (one `u16` per element).
    pub fn from_f16_bits(data: &'a [u16]) -> Self {
        TensorRef { dtype: Dtype::F16, data: Storage::Bits16(data) }
    }

    /// View a slice of bf16 bit patterns (one `u16` per element).
    pub fn from_bf16_bits(data: &'a [u16]) -> Self {
        TensorRef { dtype: Dtype::Bf16, data: Storage::Bits16(data) }
    }

    /// View half-precision bit patterns as a `dtype`-tagged tensor —
    /// the dtype-dispatching form of
    /// [`TensorRef::from_f16_bits`]/[`TensorRef::from_bf16_bits`] the
    /// eval drivers and CLI share. Panics on [`Dtype::F32`] (a `u16`
    /// slice cannot hold f32 elements; see [`Dtype::is_half`]).
    pub fn from_half_bits(dtype: Dtype, bits: &'a [u16]) -> Self {
        match dtype {
            Dtype::F16 => TensorRef::from_f16_bits(bits),
            Dtype::Bf16 => TensorRef::from_bf16_bits(bits),
            Dtype::F32 => panic!("from_half_bits needs a half-precision dtype"),
        }
    }

    /// View raw little-endian bytes (the wire representation of `dtype`
    /// elements, e.g. a raw coordinator frame payload). Errors when the
    /// byte count is not a whole number of elements.
    pub fn from_le_bytes(dtype: Dtype, bytes: &'a [u8]) -> Result<Self> {
        if bytes.len() % dtype.size_bytes() != 0 {
            return Err(Error::invalid(format!(
                "{} bytes is not a whole number of {} elements",
                bytes.len(),
                dtype
            )));
        }
        Ok(TensorRef { dtype, data: Storage::Bytes(bytes) })
    }

    /// Element type of the view.
    #[inline]
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match &self.data {
            Storage::F32(s) => s.len(),
            Storage::Bits16(s) => s.len(),
            Storage::Bytes(b) => b.len() / self.dtype.size_bytes(),
        }
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total storage bytes behind the view.
    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype.size_bytes()
    }

    /// Visit every element as `f32`, in index order, converting on load
    /// (the dispatch on storage/dtype is hoisted out of the loop). This
    /// is the primitive the fused quantizer is built on.
    pub fn for_each_f32(&self, mut f: impl FnMut(f32)) {
        match (&self.data, self.dtype) {
            (Storage::F32(s), _) => {
                for &x in *s {
                    f(x);
                }
            }
            (Storage::Bits16(s), Dtype::F16) => {
                for &h in *s {
                    f(half::f16_to_f32(h));
                }
            }
            (Storage::Bits16(s), _) => {
                for &b in *s {
                    f(half::bf16_to_f32(b));
                }
            }
            (Storage::Bytes(b), Dtype::F32) => {
                for c in b.chunks_exact(4) {
                    f(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            (Storage::Bytes(b), Dtype::F16) => {
                for c in b.chunks_exact(2) {
                    f(half::f16_to_f32(u16::from_le_bytes([c[0], c[1]])));
                }
            }
            (Storage::Bytes(b), Dtype::Bf16) => {
                for c in b.chunks_exact(2) {
                    f(half::bf16_to_f32(u16::from_le_bytes([c[0], c[1]])));
                }
            }
        }
    }

    /// Element `i` converted to `f32`. Loop-heavy code should prefer
    /// [`TensorRef::for_each_f32`], which hoists the dispatch.
    pub fn get_f32(&self, i: usize) -> f32 {
        match (&self.data, self.dtype) {
            (Storage::F32(s), _) => s[i],
            (Storage::Bits16(s), Dtype::F16) => half::f16_to_f32(s[i]),
            (Storage::Bits16(s), _) => half::bf16_to_f32(s[i]),
            (Storage::Bytes(b), Dtype::F32) => {
                f32::from_le_bytes([b[4 * i], b[4 * i + 1], b[4 * i + 2], b[4 * i + 3]])
            }
            (Storage::Bytes(b), Dtype::F16) => {
                half::f16_to_f32(u16::from_le_bytes([b[2 * i], b[2 * i + 1]]))
            }
            (Storage::Bytes(b), Dtype::Bf16) => {
                half::bf16_to_f32(u16::from_le_bytes([b[2 * i], b[2 * i + 1]]))
            }
        }
    }

    /// Copy the elements out as their little-endian wire bytes (the
    /// representation raw coordinator frames carry). Allocates; the
    /// codec paths never call this.
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len());
        match (&self.data, self.dtype) {
            (Storage::Bytes(b), _) => out.extend_from_slice(b),
            (Storage::F32(s), _) => {
                for &x in *s {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            (Storage::Bits16(s), _) => {
                for &b in *s {
                    out.extend_from_slice(&b.to_le_bytes());
                }
            }
        }
        out
    }

    /// Materialize as `f32` values. Allocates — provided for tests and
    /// display paths, not the codec hot path (which converts on load).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len());
        self.for_each_f32(|x| out.push(x));
        out
    }
}

impl fmt::Debug for TensorRef<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TensorRef({} × {})", self.len(), self.dtype)
    }
}

/// Narrow `f32` values to the bit patterns of a half-precision dtype
/// (round to nearest-even) — the single definition of the "stand-in
/// for a half-precision head" conversion the eval drivers, CLI, and
/// benches share. Panics on [`Dtype::F32`] (narrowing to f32 is the
/// identity and needs no bit vector; see [`Dtype::is_half`]).
pub fn narrow_to_half_bits(values: &[f32], dtype: Dtype) -> Vec<u16> {
    match dtype {
        Dtype::F16 => values.iter().map(|&x| half::f32_to_f16(x)).collect(),
        Dtype::Bf16 => values.iter().map(|&x| half::f32_to_bf16(x)).collect(),
        Dtype::F32 => panic!("narrow_to_half_bits needs a half-precision dtype"),
    }
}

/// Mutable storage behind a [`TensorMut`].
enum StorageMut<'a> {
    F32(&'a mut [f32]),
    Bits16(&'a mut [u16]),
    Bytes(&'a mut [u8]),
}

/// A mutable, dtype-tagged view of a caller-owned output buffer.
///
/// `TensorMut` is the output half of the zero-copy codec API:
/// [`crate::engine::Engine::decompress_into`] dequantizes straight into
/// it (converting `f32` → dtype element-wise), so steady-state decode
/// reuses one arena instead of allocating a fresh `Vec` per request.
pub struct TensorMut<'a> {
    dtype: Dtype,
    data: StorageMut<'a>,
}

impl<'a> TensorMut<'a> {
    /// View a mutable `f32` slice.
    pub fn from_f32(data: &'a mut [f32]) -> Self {
        TensorMut { dtype: Dtype::F32, data: StorageMut::F32(data) }
    }

    /// View a mutable slice of f16 bit patterns.
    pub fn from_f16_bits(data: &'a mut [u16]) -> Self {
        TensorMut { dtype: Dtype::F16, data: StorageMut::Bits16(data) }
    }

    /// View a mutable slice of bf16 bit patterns.
    pub fn from_bf16_bits(data: &'a mut [u16]) -> Self {
        TensorMut { dtype: Dtype::Bf16, data: StorageMut::Bits16(data) }
    }

    /// View raw little-endian output bytes for `dtype` elements. Errors
    /// when the byte count is not a whole number of elements.
    pub fn from_le_bytes(dtype: Dtype, bytes: &'a mut [u8]) -> Result<Self> {
        if bytes.len() % dtype.size_bytes() != 0 {
            return Err(Error::invalid(format!(
                "{} bytes is not a whole number of {} elements",
                bytes.len(),
                dtype
            )));
        }
        Ok(TensorMut { dtype, data: StorageMut::Bytes(bytes) })
    }

    /// Element type of the view.
    #[inline]
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Element capacity of the buffer.
    pub fn len(&self) -> usize {
        match &self.data {
            StorageMut::F32(s) => s.len(),
            StorageMut::Bits16(s) => s.len(),
            StorageMut::Bytes(b) => b.len() / self.dtype.size_bytes(),
        }
    }

    /// True when the buffer has no element capacity.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write elements `0..n` from `value(i)`, converting each `f32` to
    /// the buffer's dtype (the dispatch is hoisted out of the loop).
    /// Panics if `n` exceeds the capacity — callers validate first.
    pub fn store_prefix_f32(&mut self, n: usize, mut value: impl FnMut(usize) -> f32) {
        assert!(n <= self.len(), "store_prefix_f32 past buffer capacity");
        match (&mut self.data, self.dtype) {
            (StorageMut::F32(s), _) => {
                for (i, slot) in s[..n].iter_mut().enumerate() {
                    *slot = value(i);
                }
            }
            (StorageMut::Bits16(s), Dtype::F16) => {
                for (i, slot) in s[..n].iter_mut().enumerate() {
                    *slot = half::f32_to_f16(value(i));
                }
            }
            (StorageMut::Bits16(s), _) => {
                for (i, slot) in s[..n].iter_mut().enumerate() {
                    *slot = half::f32_to_bf16(value(i));
                }
            }
            (StorageMut::Bytes(b), Dtype::F32) => {
                for (i, c) in b.chunks_exact_mut(4).take(n).enumerate() {
                    c.copy_from_slice(&value(i).to_le_bytes());
                }
            }
            (StorageMut::Bytes(b), Dtype::F16) => {
                for (i, c) in b.chunks_exact_mut(2).take(n).enumerate() {
                    c.copy_from_slice(&half::f32_to_f16(value(i)).to_le_bytes());
                }
            }
            (StorageMut::Bytes(b), Dtype::Bf16) => {
                for (i, c) in b.chunks_exact_mut(2).take(n).enumerate() {
                    c.copy_from_slice(&half::f32_to_bf16(value(i)).to_le_bytes());
                }
            }
        }
    }

    /// Re-borrow as an immutable [`TensorRef`] (e.g. to read back what
    /// a decode just wrote).
    pub fn as_tensor_ref(&self) -> TensorRef<'_> {
        match &self.data {
            StorageMut::F32(s) => TensorRef::from_f32(&s[..]),
            StorageMut::Bits16(s) => {
                TensorRef { dtype: self.dtype, data: Storage::Bits16(&s[..]) }
            }
            StorageMut::Bytes(b) => {
                TensorRef { dtype: self.dtype, data: Storage::Bytes(&b[..]) }
            }
        }
    }
}

impl fmt::Debug for TensorMut<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TensorMut({} × {})", self.len(), self.dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_tags_roundtrip() {
        for d in [Dtype::F32, Dtype::F16, Dtype::Bf16] {
            assert_eq!(Dtype::from_tag(d.tag()).unwrap(), d);
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
        }
        assert!(Dtype::from_tag(3).is_err());
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn ref_views_agree_across_storages() {
        let values = [0.0f32, 1.5, -2.25, 1e-3, 300.0];
        let f16_bits: Vec<u16> = values.iter().map(|&x| half::f32_to_f16(x)).collect();
        let as_bits = TensorRef::from_f16_bits(&f16_bits);
        let le = as_bits.to_le_bytes();
        let as_bytes = TensorRef::from_le_bytes(Dtype::F16, &le).unwrap();
        assert_eq!(as_bits.len(), as_bytes.len());
        assert_eq!(as_bits.byte_len(), le.len());
        for i in 0..values.len() {
            assert_eq!(as_bits.get_f32(i), as_bytes.get_f32(i), "i={i}");
        }
        assert_eq!(as_bits.to_f32_vec(), as_bytes.to_f32_vec());

        let f32_ref = TensorRef::from_f32(&values);
        let le32 = f32_ref.to_le_bytes();
        let f32_bytes = TensorRef::from_le_bytes(Dtype::F32, &le32).unwrap();
        assert_eq!(f32_bytes.to_f32_vec(), values.to_vec());
    }

    #[test]
    fn narrow_helper_matches_per_dtype_paths() {
        let values = [0.0f32, 1.0, -2.5, 1e-4];
        for dtype in [Dtype::F16, Dtype::Bf16] {
            assert!(dtype.is_half());
            let bits = narrow_to_half_bits(&values, dtype);
            let manual: Vec<u16> = values
                .iter()
                .map(|&x| match dtype {
                    Dtype::F16 => half::f32_to_f16(x),
                    _ => half::f32_to_bf16(x),
                })
                .collect();
            assert_eq!(bits, manual);
            let view = TensorRef::from_half_bits(dtype, &bits);
            assert_eq!(view.dtype(), dtype);
            assert_eq!(view.len(), values.len());
        }
        assert!(!Dtype::F32.is_half());
    }

    #[test]
    fn ragged_byte_views_rejected() {
        let bytes = [0u8; 7];
        assert!(TensorRef::from_le_bytes(Dtype::F32, &bytes).is_err());
        assert!(TensorRef::from_le_bytes(Dtype::F16, &bytes).is_err());
        let mut bytes = [0u8; 7];
        assert!(TensorMut::from_le_bytes(Dtype::Bf16, &mut bytes).is_err());
    }

    #[test]
    fn mut_views_store_with_conversion() {
        let src = [1.0f32, -0.5, 0.0, 1.0 / 3.0];
        let mut bits = [0u16; 4];
        let mut view = TensorMut::from_bf16_bits(&mut bits);
        assert_eq!(view.dtype(), Dtype::Bf16);
        view.store_prefix_f32(4, |i| src[i]);
        let back = view.as_tensor_ref().to_f32_vec();
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 0.01 + 1e-6, "{a} vs {b}");
        }
        assert_eq!(bits[0], half::f32_to_bf16(1.0));

        let mut raw = [0u8; 8];
        let mut view = TensorMut::from_le_bytes(Dtype::F16, &mut raw).unwrap();
        view.store_prefix_f32(2, |i| src[i]);
        let r = TensorRef::from_le_bytes(Dtype::F16, &raw).unwrap();
        assert_eq!(r.get_f32(0), 1.0);
        assert_eq!(r.get_f32(1), -0.5);
        assert_eq!(u16::from_le_bytes([raw[4], raw[5]]), 0);
    }

    #[test]
    fn empty_views_behave() {
        let v: [f32; 0] = [];
        let r = TensorRef::from_f32(&v);
        assert!(r.is_empty());
        assert_eq!(r.len(), 0);
        assert!(r.to_f32_vec().is_empty());
    }
}
