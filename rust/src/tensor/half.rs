//! Hand-rolled IEEE 754 binary16 (f16) and bfloat16 conversions.
//!
//! The container is offline (no `half` crate), so the four conversions
//! the dtype-generic codec API needs are implemented here at the bit
//! level, with no floating-point environment dependence:
//!
//! * widening (`f16`/`bf16` → `f32`) is exact — every half value is
//!   representable in `f32`;
//! * narrowing (`f32` → `f16`/`bf16`) rounds to nearest, ties to even,
//!   matching both hardware `vcvtps2ph`/`bfloat` semantics and the
//!   Python oracle (`gen_golden.py` cross-checks against `struct`'s
//!   native binary16 codec and pins all four tables by CRC under
//!   `rust/tests/golden/half_conv_crcs.hex`).
//!
//! NaN handling is round-trip safe: a NaN that originated as a half
//! keeps its payload through `f32` and back bit-for-bit (the exhaustive
//! 65,536-pattern sweep in `rust/tests/dtype_tensor.rs` relies on
//! this); an `f32` NaN whose payload lives entirely below the kept bits
//! gets a quiet bit forced so it cannot collapse to infinity.

/// Widen an f16 bit pattern to the equivalent f32 bit pattern (exact).
pub const fn f16_bits_to_f32_bits(h: u16) -> u32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        if man == 0 {
            return sign; // ±0
        }
        // Subnormal: renormalize. `man` has 22..=31 leading zeros as a
        // u32, so `shift` ∈ [1, 10] and the top set bit lands on the
        // implicit-one position.
        let shift = man.leading_zeros() - 21;
        let exp32 = 113 - shift; // 127 − 15 + 1 − shift, biased
        let man32 = (man << (shift + 13)) & 0x007F_FFFF;
        return sign | (exp32 << 23) | man32;
    }
    if exp == 0x1F {
        // ±inf / NaN; payload widens into the top mantissa bits.
        return sign | 0x7F80_0000 | (man << 13);
    }
    sign | ((exp + 112) << 23) | (man << 13)
}

/// Narrow an f32 bit pattern to f16, rounding to nearest-even.
pub const fn f32_bits_to_f16_bits(bits: u32) -> u16 {
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        if abs == 0x7F80_0000 {
            return sign | 0x7C00; // ±inf
        }
        // NaN: keep the top 10 payload bits; if they are all zero the
        // payload lived below the kept range — force the quiet bit so
        // the result stays a NaN.
        let payload = ((abs >> 13) & 0x03FF) as u16;
        return sign | 0x7C00 | if payload == 0 { 0x0200 } else { payload };
    }
    let exp32 = ((abs >> 23) as i32) - 127;
    let man32 = abs & 0x007F_FFFF;
    if exp32 >= 16 {
        return sign | 0x7C00; // above the f16 range even before rounding
    }
    if exp32 >= -14 {
        // Normal f16 range: drop 13 mantissa bits with RN-even. A carry
        // out of the mantissa propagates into the exponent, which also
        // rounds 65520.. up to +inf, exactly as IEEE requires.
        let base = (((exp32 + 15) as u32) << 10) | (man32 >> 13);
        let round = man32 & 0x1000;
        let sticky = man32 & 0x0FFF;
        let lsb = man32 & 0x2000;
        let inc = if round != 0 && (sticky != 0 || lsb != 0) { 1 } else { 0 };
        return sign | (base + inc) as u16;
    }
    if exp32 < -25 {
        // Below half the smallest subnormal (this also catches every
        // f32 subnormal, whose biased exponent field is 0): round to ±0.
        return sign;
    }
    // f16 subnormal: shift the 24-bit significand (implicit one
    // restored) right by 14..=24 bits with RN-even. Rounding up from
    // the largest subnormal naturally carries into the smallest normal.
    let man = man32 | 0x0080_0000;
    let shift = (-exp32 - 1) as u32;
    let out = man >> shift;
    let rem = man & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let inc = if rem > half || (rem == half && (out & 1) != 0) { 1 } else { 0 };
    sign | (out + inc) as u16
}

/// Widen a bf16 bit pattern to the equivalent f32 bit pattern (exact).
pub const fn bf16_bits_to_f32_bits(b: u16) -> u32 {
    (b as u32) << 16
}

/// Narrow an f32 bit pattern to bf16, rounding to nearest-even.
pub const fn f32_bits_to_bf16_bits(bits: u32) -> u16 {
    let abs = bits & 0x7FFF_FFFF;
    if abs > 0x7F80_0000 {
        // NaN: truncating keeps the top 7 payload bits; when they are
        // all zero, force the quiet bit so the result stays a NaN. A
        // bf16-originated NaN always keeps its bits (its payload *is*
        // the top 7 bits), which the round-trip sweep relies on.
        let out = (bits >> 16) as u16;
        return if out & 0x007F == 0 { out | 0x0040 } else { out };
    }
    // RN-even by addition: 0x7FFF + LSB-of-result, then truncate. The
    // carry propagates through the exponent, rounding values above the
    // bf16 range to ±inf.
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// Widen an f16 bit pattern to an `f32` value (exact).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    f32::from_bits(f16_bits_to_f32_bits(h))
}

/// Narrow an `f32` value to an f16 bit pattern (round to nearest-even).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    f32_bits_to_f16_bits(x.to_bits())
}

/// Widen a bf16 bit pattern to an `f32` value (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits(bf16_bits_to_f32_bits(b))
}

/// Narrow an `f32` value to a bf16 bit pattern (round to nearest-even).
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    f32_bits_to_bf16_bits(x.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert!(f16_to_f32(0x8000).is_sign_negative());
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0xC000), -2.0);
        assert_eq!(f16_to_f32(0x7BFF), 65504.0); // max finite
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24)); // min subnormal
        assert_eq!(f16_to_f32(0x0400), 2.0f32.powi(-14)); // min normal
        assert_eq!(f16_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xFC00), f32::NEG_INFINITY);
        assert!(f16_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn f16_narrowing_rounds_to_nearest_even() {
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16(65520.0), 0x7C00); // ties up to inf
        assert_eq!(f32_to_f16(65519.99), 0x7BFF); // just under the tie
        assert_eq!(f32_to_f16(1e30), 0x7C00); // far overflow
        assert_eq!(f32_to_f16(-1e30), 0xFC00);
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16;
        // ties-to-even keeps the even mantissa (1.0).
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11)), 0x3C00);
        // The next representable f32 above the tie rounds up.
        assert_eq!(f32_to_f16(f32::from_bits((1.0f32 + 2.0f32.powi(-11)).to_bits() + 1)), 0x3C01);
        // Halfway between the first and second f16 step above 1.0
        // (odd mantissa) rounds up to even.
        assert_eq!(f32_to_f16(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3C02);
        // Underflow: half the smallest subnormal ties to even (zero);
        // anything above it rounds to the smallest subnormal.
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16(2.0f32.powi(-25) * 1.0001), 0x0001);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        // f32 subnormals flush to signed zero.
        assert_eq!(f32_to_f16(f32::from_bits(0x0000_0001)), 0x0000);
        assert_eq!(f32_to_f16(f32::from_bits(0x8000_0001)), 0x8000);
    }

    #[test]
    fn bf16_known_values_and_rounding() {
        assert_eq!(bf16_to_f32(0x3F80), 1.0);
        assert_eq!(bf16_to_f32(0xC000), -2.0);
        assert_eq!(bf16_to_f32(0x7F80), f32::INFINITY);
        assert!(bf16_to_f32(0x7FC0).is_nan());
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        // Truncation boundary: 1 + 2^-8 is halfway; even stays.
        assert_eq!(f32_to_bf16(1.0 + 2.0f32.powi(-8)), 0x3F80);
        assert_eq!(f32_to_bf16(1.0 + 3.0 * 2.0f32.powi(-8)), 0x3F82);
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80); // rounds up to inf
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
    }

    #[test]
    fn exhaustive_f16_roundtrip_is_identity() {
        for h in 0..=u16::MAX {
            let back = f32_bits_to_f16_bits(f16_bits_to_f32_bits(h));
            assert_eq!(back, h, "f16 pattern {h:#06x} drifted to {back:#06x}");
        }
    }

    #[test]
    fn exhaustive_bf16_roundtrip_is_identity() {
        for b in 0..=u16::MAX {
            let back = f32_bits_to_bf16_bits(bf16_bits_to_f32_bits(b));
            assert_eq!(back, b, "bf16 pattern {b:#06x} drifted to {back:#06x}");
        }
    }

    #[test]
    fn nan_payloads_from_f32_stay_nan() {
        // f32 NaNs whose payload sits below the kept bits must not
        // collapse to ±inf on narrowing.
        for bits in [0x7F80_0001u32, 0x7F80_1000, 0xFF80_0001, 0x7FC0_0000] {
            let h = f32_bits_to_f16_bits(bits);
            assert_eq!(h & 0x7C00, 0x7C00);
            assert_ne!(h & 0x03FF, 0, "f32 NaN {bits:#010x} became inf as f16");
            let b = f32_bits_to_bf16_bits(bits);
            assert_eq!(b & 0x7F80, 0x7F80);
            assert_ne!(b & 0x007F, 0, "f32 NaN {bits:#010x} became inf as bf16");
        }
    }

    #[test]
    fn widening_is_value_exact_for_finite_patterns() {
        // Spot-check against decimal expansions across the range.
        let cases: [(u16, f32); 5] = [
            (0x3555, 0.333251953125), // ~1/3 in f16
            (0x0401, 6.103515625e-05 * (1.0 + 1.0 / 1024.0)),
            (0x7800, 32768.0),
            (0x8401, -6.103515625e-05 * (1.0 + 1.0 / 1024.0)),
            (0x0010, 2.0f32.powi(-20)),
        ];
        for (h, want) in cases {
            assert_eq!(f16_to_f32(h), want, "pattern {h:#06x}");
        }
    }
}
