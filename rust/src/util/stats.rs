//! Summary statistics and information-theoretic helpers.
//!
//! The reshape optimizer needs Shannon entropy of empirical frequency
//! vectors (Eq. 1); benches need mean/std/percentiles with the same
//! semantics the paper reports (mean ± std across trials).

/// Shannon entropy in bits/symbol of a frequency vector.
///
/// Zero-frequency entries contribute nothing. Returns 0 for an empty or
/// all-zero vector.
pub fn shannon_entropy(freqs: &[u64]) -> f64 {
    let total: u64 = freqs.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let n = total as f64;
    let mut h = 0.0;
    for &f in freqs {
        if f > 0 {
            let p = f as f64 / n;
            h -= p * p.log2();
        }
    }
    h
}

/// Expected compressed size in *bits* for `total` symbols at entropy `h`
/// (the paper's `η = N · H`, Eq. 1).
pub fn entropy_bits(freqs: &[u64]) -> f64 {
    let total: u64 = freqs.iter().sum();
    shannon_entropy(freqs) * total as f64
}

/// Compression ratio `ρ = η / (N · log2 A)` (Eq. 1): how close the coded
/// length is to the ideal uniform-alphabet length. Lower is better.
pub fn compression_ratio(freqs: &[u64], alphabet: usize) -> f64 {
    let total: u64 = freqs.iter().sum();
    if total == 0 || alphabet <= 1 {
        return 0.0;
    }
    entropy_bits(freqs) / (total as f64 * (alphabet as f64).log2())
}

/// Build a frequency histogram over `symbols` with alphabet size `m`.
/// Panics in debug builds if a symbol exceeds the alphabet.
pub fn histogram(symbols: &[u32], m: usize) -> Vec<u64> {
    let mut freqs = vec![0u64; m];
    for &s in symbols {
        debug_assert!((s as usize) < m, "symbol {s} outside alphabet {m}");
        freqs[s as usize] += 1;
    }
    freqs
}

/// Online mean/variance accumulator (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold in one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    /// Sample standard deviation (0 for n < 2).
    pub fn std(&self) -> f64 {
        if self.n < 2 { 0.0 } else { (self.m2 / (self.n - 1) as f64).sqrt() }
    }
    /// Minimum observed value.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Maximum observed value.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold a batch of observations.
    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }
}

/// Exact percentile of a sample (linear interpolation between ranks).
/// `q` in `[0, 1]`. Sorts a copy — fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform_is_log2() {
        let freqs = vec![10u64; 16];
        assert!((shannon_entropy(&freqs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_degenerate_is_zero() {
        assert_eq!(shannon_entropy(&[100, 0, 0, 0]), 0.0);
        assert_eq!(shannon_entropy(&[]), 0.0);
        assert_eq!(shannon_entropy(&[0, 0]), 0.0);
    }

    #[test]
    fn entropy_skew_below_uniform() {
        let skewed = [1000u64, 10, 5, 1];
        let uniform = [254u64; 4];
        assert!(shannon_entropy(&skewed) < shannon_entropy(&uniform));
    }

    #[test]
    fn ratio_bounds() {
        // Uniform over full alphabet → ratio 1.
        let freqs = vec![5u64; 32];
        assert!((compression_ratio(&freqs, 32) - 1.0).abs() < 1e-12);
        // Single symbol → ratio 0.
        let freqs = [77u64, 0, 0, 0];
        assert_eq!(compression_ratio(&freqs, 4), 0.0);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0, 1, 1, 3, 3, 3], 4);
        assert_eq!(h, vec![1, 2, 0, 3]);
    }

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        s.extend(xs.iter().copied());
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.std() - naive_var.sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.5), 2.0);
    }
}
