//! Hand-rolled substrates the crate would normally pull from crates.io.
//!
//! The build environment for this reproduction is offline, so the crate
//! carries its own implementations of the small utility layers it needs:
//! a counter-based PRNG ([`prng`]), bit-level I/O ([`bitio`]), LEB128
//! varints ([`varint`]), CRC-32 checksums ([`crc32`]), FIPS 180-4
//! SHA-256 ([`sha256`]), summary statistics ([`stats`]), a JSON
//! parser/writer ([`json`]), wall-clock measurement helpers ([`timer`]),
//! and a persistent thread pool ([`threadpool`]). Each module is unit-
//! and property-tested like any other substrate.

pub mod bitio;
pub mod crc32;
pub mod json;
pub mod prng;
pub mod sha256;
pub mod stats;
pub mod threadpool;
pub mod timer;
pub mod varint;
