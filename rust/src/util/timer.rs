//! Wall-clock measurement helpers shared by benches and telemetry.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time since construction / last reset.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed milliseconds as f64.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Restart and return the lap time.
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.start;
        self.start = now;
        d
    }
}

/// Timing result of [`measure`]: per-iteration stats in milliseconds.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Per-trial wall times, milliseconds.
    pub samples_ms: Vec<f64>,
    /// Summary over `samples_ms`.
    pub summary: Summary,
}

impl Measurement {
    /// Mean milliseconds per trial.
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean()
    }
    /// Sample std-dev of milliseconds per trial.
    pub fn std_ms(&self) -> f64 {
        self.summary.std()
    }
    /// Format as the paper's `mean(std)` convention.
    pub fn fmt_mean_std(&self) -> String {
        format!("{:.3}({:.3})", self.mean_ms(), self.std_ms())
    }
}

/// Run `f` for `warmup` unmeasured iterations then `trials` measured
/// ones, returning per-trial wall times. `f`'s return value is passed to
/// `std::hint::black_box` to keep the optimizer honest.
pub fn measure<T>(warmup: usize, trials: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(trials);
    let mut summary = Summary::new();
    for _ in 0..trials {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        samples.push(ms);
        summary.add(ms);
    }
    Measurement { samples_ms: samples, summary }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_something() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn measure_runs_expected_counts() {
        let mut calls = 0;
        let m = measure(3, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 8);
        assert_eq!(m.samples_ms.len(), 5);
        assert_eq!(m.summary.count(), 5);
    }

    #[test]
    fn fmt_mean_std_shape() {
        let m = measure(0, 2, || 1 + 1);
        let s = m.fmt_mean_std();
        assert!(s.contains('(') && s.ends_with(')'));
    }
}
