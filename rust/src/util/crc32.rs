//! CRC-32 (IEEE 802.3, reflected) — the checksum used by the container
//! format and the wire protocol.
//!
//! Drop-in replacement for the `crc32fast::hash` entry point the crate
//! previously leaned on; the offline build carries its own table-driven
//! implementation. The polynomial (0xEDB88320, reflected 0x04C11DB7),
//! initial value (`!0`), and final XOR (`!state`) match zlib/PNG/zstd
//! framing, so checksums are comparable across tooling.
//!
//! A slice-by-eight variant was measured and rejected: at container sizes
//! (tens of KB) the simple table loop is already > 1 GB/s and never shows
//! up in the hot-path profile next to the rANS inner loop.

/// Build the reflected CRC-32 lookup table at compile time.
const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Streaming CRC-32 state (for incremental framing paths).
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh checksum state.
    pub fn new() -> Self {
        Crc32 { state: !0 }
    }

    /// Fold `bytes` into the state.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// Final checksum value.
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32 of `bytes` (same convention as `crc32fast::hash`).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vectors() {
        // The canonical check value for CRC-32/ISO-HDLC.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        for split in [0usize, 1, 255, 256, 5000, 9999, 10_000] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finalize(), hash(&data), "split {split}");
        }
    }

    #[test]
    fn single_byte_changes_are_detected() {
        let data = vec![0x5Au8; 512];
        let base = hash(&data);
        for i in (0..data.len()).step_by(17) {
            let mut bad = data.clone();
            bad[i] ^= 0x01;
            assert_ne!(hash(&bad), base, "flip at {i}");
        }
    }
}
