//! LEB128 variable-length integers.
//!
//! Used by the container format to pack frequency tables and header
//! fields: most symbol frequencies are small, so varints shrink the
//! side-information the decoder needs (the paper transmits the frequency
//! vector `F` alongside the bitstream).

use crate::error::{Error, Result};

/// Append `value` as unsigned LEB128.
#[inline]
pub fn write_u64(buf: &mut Vec<u8>, mut value: u64) {
    loop {
        let byte = (value & 0x7F) as u8;
        value >>= 7;
        if value == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Append `value` as unsigned LEB128 (usize convenience).
#[inline]
pub fn write_usize(buf: &mut Vec<u8>, value: usize) {
    write_u64(buf, value as u64)
}

/// Decode an unsigned LEB128 from `buf[*pos..]`, advancing `pos`.
#[inline]
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut out = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *buf
            .get(*pos)
            .ok_or_else(|| Error::corrupt("varint truncated"))?;
        *pos += 1;
        if shift == 63 && byte > 1 {
            return Err(Error::corrupt("varint overflows u64"));
        }
        out |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(out);
        }
        shift += 7;
        if shift > 63 {
            return Err(Error::corrupt("varint too long"));
        }
    }
}

/// Decode an unsigned LEB128 as usize.
#[inline]
pub fn read_usize(buf: &[u8], pos: &mut usize) -> Result<usize> {
    let v = read_u64(buf, pos)?;
    usize::try_from(v).map_err(|_| Error::corrupt("varint exceeds usize"))
}

/// Encoded width in bytes of `value` as unsigned LEB128, without
/// writing anything: `ceil(bit_length / 7)`, minimum 1.
#[inline]
pub fn len_u64(value: u64) -> usize {
    if value == 0 {
        1
    } else {
        (64 - value.leading_zeros() as usize).div_ceil(7)
    }
}

/// Encoded width of `value` as unsigned LEB128 (usize convenience).
#[inline]
pub fn len_usize(value: usize) -> usize {
    len_u64(value as u64)
}

/// ZigZag-encode a signed value then LEB128 it.
#[inline]
pub fn write_i64(buf: &mut Vec<u8>, value: i64) {
    write_u64(buf, ((value << 1) ^ (value >> 63)) as u64)
}

/// Inverse of [`write_i64`].
#[inline]
pub fn read_i64(buf: &[u8], pos: &mut usize) -> Result<i64> {
    let z = read_u64(buf, pos)?;
    Ok(((z >> 1) as i64) ^ -((z & 1) as i64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn roundtrip_signed() {
        for v in [0i64, -1, 1, -64, 63, i64::MIN, i64::MAX, -123456789] {
            let mut buf = Vec::new();
            write_i64(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_i64(&buf, &mut pos).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_random_stream() {
        let mut rng = Rng::new(21);
        let vals: Vec<u64> = (0..5000).map(|_| rng.next_u64() >> (rng.below(64) as u32)).collect();
        let mut buf = Vec::new();
        for &v in &vals {
            write_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn truncated_errors() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn overlong_errors() {
        // 11 continuation bytes is always invalid for u64.
        let buf = vec![0x80u8; 11];
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn small_values_are_one_byte() {
        for v in 0..128u64 {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            assert_eq!(buf.len(), 1);
        }
    }

    #[test]
    fn len_u64_matches_write_exactly() {
        let mut rng = Rng::new(99);
        let mut check = |v: u64| {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            assert_eq!(len_u64(v), buf.len(), "v={v}");
        };
        for v in [0u64, 1, 127, 128, 16383, 16384, (1 << 21) - 1, 1 << 21, u32::MAX as u64, u64::MAX]
        {
            check(v);
        }
        for _ in 0..2000 {
            check(rng.next_u64() >> (rng.below(64) as u32));
        }
    }
}
