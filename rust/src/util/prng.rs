//! Deterministic pseudo-random number generation.
//!
//! Implements xoshiro256++ (Blackman & Vigna), seeded through SplitMix64
//! so that any `u64` seed yields a well-mixed state. All experiment
//! drivers and property tests take explicit seeds, making every run in
//! EXPERIMENTS.md reproducible bit-for-bit.

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
///
/// Fast, 256-bit state, passes BigCrush; more than adequate for workload
/// synthesis and property-test case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start at the all-zero state.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper half of the 64-bit stream).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` (single precision).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method
    /// (unbiased). `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound {
                return (m >> 64) as u64;
            }
            // Rejection zone: low < bound && low < (2^64 mod bound).
            let threshold = bound.wrapping_neg() % bound;
            if low >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value not kept —
    /// simplicity over speed; this is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.next_f64(); // in (0, 1]
        -u.ln() / lambda
    }

    /// Geometric-like zipfian sample over `[0, n)` with exponent `s`,
    /// via inverse-CDF on precomputable weights. Used to synthesize the
    /// skewed symbol distributions the paper's Fig. 2 illustrates.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Rejection-free inverse transform on the harmonic CDF is costly;
        // for workload synthesis a simple cutoff walk is fine (n is small).
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.next_f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fork a child generator with a decorrelated stream (for parallel
    /// lanes / worker threads).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut seed = self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 4, "zipf head should dominate: {counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_decorrelate() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }
}
