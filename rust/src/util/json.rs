//! Minimal JSON parser / writer.
//!
//! The artifact manifest (written by `python/compile/aot.py`) and the
//! config system use JSON as the interchange format. serde is not
//! available offline, so this module implements the subset of JSON the
//! repo needs: full RFC 8259 syntax on parse (objects, arrays, strings
//! with escapes, numbers, bools, null) and deterministic output on write.
//!
//! Numbers are held as `f64`; integer accessors check exactness. Object
//! key order is preserved (insertion order) so written manifests diff
//! cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (kept as f64; 2^53 integer exactness caveat applies).
    Num(f64),
    /// String (unescaped).
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object with preserved insertion order.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Lookup a key in an object value.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field lookup with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::config(format!("missing required field '{key}'")))
    }

    /// Index into an array value.
    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// Borrow as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Borrow as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Borrow as an exact integer (errors out on 1.5 etc. via None).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) => Some(*n as i64),
            _ => None,
        }
    }

    /// Borrow as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    /// Borrow as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Borrow as array slice.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as object entries.
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(kvs) => Some(kvs),
            _ => None,
        }
    }

    /// Convenience: string field of an object.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::config(format!("field '{key}' is not a string")))
    }

    /// Convenience: usize field of an object.
    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::config(format!("field '{key}' is not a non-negative integer")))
    }

    /// Convenience: f64 field of an object.
    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::config(format!("field '{key}' is not a number")))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(&mut out, self, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_value(&mut out, self, Some(2), 0);
        out
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Value>> for Value {
    fn from(m: BTreeMap<String, Value>) -> Self {
        Value::Obj(m.into_iter().collect())
    }
}

/// Builder for object values preserving insertion order.
#[derive(Debug, Default, Clone)]
pub struct ObjBuilder(Vec<(String, Value)>);

impl ObjBuilder {
    /// Empty object builder.
    pub fn new() -> Self {
        Self::default()
    }
    /// Add a field (chainable).
    pub fn field(mut self, key: &str, value: impl Into<Value>) -> Self {
        self.0.push((key.to_string(), value.into()));
        self
    }
    /// Finish into a [`Value::Obj`].
    pub fn build(self) -> Value {
        Value::Obj(self.0)
    }
}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.parse_value(0)?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_object(depth),
            Some(b'[') => self.parse_array(depth),
            Some(b'"') => Ok(Value::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Value::Bool(true)),
            Some(b'f') => self.parse_lit("false", Value::Bool(false)),
            Some(b'n') => self.parse_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{lit}'")))
        }
    }

    fn parse_object(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value(depth + 1)?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn parse_array(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            let v = self.parse_value(depth + 1)?;
            items.push(v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn parse_string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.parse_hex4()?;
                            // Handle surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.parse_hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("invalid surrogate pair"))?,
                                    );
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("invalid escape character")),
                    }
                }
                _ if b < 0x20 => return Err(self.err("raw control character in string")),
                _ => {
                    // Re-decode UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn parse_number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part.
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // Fraction.
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // Exponent.
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text.parse().map_err(|_| self.err("number out of range"))?;
        Ok(Value::Num(n))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_number(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        // JSON has no Inf/NaN; emit null like most lenient writers.
        out.push_str("null");
    }
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_number(out, *n),
        Value::Str(s) => write_escaped(out, s),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * (level + 1)));
                }
                write_value(out, item, indent, level + 1);
            }
            if let Some(ind) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(ind * level));
            }
            out.push(']');
        }
        Value::Obj(kvs) => {
            if kvs.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in kvs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(ind) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind * (level + 1)));
                }
                write_escaped(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, val, indent, level + 1);
            }
            if let Some(ind) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(ind * level));
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_i64(), Some(1));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Value::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = parse(r#""Aé 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("01").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\x\"").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn rejects_deep_nesting() {
        let doc = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&doc).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = ObjBuilder::new()
            .field("name", "resnet_mini")
            .field("layers", vec![1usize, 2, 3])
            .field("q", 4usize)
            .field("ratio", 0.25)
            .field("ok", true)
            .build();
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, v, "roundtrip failed for: {text}");
        }
    }

    #[test]
    fn integers_written_without_fraction() {
        let v = Value::Num(128.0);
        assert_eq!(v.to_string_compact(), "128");
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn field_helpers_report_errors() {
        let v = parse(r#"{"n": "x"}"#).unwrap();
        assert!(v.usize_field("n").is_err());
        assert!(v.str_field("missing").is_err());
        assert_eq!(v.str_field("n").unwrap(), "x");
    }
}
