//! Streaming SHA-256 (FIPS 180-4), hand-rolled like [`crate::util::crc32`]
//! (no external crates in the offline container).
//!
//! [`Sha256`] exposes the same streaming-hasher shape as
//! [`crate::util::crc32::Crc32`] — `new()` / `update()` / `finalize()` —
//! plus a one-shot [`hash`]. The registry's content addressing and the
//! HMAC manifest signer are both built on it, so correctness is pinned
//! three ways: the FIPS-180 vectors in this module's tests, the committed
//! golden vectors under `rust/tests/golden/`, and the executable
//! `gen_golden.py` differential against CPython's `hashlib` (the
//! in-container oracle — rerun it if this file ever changes).

/// Initial hash state: the first 32 bits of the fractional parts of the
/// square roots of the first 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
    0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
];

/// Round constants: the first 32 bits of the fractional parts of the
/// cube roots of the first 64 primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5,
    0x3956C25B, 0x59F111F1, 0x923F82A4, 0xAB1C5ED5,
    0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3,
    0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174,
    0xE49B69C1, 0xEFBE4786, 0x0FC19DC6, 0x240CA1CC,
    0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
    0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7,
    0xC6E00BF3, 0xD5A79147, 0x06CA6351, 0x14292967,
    0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13,
    0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85,
    0xA2BFE8A1, 0xA81A664B, 0xC24B8B70, 0xC76C51A3,
    0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
    0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5,
    0x391C0CB3, 0x4ED8AA4A, 0x5B9CCA4F, 0x682E6FF3,
    0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208,
    0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2,
];

/// Streaming SHA-256 hasher. Feed bytes with [`update`](Self::update),
/// consume with [`finalize`](Self::finalize); splitting the input across
/// any number of `update` calls yields the same digest as one shot.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial block carried between `update` calls.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding trailer needs bits).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        self.total = self.total.wrapping_add(bytes.len() as u64);
        let mut rest = bytes;
        if self.buf_len > 0 {
            let take = rest.len().min(64 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len < 64 {
                return;
            }
            let block = self.buf;
            compress(&mut self.state, &block);
            self.buf_len = 0;
        }
        let mut chunks = rest.chunks_exact(64);
        for block in &mut chunks {
            compress(&mut self.state, block.try_into().unwrap());
        }
        let tail = chunks.remainder();
        self.buf[..tail.len()].copy_from_slice(tail);
        self.buf_len = tail.len();
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // The length trailer completes the final block exactly.
        let mut block = self.buf;
        block[56..64].copy_from_slice(&bit_len.to_be_bytes());
        compress(&mut self.state, &block);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// One FIPS 180-4 compression round over a 64-byte block.
fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
    let mut w = [0u32; 64];
    for i in 0..16 {
        w[i] = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

/// One-shot digest of `bytes`.
pub fn hash(bytes: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(bytes);
    h.finalize()
}

/// Lowercase hex of a digest (the registry's on-disk address form).
pub fn to_hex(digest: &[u8]) -> String {
    let mut s = String::with_capacity(digest.len() * 2);
    for &b in digest {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xF) as u32, 16).unwrap());
    }
    s
}

/// Parse lowercase/uppercase hex into bytes; `None` on odd length or a
/// non-hex character (tamper in a manifest digest field must not panic).
pub fn from_hex(hex: &str) -> Option<Vec<u8>> {
    if hex.len() % 2 != 0 {
        return None;
    }
    let bytes = hex.as_bytes();
    let mut out = Vec::with_capacity(hex.len() / 2);
    for pair in bytes.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16)?;
        let lo = (pair[1] as char).to_digit(16)?;
        out.push(((hi << 4) | lo) as u8);
    }
    Some(out)
}

/// Constant-time equality for digests and MACs: XOR-accumulate every
/// byte so timing does not leak the first mismatching position.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b) {
        acc |= x ^ y;
    }
    acc == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FIPS 180-4 / NIST CAVP short-message vectors.
    #[test]
    fn fips_vectors() {
        let cases: [(&[u8], &str); 3] = [
            (b"", "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"),
            (b"abc", "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"),
            (
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq",
                "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1",
            ),
        ];
        for (msg, want) in cases {
            assert_eq!(to_hex(&hash(msg)), want);
        }
    }

    /// The FIPS long-message vector: one million 'a' bytes, streamed in
    /// deliberately awkward chunk sizes.
    #[test]
    fn fips_million_a() {
        let mut h = Sha256::new();
        let chunk = [b'a'; 997]; // prime-ish, never block-aligned
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            h.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            to_hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    /// Streaming across any split point must equal the one-shot digest
    /// (the property `Sha256Reader` relies on).
    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..777u32).map(|i| (i * 131 + 17) as u8).collect();
        let oneshot = hash(&data);
        for split in [0, 1, 17, 63, 64, 65, 128, 500, data.len()] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), oneshot, "split at {split}");
        }
        // Byte-at-a-time, too.
        let mut h = Sha256::new();
        for b in &data {
            h.update(std::slice::from_ref(b));
        }
        assert_eq!(h.finalize(), oneshot);
    }

    #[test]
    fn hex_roundtrip_and_rejects() {
        let d = hash(b"round");
        let hex = to_hex(&d);
        assert_eq!(from_hex(&hex).unwrap(), d.to_vec());
        assert!(from_hex("abc").is_none(), "odd length");
        assert!(from_hex("zz").is_none(), "non-hex");
        assert_eq!(from_hex("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn ct_eq_semantics() {
        assert!(ct_eq(b"same", b"same"));
        assert!(!ct_eq(b"same", b"sane"));
        assert!(!ct_eq(b"short", b"longer"));
        assert!(ct_eq(b"", b""));
    }

    /// A single flipped input bit changes the digest (sanity, mirrors
    /// the crc32 module's test).
    #[test]
    fn single_byte_changes_are_detected() {
        let mut data = vec![7u8; 300];
        let base = hash(&data);
        data[155] ^= 0x40;
        assert_ne!(hash(&data), base);
    }
}
