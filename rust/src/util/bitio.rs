//! Bit-level I/O.
//!
//! LSB-first bit packing used by the tANS baseline and by the container
//! format's compact headers. The rANS coders do whole-`u32`/`u16` flushes
//! and do not need sub-byte I/O, but tANS emits per-symbol variable bit
//! counts, so a real bit writer/reader is required.

/// Append-only LSB-first bit writer backed by a `Vec<u8>`.
#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits currently buffered in `acc` (0..=63).
    nbits: u32,
    acc: u64,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value` (n ≤ 57 per call).
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57, "write_bits supports at most 57 bits per call");
        debug_assert!(n == 64 || value < (1u64 << n), "value has bits above n");
        self.acc |= value << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Number of whole bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush any partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// LSB-first bit reader over a byte slice.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next byte index to load.
    pos: usize,
    nbits: u32,
    acc: u64,
}

impl<'a> BitReader<'a> {
    /// Reader positioned at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, nbits: 0, acc: 0 }
    }

    /// Read `n` bits (n ≤ 57). Returns `None` past end of stream.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 57);
        while self.nbits < n {
            let byte = *self.buf.get(self.pos)?;
            self.acc |= (byte as u64) << self.nbits;
            self.nbits += 8;
            self.pos += 1;
        }
        let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        let out = self.acc & mask;
        self.acc >>= n;
        self.nbits -= n;
        Some(out)
    }

    /// Bits remaining (including buffered ones).
    pub fn remaining_bits(&self) -> usize {
        (self.buf.len() - self.pos) * 8 + self.nbits as usize
    }
}

/// A reverse bit reader: reads bits from the *end* of the stream backwards.
///
/// tANS decodes in the reverse order of encoding; writing forward and
/// reading backward avoids buffering the whole symbol stream twice.
#[derive(Debug, Clone)]
pub struct RevBitReader<'a> {
    buf: &'a [u8],
    /// Total valid bits in the stream (writer may have zero-padded).
    bit_pos: usize,
}

impl<'a> RevBitReader<'a> {
    /// Reader positioned `valid_bits` from the start; reads move backwards.
    pub fn new(buf: &'a [u8], valid_bits: usize) -> Self {
        debug_assert!(valid_bits <= buf.len() * 8);
        RevBitReader { buf, bit_pos: valid_bits }
    }

    /// Read the `n` bits that were written immediately before the cursor,
    /// returning them in their original (written) order.
    #[inline]
    pub fn read_bits_rev(&mut self, n: u32) -> Option<u64> {
        if (self.bit_pos as u64) < n as u64 {
            return None;
        }
        self.bit_pos -= n as usize;
        let mut out = 0u64;
        for i in 0..n as usize {
            let bit_index = self.bit_pos + i;
            let byte = self.buf[bit_index / 8];
            let bit = (byte >> (bit_index % 8)) & 1;
            out |= (bit as u64) << i;
        }
        Some(out)
    }

    /// Bits left before the cursor hits the start of the stream.
    pub fn remaining_bits(&self) -> usize {
        self.bit_pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFF, 8);
        w.write_bits(0, 1);
        w.write_bits(0x1234, 16);
        let bits = w.bit_len();
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(3), Some(0b101));
        assert_eq!(r.read_bits(8), Some(0xFF));
        assert_eq!(r.read_bits(1), Some(0));
        assert_eq!(r.read_bits(16), Some(0x1234));
        assert_eq!(bits, 28);
    }

    #[test]
    fn roundtrip_random_widths() {
        let mut rng = Rng::new(99);
        let items: Vec<(u64, u32)> = (0..2000)
            .map(|_| {
                let n = rng.range_u64(1, 57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n), Some(v));
        }
    }

    #[test]
    fn reverse_reader_mirrors_writes() {
        let mut w = BitWriter::new();
        let items: &[(u64, u32)] = &[(0b1, 1), (0b1010, 4), (0x3F, 6), (0x155, 9)];
        for &(v, n) in items {
            w.write_bits(v, n);
        }
        let valid = w.bit_len();
        let buf = w.finish();
        let mut r = RevBitReader::new(&buf, valid);
        for &(v, n) in items.iter().rev() {
            assert_eq!(r.read_bits_rev(n), Some(v), "width {n}");
        }
        assert_eq!(r.remaining_bits(), 0);
    }

    #[test]
    fn read_past_end_is_none() {
        let buf = [0xAAu8];
        let mut r = BitReader::new(&buf);
        assert!(r.read_bits(8).is_some());
        assert!(r.read_bits(1).is_none());
        let mut rr = RevBitReader::new(&buf, 8);
        assert!(rr.read_bits_rev(9).is_none());
        assert!(rr.read_bits_rev(8).is_some());
        assert!(rr.read_bits_rev(1).is_none());
    }

    #[test]
    fn empty_writer_finishes_empty() {
        assert!(BitWriter::new().finish().is_empty());
    }
}
