//! A small fixed-size thread pool.
//!
//! Used by the persistent compression [`crate::engine`] (chunk-parallel
//! rANS lanes) and by the coordinator's request router. tokio is
//! unavailable offline; the serving stack is thread-based, which is also
//! closer to how a GPU implementation partitions lanes across SMs — a
//! fixed worker set with explicit work handoff.
//!
//! Two dispatch styles coexist:
//! * [`ThreadPool::run_batch`] — jobs run on the *persistent* workers
//!   and results return in submission order. This is the hot-path shape:
//!   thread startup is paid once at pool construction, not per call.
//! * [`ThreadPool::map`] — borrows its closure over scoped threads
//!   spawned per call. Convenient for cold paths that need non-`'static`
//!   borrows; costs ~1 ms of fan-out per call on a loaded host (measured
//!   in `benches/perf_hotpath.rs`), which is exactly what the engine's
//!   pooled dispatch avoids.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool with panic isolation.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    tx: Option<Sender<Job>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (size ≥ 1 enforced).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("rans-sc-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx), panics }
    }

    /// Pool sized to the machine (`available_parallelism`, min 2).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Number of jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Run a batch of independent jobs on the **persistent** workers,
    /// returning results in submission order.
    ///
    /// Blocks until every job has settled. A panicking job yields an
    /// `Err` carrying the panic payload in its slot (the other jobs are
    /// unaffected), so callers decide whether a lane failure is fatal.
    ///
    /// Unlike [`ThreadPool::map`], jobs must be `'static`: the engine
    /// shares input buffers with workers via `Arc` instead of borrowing.
    /// Do not call from inside a pool job — with every worker blocked on
    /// a nested batch the queue cannot drain.
    pub fn run_batch<R, F>(&self, jobs: Vec<F>) -> Vec<std::thread::Result<R>>
    where
        F: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let (tx, rx) = channel::<(usize, std::thread::Result<R>)>();
        for (idx, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.execute(move || {
                // Catch the panic *inside* the submitted closure so the
                // result channel always receives exactly one message per
                // job and the caller cannot deadlock.
                let result = catch_unwind(AssertUnwindSafe(job));
                let _ = tx.send((idx, result));
            });
        }
        drop(tx);
        let mut out: Vec<Option<std::thread::Result<R>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match rx.recv() {
                Ok((idx, result)) => out[idx] = Some(result),
                // All senders gone before n results: workers died (pool
                // shutdown mid-batch). Surface as panicked slots below.
                Err(_) => break,
            }
        }
        out.into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| Err(Box::new("worker pool shut down mid-batch")))
            })
            .collect()
    }

    /// Run `f` over `items` in parallel, preserving order of results.
    ///
    /// Blocks until all items are processed. Panics in `f` are propagated
    /// as a panic here (after all workers finish their share).
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync) -> Vec<R>
    where
        T: Send,
        R: Send,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let panicked = AtomicUsize::new(0);
        // Scoped threads let us borrow f and out without 'static bounds;
        // chunk the items across pool-size lanes.
        let lanes = self.size().min(n);
        let items = Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>());
        let out_ref = Mutex::new(&mut out);
        std::thread::scope(|scope| {
            for _ in 0..lanes {
                scope.spawn(|| loop {
                    let next = { items.lock().unwrap().pop() };
                    match next {
                        Some((idx, item)) => {
                            match catch_unwind(AssertUnwindSafe(|| f(item))) {
                                Ok(r) => {
                                    let mut guard = out_ref.lock().unwrap();
                                    guard[idx] = Some(r);
                                }
                                Err(_) => {
                                    panicked.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                        }
                        None => break,
                    }
                });
            }
        });
        if panicked.load(Ordering::SeqCst) > 0 {
            panic!("{} parallel map item(s) panicked", panicked.load(Ordering::SeqCst));
        }
        out.into_iter().map(|r| r.expect("missing map result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_is_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn panicking_job_does_not_kill_pool() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_batch_preserves_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..64)
            .map(|i: u64| move || i * i)
            .collect();
        let out = pool.run_batch(jobs);
        for (i, r) in out.into_iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), (i * i) as u64);
        }
    }

    #[test]
    fn run_batch_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<std::thread::Result<u32>> = pool.run_batch(Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn run_batch_isolates_panics() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("lane blew up")),
            Box::new(|| 3),
        ];
        let out = pool.run_batch(jobs);
        assert_eq!(*out[0].as_ref().unwrap(), 1);
        assert!(out[1].is_err());
        assert_eq!(*out[2].as_ref().unwrap(), 3);
        // The pool survives and keeps serving.
        let again = pool.run_batch(vec![|| 7u32]);
        assert_eq!(*again[0].as_ref().unwrap(), 7);
    }

    #[test]
    fn run_batch_reuses_persistent_workers() {
        // Thread ids seen across many batches must stay within the pool
        // size — no per-call spawning.
        let pool = ThreadPool::new(3);
        let mut ids = std::collections::HashSet::new();
        for _ in 0..10 {
            let jobs: Vec<_> = (0..6).map(|_| || std::thread::current().id()).collect();
            for r in pool.run_batch(jobs) {
                ids.insert(r.unwrap());
            }
        }
        assert!(ids.len() <= 3, "saw {} distinct worker threads", ids.len());
    }

    #[test]
    #[should_panic(expected = "parallel map item")]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("bad item")
            } else {
                x
            }
        });
    }
}
