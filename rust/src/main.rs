//! rans-sc launcher.
//!
//! Subcommands:
//!
//! * `serve-cloud`   — run the cloud node (TCP accept loop).
//! * `infer`         — one-shot edge inference against a cloud node.
//! * `compress`      — compress a synthetic/artifact IF, print stats.
//! * `optimize`      — run Algorithm 1 on a feature tensor, print Ñ.
//! * `accuracy`      — Table-2 style accuracy sweep for one model route.
//! * `stats`         — fetch a cloud node's metrics snapshot.
//! * `registry`      — publish/fetch/verify signed model deployments
//!   (`registry publish|fetch|verify`, keyed by `--set registry.key=…`).
//! * `version`       — print the version.
//!
//! Global flags: `--config <file.json>` and repeated `--set key=value`
//! overrides (see `config::AppConfig`).

use std::process::ExitCode;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use rans_sc::config::AppConfig;
use rans_sc::coordinator::{
    connect_tcp, connect_tcp_timeout, CloudNode, EdgeConfig, EdgeNode, ServerLimits,
};
use rans_sc::data::VisionSet;
use rans_sc::error::Result;
use rans_sc::eval;
use rans_sc::pipeline::{self, PipelineConfig};
use rans_sc::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};
use rans_sc::tensor::{Dtype, TensorRef};

struct Args {
    cmd: String,
    cfg: AppConfig,
    rest: Vec<String>,
}

/// The per-lane stream layout selected by the config's `states` knob.
fn layout_of(cfg: &AppConfig) -> rans_sc::pipeline::StreamLayout {
    if cfg.states <= 1 {
        rans_sc::pipeline::StreamLayout::V1
    } else {
        rans_sc::pipeline::StreamLayout::MultiState(cfg.states)
    }
}

fn parse_args() -> Result<Args> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        argv.push("help".to_string());
    }
    let cmd = argv.remove(0);
    let mut cfg = AppConfig::default();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--config" => {
                i += 1;
                let path = argv.get(i).ok_or_else(|| {
                    rans_sc::Error::config("--config needs a file argument")
                })?;
                cfg = AppConfig::from_file(path)?;
            }
            "--set" => {
                i += 1;
                let spec = argv.get(i).ok_or_else(|| {
                    rans_sc::Error::config("--set needs key=value")
                })?;
                cfg.apply_override(spec)?;
            }
            other => rest.push(other.to_string()),
        }
        i += 1;
    }
    Ok(Args { cmd, cfg, rest })
}

fn cmd_serve_cloud(cfg: &AppConfig) -> Result<()> {
    let node = Arc::new(
        CloudNode::new(&cfg.artifacts_dir)?
            .with_limits(ServerLimits { max_inflight: cfg.max_inflight }),
    );
    let listener = std::net::TcpListener::bind(&cfg.addr)
        .map_err(|e| rans_sc::Error::transport(format!("bind {}: {e}", cfg.addr)))?;
    println!("cloud node listening on {}", cfg.addr);
    let stop = Arc::new(AtomicBool::new(false));
    node.serve_tcp(listener, stop)?;
    println!("{}", node.metrics().report());
    Ok(())
}

fn cmd_infer(cfg: &AppConfig) -> Result<()> {
    if cfg.dtype != Dtype::F32 {
        // The vision infer path runs the head artifact, whose symbols
        // are f32-derived; the dtype knob drives `compress` and the LM
        // feature-level API (`LmEdgeNode::infer_features`).
        eprintln!(
            "note: dtype={} is ignored by the vision infer path (ships f32 symbols)",
            cfg.dtype
        );
    }
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, &cfg.artifacts_dir);
    let exec = Arc::new(VisionSplitExec::load(&pool, &manifest, &cfg.model, cfg.sl, cfg.batch)?);
    let set = VisionSet::load(manifest.resolve(&exec.entry.test_data))?;
    let io_timeout = std::time::Duration::from_millis(cfg.io_timeout_ms);
    let transport = connect_tcp_timeout(&cfg.addr, io_timeout)?;
    let redial_addr = cfg.addr.clone();
    let edge = EdgeNode::new(
        Arc::clone(&exec),
        transport,
        EdgeConfig {
            model: cfg.model.clone(),
            sl: cfg.sl,
            batch: cfg.batch,
            q: cfg.q,
            lanes: cfg.lanes,
            parallel: cfg.parallel,
            layout: layout_of(cfg),
            dtype: cfg.dtype,
        },
    )
    .with_session_config(cfg.session.clone())
    .with_reconnect(Box::new(move || connect_tcp_timeout(&redial_addr, io_timeout)));
    let (xs, ys) = set.batch(0, cfg.batch);
    let out = edge.infer(&xs)?;
    let classes = exec.entry.num_classes;
    for (b, &label) in ys.iter().enumerate() {
        let logits = &out.logits[b * classes..(b + 1) * classes];
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("sample {b}: predicted {pred}, label {label}");
    }
    println!(
        "payload {} B | encode {:.3} ms | T_comm {:.3} ms | decode {:.3} ms | compute {:.3} ms",
        out.payload_bytes,
        out.breakdown.encode_ms,
        out.breakdown.transfer_ms,
        out.breakdown.decode_ms,
        out.breakdown.compute_ms
    );
    Ok(())
}

fn cmd_compress(cfg: &AppConfig) -> Result<()> {
    let (data, source) = eval::feature_tensor(&cfg.artifacts_dir, &cfg.model, cfg.sl)?;
    println!(
        "feature source: {source:?}, {} elements ({} on the wire)",
        data.len(),
        cfg.dtype
    );
    // Non-f32 dtypes narrow the feature to the configured element type
    // first (the stand-in for a half-precision head), then compress
    // through the zero-copy dtype-generic entry point.
    let pcfg = PipelineConfig { lanes: cfg.lanes, ..PipelineConfig::paper(cfg.q) }
        .with_states(cfg.states);
    let bits: Vec<u16> = if cfg.dtype.is_half() {
        rans_sc::tensor::narrow_to_half_bits(&data, cfg.dtype)
    } else {
        Vec::new()
    };
    let tensor = if cfg.dtype.is_half() {
        TensorRef::from_half_bits(cfg.dtype, &bits)
    } else {
        TensorRef::from_f32(&data)
    };
    let raw_bytes = tensor.byte_len();
    let (bytes, stats) = pipeline::compress_tensor(tensor, &pcfg)?;
    println!(
        "Q={} reshape {}x{} nnz={} entropy={:.3} b/sym",
        cfg.q, stats.n_rows, stats.n_cols, stats.nnz, stats.entropy
    );
    println!(
        "raw {} B -> {} B ({:.2}x), payload {} B + side {} B",
        raw_bytes,
        bytes.len(),
        raw_bytes as f64 / bytes.len() as f64,
        stats.payload_bytes,
        stats.side_info_bytes
    );
    let back = pipeline::decompress(&bytes)?;
    println!("roundtrip ok: {} elements", back.len());
    Ok(())
}

fn cmd_optimize(cfg: &AppConfig) -> Result<()> {
    let (data, source) = eval::feature_tensor(&cfg.artifacts_dir, &cfg.model, cfg.sl)?;
    println!("feature source: {source:?}");
    let sweeps = eval::cost_model_sweep(&data, &[cfg.q])?;
    let s = &sweeps[0];
    println!(
        "Q={}: domain {} candidates, Algorithm 1 evaluated {}",
        s.q, s.domain_size, s.evaluated
    );
    println!(
        "Ñ = {} ({} B) vs N* = {} ({} B) — gap {:.2}%",
        s.n_tilde,
        s.bytes_at_tilde,
        s.n_star,
        s.bytes_at_star,
        s.gap() * 100.0
    );
    Ok(())
}

fn cmd_accuracy(cfg: &AppConfig, rest: &[String]) -> Result<()> {
    let n_samples: usize = rest
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, &cfg.artifacts_dir);
    let exec = VisionSplitExec::load(&pool, &manifest, &cfg.model, cfg.sl, 1)?;
    let set = VisionSet::load(manifest.resolve(&exec.entry.test_data))?;
    println!(
        "model {} SL{} — build-time baseline {:.4}",
        cfg.model, cfg.sl, exec.entry.baseline_accuracy
    );
    let points = eval::accuracy_sweep(&exec, &set, &[8, 6, 4, 3, 2], n_samples)?;
    println!("{:>8} {:>10} {:>12} {:>10} {:>10}", "Q", "acc", "payload B", "enc ms", "dec ms");
    for p in &points {
        let q = p.q.map(|q| q.to_string()).unwrap_or_else(|| "base".into());
        println!(
            "{q:>8} {:>10.4} {:>12.0} {:>10.3} {:>10.3}",
            p.accuracy,
            p.mean_payload_bytes,
            p.enc_ms.mean(),
            p.dec_ms.mean()
        );
    }
    Ok(())
}

fn cmd_registry(cfg: &AppConfig, rest: &[String]) -> Result<()> {
    use rans_sc::runtime::registry::{
        ChunkStore, DeployParams, HmacSha256Signer, RegistryManifest, DEFAULT_CHUNK_LEN,
    };
    let usage = || {
        rans_sc::Error::config(
            "usage: registry publish <model> <version> <head-file> <tail-file> | \
             registry fetch <model> [version] | registry verify <model> [version]",
        )
    };
    let sub = rest.first().map(String::as_str).ok_or_else(usage)?;
    if cfg.registry.key.is_empty() {
        return Err(rans_sc::Error::config(
            "registry.key is not set (--set registry.key=…): refusing to sign or \
             verify with an empty key",
        ));
    }
    let signer =
        HmacSha256Signer::new(cfg.registry.key.as_bytes(), cfg.registry.key_id.clone());
    let store = ChunkStore::open(&cfg.registry.dir);
    let parse_version = |s: &String| {
        s.parse::<u64>()
            .map_err(|_| rans_sc::Error::config(format!("bad model version '{s}'")))
    };
    match sub {
        "publish" => {
            let (model, version, head_path, tail_path) =
                match (rest.get(1), rest.get(2), rest.get(3), rest.get(4)) {
                    (Some(m), Some(v), Some(h), Some(t)) => (m, parse_version(v)?, h, t),
                    _ => return Err(usage()),
                };
            let read = |p: &String| {
                std::fs::read(p)
                    .map_err(|e| rans_sc::Error::artifact(format!("{p}: read failed: {e}")))
            };
            let head_bytes = read(head_path)?;
            let tail_bytes = read(tail_path)?;
            let manifest = RegistryManifest {
                model: model.clone(),
                model_version: version,
                deploy: DeployParams {
                    sl: cfg.sl,
                    batch: cfg.batch,
                    q: cfg.q,
                    lanes: cfg.lanes,
                    states: cfg.states,
                    dtype: cfg.dtype.name().into(),
                },
                head: store.put_artifact(&head_bytes, DEFAULT_CHUNK_LEN)?,
                tail: store.put_artifact(&tail_bytes, DEFAULT_CHUNK_LEN)?,
            };
            let path = store.publish(&manifest, &signer)?;
            println!(
                "published {model} v{version} ({} + {} bytes, {} chunks) -> {}",
                head_bytes.len(),
                tail_bytes.len(),
                manifest.head.chunks.len() + manifest.tail.chunks.len(),
                path.display()
            );
        }
        "fetch" => {
            let model = rest.get(1).ok_or_else(usage)?;
            let version = rest.get(2).map(parse_version).transpose()?;
            let dep = store.fetch(model, version, &signer)?;
            println!(
                "fetched {} v{}: head {} B, tail {} B (every byte verified)",
                dep.manifest.model,
                dep.manifest.model_version,
                dep.head.len(),
                dep.tail.len()
            );
            let d = &dep.manifest.deploy;
            println!(
                "deploy params: sl={} batch={} q={} lanes={} states={} dtype={}",
                d.sl, d.batch, d.q, d.lanes, d.states, d.dtype
            );
        }
        "verify" => {
            let model = rest.get(1).ok_or_else(usage)?;
            let version = rest.get(2).map(parse_version).transpose()?;
            let manifest = store.load_manifest(model, version, &signer)?;
            let head = store.verify_artifact(&manifest.head)?;
            let tail = store.verify_artifact(&manifest.tail)?;
            println!(
                "verified {} v{}: signature ok, head {head} B ok, tail {tail} B ok",
                manifest.model, manifest.model_version
            );
        }
        _ => return Err(usage()),
    }
    Ok(())
}

fn cmd_stats(cfg: &AppConfig) -> Result<()> {
    use rans_sc::coordinator::{Frame, FrameKind, Transport};
    let mut t = connect_tcp(&cfg.addr)?;
    t.send(&Frame::new(1, FrameKind::Stats))?;
    match t.recv()?.kind {
        FrameKind::StatsReply { json } => println!("{json}"),
        other => println!("unexpected reply: {other:?}"),
    }
    Ok(())
}

fn help() {
    println!(
        "rans-sc {} — rANS split-computing coordinator

USAGE: rans-sc <command> [--config file.json] [--set key=value]...

Encode-side commands autotune the rANS `lanes`/`states` shape for this
machine with a one-shot microbenchmark; `--set lanes=…` / `--set
states=…` pin a knob and `--set autotune=off` disables tuning. The
decode backend can be pinned with RANS_SC_FORCE_BACKEND=
scalar|sse4.1|avx2|neon.

The TCP link is resilient by default: `infer` wraps its connection in
a session with deadline-aware retry/backoff, heartbeat reconnect, and
shed-aware error reporting. Tune it with `--set io_timeout_ms=…`,
`--set session.deadline_ms=…`, `--set session.max_retries=…`, etc.;
`serve-cloud` caps concurrent work with `--set max_inflight=…` and
answers `Busy` (with a retry-after hint) when overloaded.

COMMANDS:
  serve-cloud        run the cloud node (binds --set addr=HOST:PORT)
  infer              one edge inference against a running cloud node
  compress           compress an IF tensor and print pipeline stats
                     (--set dtype=bf16 ships half-precision features)
  optimize           run Algorithm 1 (reshape search) and print Ñ vs N*
  accuracy [N]       accuracy sweep over Q for the configured model
  stats              fetch cloud metrics snapshot
  registry publish <model> <version> <head> <tail>
                     chunk, hash, sign, and store a deployment
                     (key via --set registry.key=…, root via
                     --set registry.dir=…)
  registry fetch <model> [version]
                     fetch a deployment, verifying signature and
                     every chunk's SHA-256 while streaming
  registry verify <model> [version]
                     verify a stored deployment without keeping it
  version            print version
",
        rans_sc::version()
    );
}

fn main() -> ExitCode {
    let mut args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    // Encode-side commands pick up the machine-tuned `lanes × states`
    // shape unless the config pins it (`--set lanes=…` / `--set
    // states=…` always win; `--set autotune=off` disables tuning).
    // Decode side needs nothing: the stream is self-describing.
    if matches!(args.cmd.as_str(), "infer" | "compress") {
        if let Some(t) = rans_sc::engine::autotune::apply(&mut args.cfg) {
            eprintln!(
                "autotune: lanes={} states={} (decode backend {}; --set autotune=off to disable)",
                t.lanes,
                t.states,
                t.backend.name()
            );
        }
    }
    let result = match args.cmd.as_str() {
        "serve-cloud" => cmd_serve_cloud(&args.cfg),
        "infer" => cmd_infer(&args.cfg),
        "compress" => cmd_compress(&args.cfg),
        "optimize" => cmd_optimize(&args.cfg),
        "accuracy" => cmd_accuracy(&args.cfg, &args.rest),
        "stats" => cmd_stats(&args.cfg),
        "registry" => cmd_registry(&args.cfg, &args.rest),
        "version" => {
            println!("rans-sc {}", rans_sc::version());
            Ok(())
        }
        _ => {
            help();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
