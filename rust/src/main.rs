//! rans-sc launcher.
//!
//! Subcommands:
//!
//! * `serve-cloud`   — run the cloud node (TCP accept loop); with
//!   `--daemon`, serve through the actor-based daemon (adaptive
//!   batching, per-tenant quotas).
//! * `loadgen`       — synthetic fleet load test against an in-process
//!   daemon; prints the outcome/latency report JSON.
//! * `infer`         — one-shot edge inference against a cloud node.
//! * `compress`      — compress a synthetic/artifact IF, print stats.
//! * `optimize`      — run Algorithm 1 on a feature tensor, print Ñ.
//! * `accuracy`      — Table-2 style accuracy sweep for one model route.
//! * `stats`         — fetch a cloud node's metrics snapshot.
//! * `registry`      — publish/fetch/verify signed model deployments,
//!   diff versions, and delta-sync from a mirror
//!   (`registry publish|fetch|verify|delta|sync`, keyed by
//!   `--set registry.key=…`).
//! * `version`       — print the version.
//!
//! Global flags: `--config <file.json>` and repeated `--set key=value`
//! overrides (see `config::AppConfig`).

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use rans_sc::config::AppConfig;
use rans_sc::coordinator::{
    connect_tcp, connect_tcp_timeout, CloudNode, EdgeConfig, EdgeNode, ServerLimits,
};
use rans_sc::data::VisionSet;
use rans_sc::error::Result;
use rans_sc::eval;
use rans_sc::pipeline::{self, PipelineConfig};
use rans_sc::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};
use rans_sc::tensor::{Dtype, TensorRef};

struct Args {
    cmd: String,
    cfg: AppConfig,
    rest: Vec<String>,
}

/// The per-lane stream layout selected by the config's `states` knob.
fn layout_of(cfg: &AppConfig) -> rans_sc::pipeline::StreamLayout {
    if cfg.states <= 1 {
        rans_sc::pipeline::StreamLayout::V1
    } else {
        rans_sc::pipeline::StreamLayout::MultiState(cfg.states)
    }
}

fn parse_args() -> Result<Args> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        argv.push("help".to_string());
    }
    let cmd = argv.remove(0);
    let mut cfg = AppConfig::default();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--config" => {
                i += 1;
                let path = argv.get(i).ok_or_else(|| {
                    rans_sc::Error::config("--config needs a file argument")
                })?;
                cfg = AppConfig::from_file(path)?;
            }
            "--set" => {
                i += 1;
                let spec = argv.get(i).ok_or_else(|| {
                    rans_sc::Error::config("--set needs key=value")
                })?;
                cfg.apply_override(spec)?;
            }
            other => rest.push(other.to_string()),
        }
        i += 1;
    }
    Ok(Args { cmd, cfg, rest })
}

fn cmd_serve_cloud(cfg: &AppConfig, rest: &[String]) -> Result<()> {
    let listener = std::net::TcpListener::bind(&cfg.addr)
        .map_err(|e| rans_sc::Error::transport(format!("bind {}: {e}", cfg.addr)))?;
    let stop = Arc::new(AtomicBool::new(false));
    if rest.iter().any(|a| a == "--daemon") {
        // Actor-based daemon front: adaptive batching, per-tenant
        // quotas (tenant = peer IP), live knobs seeded from `daemon.*`.
        let node = Arc::new(CloudNode::new(&cfg.artifacts_dir)?);
        let daemon = rans_sc::coordinator::Daemon::for_node(cfg.daemon_config(), node);
        println!("serving daemon listening on {}", cfg.addr);
        daemon.serve_tcp(listener, stop)?;
        println!("{}", daemon.metrics().report());
        daemon.shutdown();
        return Ok(());
    }
    let node = Arc::new(
        CloudNode::new(&cfg.artifacts_dir)?
            .with_limits(ServerLimits { max_inflight: cfg.max_inflight }),
    );
    println!("cloud node listening on {}", cfg.addr);
    node.serve_tcp(listener, stop)?;
    println!("{}", node.metrics().report());
    Ok(())
}

fn cmd_loadgen(cfg: &AppConfig, rest: &[String]) -> Result<()> {
    use rans_sc::coordinator::loadgen::{self, LoadgenConfig};
    let mut lg = LoadgenConfig { daemon: cfg.daemon_config(), ..Default::default() };
    let mut i = 0;
    while i < rest.len() {
        let flag = rest[i].as_str();
        let val = rest.get(i + 1).ok_or_else(|| {
            rans_sc::Error::config(format!("loadgen flag '{flag}' needs a value"))
        })?;
        let bad = |what: &str| {
            rans_sc::Error::config(format!("loadgen: bad {what} '{val}' for '{flag}'"))
        };
        match flag {
            "--edges" => lg.edges = val.parse().map_err(|_| bad("count"))?,
            "--requests" => lg.requests_per_edge = val.parse().map_err(|_| bad("count"))?,
            "--tenants" => lg.tenants = val.parse().map_err(|_| bad("count"))?,
            "--seed" => lg.seed = val.parse().map_err(|_| bad("seed"))?,
            "--faulty" => lg.faulty_share = val.parse().map_err(|_| bad("fraction"))?,
            "--service-us" => lg.service_us = val.parse().map_err(|_| bad("micros"))?,
            "--workers" => lg.workers = val.parse().map_err(|_| bad("count"))?,
            other => {
                return Err(rans_sc::Error::config(format!(
                    "unknown loadgen flag '{other}' (see `rans-sc help`)"
                )))
            }
        }
        i += 2;
    }
    let report = loadgen::run(&lg);
    println!("{}", report.to_json());
    if report.unanswered != 0 {
        return Err(rans_sc::Error::runtime(format!(
            "{} of {} requests got no explicit outcome",
            report.unanswered, report.requests
        )));
    }
    Ok(())
}

fn cmd_infer(cfg: &AppConfig) -> Result<()> {
    if cfg.dtype != Dtype::F32 {
        // The vision infer path runs the head artifact, whose symbols
        // are f32-derived; the dtype knob drives `compress` and the LM
        // feature-level API (`LmEdgeNode::infer_features`).
        eprintln!(
            "note: dtype={} is ignored by the vision infer path (ships f32 symbols)",
            cfg.dtype
        );
    }
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, &cfg.artifacts_dir);
    let exec = Arc::new(VisionSplitExec::load(&pool, &manifest, &cfg.model, cfg.sl, cfg.batch)?);
    let set = VisionSet::load(manifest.resolve(&exec.entry.test_data))?;
    let io_timeout = std::time::Duration::from_millis(cfg.io_timeout_ms);
    let transport = connect_tcp_timeout(&cfg.addr, io_timeout)?;
    let redial_addr = cfg.addr.clone();
    let edge = EdgeNode::new(
        Arc::clone(&exec),
        transport,
        EdgeConfig {
            model: cfg.model.clone(),
            sl: cfg.sl,
            batch: cfg.batch,
            q: cfg.q,
            lanes: cfg.lanes,
            parallel: cfg.parallel,
            layout: layout_of(cfg),
            dtype: cfg.dtype,
        },
    )
    .with_session_config(cfg.session.clone())
    .with_reconnect(Box::new(move || connect_tcp_timeout(&redial_addr, io_timeout)));
    let (xs, ys) = set.batch(0, cfg.batch);
    let out = edge.infer(&xs)?;
    let classes = exec.entry.num_classes;
    for (b, &label) in ys.iter().enumerate() {
        let logits = &out.logits[b * classes..(b + 1) * classes];
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("sample {b}: predicted {pred}, label {label}");
    }
    println!(
        "payload {} B | encode {:.3} ms | T_comm {:.3} ms | decode {:.3} ms | compute {:.3} ms",
        out.payload_bytes,
        out.breakdown.encode_ms,
        out.breakdown.transfer_ms,
        out.breakdown.decode_ms,
        out.breakdown.compute_ms
    );
    Ok(())
}

fn cmd_compress(cfg: &AppConfig) -> Result<()> {
    let (data, source) = eval::feature_tensor(&cfg.artifacts_dir, &cfg.model, cfg.sl)?;
    println!(
        "feature source: {source:?}, {} elements ({} on the wire)",
        data.len(),
        cfg.dtype
    );
    // Non-f32 dtypes narrow the feature to the configured element type
    // first (the stand-in for a half-precision head), then compress
    // through the zero-copy dtype-generic entry point.
    let pcfg = PipelineConfig { lanes: cfg.lanes, ..PipelineConfig::paper(cfg.q) }
        .with_states(cfg.states);
    let bits: Vec<u16> = if cfg.dtype.is_half() {
        rans_sc::tensor::narrow_to_half_bits(&data, cfg.dtype)
    } else {
        Vec::new()
    };
    let tensor = if cfg.dtype.is_half() {
        TensorRef::from_half_bits(cfg.dtype, &bits)
    } else {
        TensorRef::from_f32(&data)
    };
    let raw_bytes = tensor.byte_len();
    let (bytes, stats) = pipeline::compress_tensor(tensor, &pcfg)?;
    println!(
        "Q={} reshape {}x{} nnz={} entropy={:.3} b/sym",
        cfg.q, stats.n_rows, stats.n_cols, stats.nnz, stats.entropy
    );
    println!(
        "raw {} B -> {} B ({:.2}x), payload {} B + side {} B",
        raw_bytes,
        bytes.len(),
        raw_bytes as f64 / bytes.len() as f64,
        stats.payload_bytes,
        stats.side_info_bytes
    );
    let back = pipeline::decompress(&bytes)?;
    println!("roundtrip ok: {} elements", back.len());
    Ok(())
}

fn cmd_optimize(cfg: &AppConfig) -> Result<()> {
    let (data, source) = eval::feature_tensor(&cfg.artifacts_dir, &cfg.model, cfg.sl)?;
    println!("feature source: {source:?}");
    let sweeps = eval::cost_model_sweep(&data, &[cfg.q])?;
    let s = &sweeps[0];
    println!(
        "Q={}: domain {} candidates, Algorithm 1 evaluated {}",
        s.q, s.domain_size, s.evaluated
    );
    println!(
        "Ñ = {} ({} B) vs N* = {} ({} B) — gap {:.2}%",
        s.n_tilde,
        s.bytes_at_tilde,
        s.n_star,
        s.bytes_at_star,
        s.gap() * 100.0
    );
    Ok(())
}

fn cmd_accuracy(cfg: &AppConfig, rest: &[String]) -> Result<()> {
    let n_samples: usize = rest
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, &cfg.artifacts_dir);
    let exec = VisionSplitExec::load(&pool, &manifest, &cfg.model, cfg.sl, 1)?;
    let set = VisionSet::load(manifest.resolve(&exec.entry.test_data))?;
    println!(
        "model {} SL{} — build-time baseline {:.4}",
        cfg.model, cfg.sl, exec.entry.baseline_accuracy
    );
    let points = eval::accuracy_sweep(&exec, &set, &[8, 6, 4, 3, 2], n_samples)?;
    println!("{:>8} {:>10} {:>12} {:>10} {:>10}", "Q", "acc", "payload B", "enc ms", "dec ms");
    for p in &points {
        let q = p.q.map(|q| q.to_string()).unwrap_or_else(|| "base".into());
        println!(
            "{q:>8} {:>10.4} {:>12.0} {:>10.3} {:>10.3}",
            p.accuracy,
            p.mean_payload_bytes,
            p.enc_ms.mean(),
            p.dec_ms.mean()
        );
    }
    Ok(())
}

fn cmd_registry(cfg: &AppConfig, rest: &[String]) -> Result<()> {
    use rans_sc::runtime::registry::{
        sync_deployment, CdcParams, ChunkStore, DeltaPlan, DeployParams, HmacSha256Signer,
        RegistryManifest, StoreSource, SyncOptions, DEFAULT_CHUNK_LEN,
    };
    let usage = || {
        rans_sc::Error::config(
            "usage: registry publish <model> <version> <head-file> <tail-file> | \
             registry fetch <model> [version] [head-out tail-out] | \
             registry verify <model> [version] | \
             registry delta <model> <from> <to> | \
             registry sync <model> [version]  (source via --set registry.src=DIR)",
        )
    };
    let sub = rest.first().map(String::as_str).ok_or_else(usage)?;
    if cfg.registry.key.is_empty() {
        return Err(rans_sc::Error::config(
            "registry.key is not set (--set registry.key=…): refusing to sign or \
             verify with an empty key",
        ));
    }
    let signer =
        HmacSha256Signer::new(cfg.registry.key.as_bytes(), cfg.registry.key_id.clone());
    let store = ChunkStore::open(&cfg.registry.dir);
    let parse_version = |s: &String| {
        s.parse::<u64>()
            .map_err(|_| rans_sc::Error::config(format!("bad model version '{s}'")))
    };
    match sub {
        "publish" => {
            let (model, version, head_path, tail_path) =
                match (rest.get(1), rest.get(2), rest.get(3), rest.get(4)) {
                    (Some(m), Some(v), Some(h), Some(t)) => (m, parse_version(v)?, h, t),
                    _ => return Err(usage()),
                };
            let read = |p: &String| {
                std::fs::read(p)
                    .map_err(|e| rans_sc::Error::artifact(format!("{p}: read failed: {e}")))
            };
            let head_bytes = read(head_path)?;
            let tail_bytes = read(tail_path)?;
            // CDC boundaries survive insertions across versions, so
            // later `registry delta` transfers stay minimal.
            let put = |bytes: &[u8]| {
                if cfg.registry.chunking == "cdc" {
                    store.put_artifact_cdc(bytes, &CdcParams::default())
                } else {
                    store.put_artifact(bytes, DEFAULT_CHUNK_LEN)
                }
            };
            let manifest = RegistryManifest {
                model: model.clone(),
                model_version: version,
                deploy: DeployParams {
                    sl: cfg.sl,
                    batch: cfg.batch,
                    q: cfg.q,
                    lanes: cfg.lanes,
                    states: cfg.states,
                    dtype: cfg.dtype.name().into(),
                },
                head: put(&head_bytes)?,
                tail: put(&tail_bytes)?,
            };
            let path = store.publish(&manifest, &signer)?;
            println!(
                "published {model} v{version} ({} + {} bytes, {} chunks, {} chunking) -> {}",
                head_bytes.len(),
                tail_bytes.len(),
                manifest.head.chunks.len() + manifest.tail.chunks.len(),
                cfg.registry.chunking,
                path.display()
            );
        }
        "fetch" => {
            let model = rest.get(1).ok_or_else(usage)?;
            // `fetch <model> [version] [head-out tail-out]`: an
            // all-digits second operand is the version, anything else
            // starts the output paths.
            let has_version =
                rest.get(2).is_some_and(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()));
            let version = if has_version { Some(parse_version(&rest[2])?) } else { None };
            let out_idx = if has_version { 3 } else { 2 };
            let dep = store.fetch(model, version, &signer)?;
            let v = dep.manifest.model_version;
            let (head_out, tail_out) = match (rest.get(out_idx), rest.get(out_idx + 1)) {
                (Some(h), Some(t)) => (PathBuf::from(h), PathBuf::from(t)),
                (Some(_), None) => {
                    return Err(rans_sc::Error::config(
                        "fetch output needs BOTH paths: <head-out> <tail-out>",
                    ))
                }
                (None, _) => {
                    let dir = PathBuf::from(&cfg.registry.out);
                    (
                        dir.join(format!("{model}-v{v}-head.bin")),
                        dir.join(format!("{model}-v{v}-tail.bin")),
                    )
                }
            };
            dep.write_to(&head_out, &tail_out)?;
            println!(
                "fetched {} v{v}: head {} B -> {}, tail {} B -> {} (every byte verified)",
                dep.manifest.model,
                dep.head.len(),
                head_out.display(),
                dep.tail.len(),
                tail_out.display()
            );
            let d = &dep.manifest.deploy;
            println!(
                "deploy params: sl={} batch={} q={} lanes={} states={} dtype={}",
                d.sl, d.batch, d.q, d.lanes, d.states, d.dtype
            );
        }
        "verify" => {
            let model = rest.get(1).ok_or_else(usage)?;
            let version = rest.get(2).map(parse_version).transpose()?;
            let manifest = store.load_manifest(model, version, &signer)?;
            let head = store.verify_artifact(&manifest.head)?;
            let tail = store.verify_artifact(&manifest.tail)?;
            println!(
                "verified {} v{}: signature ok, head {head} B ok, tail {tail} B ok",
                manifest.model, manifest.model_version
            );
        }
        "delta" => {
            let (model, from, to) = match (rest.get(1), rest.get(2), rest.get(3)) {
                (Some(m), Some(f), Some(t)) => (m, parse_version(f)?, parse_version(t)?),
                _ => return Err(usage()),
            };
            let from_m = store.load_manifest(model, Some(from), &signer)?;
            let to_m = store.load_manifest(model, Some(to), &signer)?;
            let plan = DeltaPlan::plan(&from_m, &to_m);
            println!("{}", plan.to_json());
        }
        "sync" => {
            let model = rest.get(1).ok_or_else(usage)?;
            let version = rest.get(2).map(parse_version).transpose()?.unwrap_or(0);
            if cfg.registry.src.is_empty() {
                return Err(rans_sc::Error::config(
                    "registry.src is not set (--set registry.src=DIR): nothing to sync from",
                ));
            }
            // Deterministic mid-stream kill for the resume wall: CI
            // sets this to abort after N chunk downloads, then re-runs
            // the sync and asserts no completed chunk is re-fetched.
            let abort_after = std::env::var("RANS_SC_SYNC_ABORT_AFTER")
                .ok()
                .and_then(|s| s.parse::<u64>().ok());
            let mut source = StoreSource::open(&cfg.registry.src);
            let (manifest, report) = sync_deployment(
                &store,
                &mut source,
                &signer,
                model,
                version,
                &SyncOptions { abort_after },
            )?;
            println!("synced {} v{}: {}", manifest.model, manifest.model_version, report.to_json());
        }
        _ => return Err(usage()),
    }
    Ok(())
}

fn cmd_stats(cfg: &AppConfig) -> Result<()> {
    use rans_sc::coordinator::{Frame, FrameKind, Transport};
    let mut t = connect_tcp(&cfg.addr)?;
    t.send(&Frame::new(1, FrameKind::Stats))?;
    match t.recv()?.kind {
        FrameKind::StatsReply { json } => println!("{json}"),
        other => println!("unexpected reply: {other:?}"),
    }
    Ok(())
}

fn help() {
    println!(
        "rans-sc {} — rANS split-computing coordinator

USAGE: rans-sc <command> [--config file.json] [--set key=value]...

Encode-side commands autotune the rANS `lanes`/`states` shape for this
machine with a one-shot microbenchmark; `--set lanes=…` / `--set
states=…` pin a knob and `--set autotune=off` disables tuning. The
decode backend can be pinned with RANS_SC_FORCE_BACKEND=
scalar|sse4.1|avx2|neon.

The TCP link is resilient by default: `infer` wraps its connection in
a session with deadline-aware retry/backoff, heartbeat reconnect, and
shed-aware error reporting. Tune it with `--set io_timeout_ms=…`,
`--set session.deadline_ms=…`, `--set session.max_retries=…`, etc.;
`serve-cloud` caps concurrent work with `--set max_inflight=…` and
answers `Busy` (with a retry-after hint) when overloaded.

COMMANDS:
  serve-cloud        run the cloud node (binds --set addr=HOST:PORT);
                     --daemon serves through the actor-based daemon:
                     adaptive batching, per-tenant (peer-IP) quotas,
                     live dials seeded from --set daemon.*
  loadgen            drive a fresh in-process daemon with a synthetic
                     fleet and print the outcome/latency report JSON
                     (req_per_s, p50_ms, p99_ms, unanswered must be 0);
                     --edges N --requests N --tenants N --seed N
                     --faulty 0.1 --service-us N --workers N
  infer              one edge inference against a running cloud node
  compress           compress an IF tensor and print pipeline stats
                     (--set dtype=bf16 ships half-precision features)
  optimize           run Algorithm 1 (reshape search) and print Ñ vs N*
  accuracy [N]       accuracy sweep over Q for the configured model
  stats              fetch cloud metrics snapshot
  registry publish <model> <version> <head> <tail>
                     chunk, hash, sign, and store a deployment
                     (key via --set registry.key=…, root via
                     --set registry.dir=…)
  registry fetch <model> [version] [head-out tail-out]
                     fetch a deployment, verifying signature and
                     every chunk's SHA-256 while streaming, then
                     write both halves to the output paths (default:
                     --set registry.out=DIR, ./fetched)
  registry verify <model> [version]
                     verify a stored deployment without keeping it
  registry delta <model> <from> <to>
                     diff two published versions' chunk sets; print
                     missing addresses + delta_bytes vs full_bytes
  registry sync <model> [version]
                     delta-sync a version from a mirror registry
                     (--set registry.src=DIR), resuming any
                     interrupted fetch from its sidecar
  version            print version
",
        rans_sc::version()
    );
}

fn main() -> ExitCode {
    let mut args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    // Encode-side commands pick up the machine-tuned `lanes × states`
    // shape unless the config pins it (`--set lanes=…` / `--set
    // states=…` always win; `--set autotune=off` disables tuning).
    // Decode side needs nothing: the stream is self-describing.
    if matches!(args.cmd.as_str(), "infer" | "compress") {
        if let Some(t) = rans_sc::engine::autotune::apply(&mut args.cfg) {
            eprintln!(
                "autotune: lanes={} states={} (decode backend {}; --set autotune=off to disable)",
                t.lanes,
                t.states,
                t.backend.name()
            );
        }
    }
    let result = match args.cmd.as_str() {
        "serve-cloud" => cmd_serve_cloud(&args.cfg, &args.rest),
        "loadgen" => cmd_loadgen(&args.cfg, &args.rest),
        "infer" => cmd_infer(&args.cfg),
        "compress" => cmd_compress(&args.cfg),
        "optimize" => cmd_optimize(&args.cfg),
        "accuracy" => cmd_accuracy(&args.cfg, &args.rest),
        "stats" => cmd_stats(&args.cfg),
        "registry" => cmd_registry(&args.cfg, &args.rest),
        "version" => {
            println!("rans-sc {}", rans_sc::version());
            Ok(())
        }
        _ => {
            help();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
