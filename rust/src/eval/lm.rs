//! Table-3 driver: LM multiple-choice sweep.
//!
//! Per (model, task, Q): accuracy, `T_comm(Ñ)` under the ε-outage
//! channel, mean container size, and encode/decode timing — the exact
//! columns of Table 3, with the baseline row using the raw float path.

use crate::channel::OutageChannel;
use crate::data::{lm_tasks::score_choices, McTask};
use crate::error::Result;
use crate::pipeline::{self, PipelineConfig, ReshapeStrategy};
use crate::runtime::LmSplitExec;
use crate::util::stats::Summary;
use crate::util::timer::Stopwatch;

/// One Table-3 row.
#[derive(Debug, Clone)]
pub struct LmRow {
    /// Task id.
    pub task: String,
    /// Bit-width; `None` = uncompressed baseline.
    pub q: Option<u8>,
    /// Multiple-choice accuracy.
    pub accuracy: f64,
    /// Mean payload bytes per item.
    pub mean_payload_bytes: f64,
    /// Deterministic ε-outage communication latency for the mean payload.
    pub t_comm_ms: f64,
    /// Encode timing summary (head + pipeline), ms.
    pub enc_ms: Summary,
    /// Decode timing summary, ms.
    pub dec_ms: Summary,
}

/// Evaluate one task at the baseline and each Q.
pub fn lm_task_sweep(
    exec: &LmSplitExec,
    task: &McTask,
    task_name: &str,
    qs: &[u8],
    n_items: usize,
    channel: &OutageChannel,
) -> Result<Vec<LmRow>> {
    let n = n_items.min(task.items.len()).max(1);
    let mut rows = Vec::new();

    // Baseline (raw hidden states over the link).
    {
        let mut correct = 0usize;
        let mut payload = Summary::new();
        let mut enc = Summary::new();
        for item in task.items.iter().take(n) {
            let tokens = task.item_batch(item);
            let t0 = Stopwatch::new();
            let hidden = exec.run_head_raw(&tokens)?;
            enc.add(t0.elapsed_ms());
            payload.add((hidden.len() * 4) as f64);
            let logits = exec.run_tail_raw(&hidden)?;
            if score_choices(&logits, task, item) == item.correct {
                correct += 1;
            }
        }
        rows.push(LmRow {
            task: task_name.to_string(),
            q: None,
            accuracy: correct as f64 / n as f64,
            mean_payload_bytes: payload.mean(),
            t_comm_ms: channel.comm_latency_ms(payload.mean() as usize),
            enc_ms: enc,
            dec_ms: Summary::new(),
        });
    }

    for &q in qs {
        let mut correct = 0usize;
        let mut payload = Summary::new();
        let mut enc = Summary::new();
        let mut dec = Summary::new();
        let mut plan: Option<usize> = None;
        for item in task.items.iter().take(n) {
            let tokens = task.item_batch(item);
            let t0 = Stopwatch::new();
            let (symbols, params) = exec.run_head(&tokens, q)?;
            let reshape = match plan {
                Some(np) => ReshapeStrategy::Fixed(np),
                None => ReshapeStrategy::Optimize,
            };
            let cfg = PipelineConfig {
                q,
                lanes: 8,
                parallel: crate::pipeline::codec::default_parallelism(),
                reshape,
                layout: pipeline::StreamLayout::V1,
            };
            let (container, stats) = pipeline::compress_quantized(&symbols, params, &cfg)?;
            plan.get_or_insert(stats.n_rows);
            enc.add(t0.elapsed_ms());
            payload.add(container.len() as f64);
            let t1 = Stopwatch::new();
            let (dec_syms, dec_params) = pipeline::decompress_to_symbols(
                &container,
                crate::pipeline::codec::default_parallelism(),
            )?;
            dec.add(t1.elapsed_ms());
            let logits = exec.run_tail(&dec_syms, &dec_params)?;
            if score_choices(&logits, task, item) == item.correct {
                correct += 1;
            }
        }
        rows.push(LmRow {
            task: task_name.to_string(),
            q: Some(q),
            accuracy: correct as f64 / n as f64,
            mean_payload_bytes: payload.mean(),
            t_comm_ms: channel.comm_latency_ms(payload.mean() as usize),
            enc_ms: enc,
            dec_ms: dec,
        });
    }
    Ok(rows)
}
