//! Table-3 driver: LM multiple-choice sweep.
//!
//! Per (model, task, Q): accuracy, `T_comm(Ñ)` under the ε-outage
//! channel, mean container size, and encode/decode timing — the exact
//! columns of Table 3, with the baseline row using the raw float path.
//!
//! The driver is dtype-generic: with [`Dtype::Bf16`] (or `F16`) it
//! simulates the Llama2-style half-precision deployment — the head's
//! hidden states are narrowed to the wire dtype once (standing in for a
//! model that computes in bf16), then compressed through the zero-copy
//! [`pipeline::compress_tensor`] path (conversion fused into quantize;
//! no intermediate f32 `Vec`) and shipped with the dtype tag the cloud
//! decoder sniffs. The baseline row's raw payload shrinks accordingly
//! (2 bytes/element instead of 4).

use crate::channel::OutageChannel;
use crate::data::{lm_tasks::score_choices, McTask};
use crate::error::Result;
use crate::pipeline::{self, PipelineConfig, ReshapeStrategy, TensorRef};
use crate::runtime::LmSplitExec;
use crate::tensor::{self, Dtype};
use crate::util::stats::Summary;
use crate::util::timer::Stopwatch;

/// One Table-3 row.
#[derive(Debug, Clone)]
pub struct LmRow {
    /// Task id.
    pub task: String,
    /// Bit-width; `None` = uncompressed baseline.
    pub q: Option<u8>,
    /// Element type the features crossed the link as.
    pub dtype: Dtype,
    /// Multiple-choice accuracy.
    pub accuracy: f64,
    /// Mean payload bytes per item.
    pub mean_payload_bytes: f64,
    /// Deterministic ε-outage communication latency for the mean payload.
    pub t_comm_ms: f64,
    /// Encode timing summary (head + pipeline), ms.
    pub enc_ms: Summary,
    /// Decode timing summary, ms.
    pub dec_ms: Summary,
}


/// Evaluate one task at the baseline and each Q, shipping features of
/// `dtype` over the simulated link.
pub fn lm_task_sweep(
    exec: &LmSplitExec,
    task: &McTask,
    task_name: &str,
    qs: &[u8],
    n_items: usize,
    channel: &OutageChannel,
    dtype: Dtype,
) -> Result<Vec<LmRow>> {
    let n = n_items.min(task.items.len()).max(1);
    let mut rows = Vec::new();

    // Baseline (raw hidden states of `dtype` over the link). For
    // half-precision the hidden states are narrowed — edge-side work,
    // inside the enc window — and then widened back for the tail (the
    // cloud's job in the real deployment, so *outside* the enc window);
    // the accuracy column thereby reflects the same rounding the
    // claimed wire bytes imply.
    {
        let mut correct = 0usize;
        let mut payload = Summary::new();
        let mut enc = Summary::new();
        for item in task.items.iter().take(n) {
            let tokens = task.item_batch(item);
            let t0 = Stopwatch::new();
            let mut hidden = exec.run_head_raw(&tokens)?;
            let bits = dtype.is_half().then(|| tensor::narrow_to_half_bits(&hidden, dtype));
            enc.add(t0.elapsed_ms());
            if let Some(bits) = &bits {
                hidden = TensorRef::from_half_bits(dtype, bits).to_f32_vec();
            }
            payload.add((hidden.len() * dtype.size_bytes()) as f64);
            let logits = exec.run_tail_raw(&hidden)?;
            if score_choices(&logits, task, item) == item.correct {
                correct += 1;
            }
        }
        rows.push(LmRow {
            task: task_name.to_string(),
            q: None,
            dtype,
            accuracy: correct as f64 / n as f64,
            mean_payload_bytes: payload.mean(),
            t_comm_ms: channel.comm_latency_ms(payload.mean() as usize),
            enc_ms: enc,
            dec_ms: Summary::new(),
        });
    }

    for &q in qs {
        let mut correct = 0usize;
        let mut payload = Summary::new();
        let mut enc = Summary::new();
        let mut dec = Summary::new();
        let mut plan: Option<usize> = None;
        for item in task.items.iter().take(n) {
            let tokens = task.item_batch(item);
            let t0 = Stopwatch::new();
            let reshape = match plan {
                Some(np) => ReshapeStrategy::Fixed(np),
                None => ReshapeStrategy::Optimize,
            };
            let cfg = PipelineConfig {
                q,
                lanes: 8,
                parallel: crate::pipeline::codec::default_parallelism(),
                reshape,
                layout: pipeline::StreamLayout::V1,
            };
            let (container, stats) = if dtype == Dtype::F32 {
                // Artifact hot path: the head emits AIQ symbols.
                let (symbols, params) = exec.run_head(&tokens, q)?;
                pipeline::compress_quantized(&symbols, params, &cfg)?
            } else {
                // Half-precision path: narrow the hidden states to the
                // wire dtype, then the zero-copy dtype-generic entry
                // point (quantize fuses the half→f32 conversion).
                let hidden = exec.run_head_raw(&tokens)?;
                let bits = tensor::narrow_to_half_bits(&hidden, dtype);
                pipeline::compress_tensor(TensorRef::from_half_bits(dtype, &bits), &cfg)?
            };
            plan.get_or_insert(stats.n_rows);
            enc.add(t0.elapsed_ms());
            payload.add(container.len() as f64);
            let t1 = Stopwatch::new();
            let (dec_syms, dec_params) = pipeline::decompress_to_symbols(&container)?;
            dec.add(t1.elapsed_ms());
            let logits = exec.run_tail(&dec_syms, &dec_params)?;
            if score_choices(&logits, task, item) == item.correct {
                correct += 1;
            }
        }
        rows.push(LmRow {
            task: task_name.to_string(),
            q: Some(q),
            dtype,
            accuracy: correct as f64 / n as f64,
            mean_payload_bytes: payload.mean(),
            t_comm_ms: channel.comm_latency_ms(payload.mean() as usize),
            enc_ms: enc,
            dec_ms: dec,
        });
    }
    Ok(rows)
}
