//! Drivers for Fig. 2 (reshape histograms), Fig. 3 (enc/dec latency vs
//! N) and Fig. 4 (cost model vs measured size, Ñ vs N*).

use crate::error::Result;
use crate::pipeline::{self, PipelineConfig, ReshapeStrategy};
use crate::quant::fit_and_quantize;
use crate::reshape::{
    self,
    cost::LatencyTerms,
    optimizer::{exhaustive_search, OptimizerConfig},
};
use crate::sparse::ModCsr;
use crate::util::stats;
use crate::util::timer::{measure, Measurement};

/// Fig. 2 row: one reshape configuration of the same tensor.
#[derive(Debug, Clone)]
pub struct ReshapeHistRow {
    /// Rows N.
    pub n: usize,
    /// Columns K.
    pub k: usize,
    /// Entropy of the concatenated stream D, bits/symbol.
    pub entropy: f64,
    /// Actual compressed container size, bytes.
    pub compressed_bytes: usize,
    /// Frequency histogram of D (truncated to the alphabet).
    pub histogram: Vec<u64>,
}

/// Fig. 2: evaluate explicit reshape configurations at a fixed Q.
pub fn reshape_histogram(data: &[f32], q: u8, ns: &[usize]) -> Result<Vec<ReshapeHistRow>> {
    let (params, symbols) = fit_and_quantize(q, data)?;
    let mut rows = Vec::new();
    for &n in ns {
        let k = symbols.len() / n;
        let csr = ModCsr::encode(&symbols, n, k, params.zero_symbol())?;
        let d = csr.concat();
        let alphabet = csr.concat_alphabet(params.alphabet());
        let freqs = stats::histogram(&d, alphabet);
        let entropy = stats::shannon_entropy(&freqs);
        let cfg = PipelineConfig {
            q,
            lanes: 8,
            parallel: pipeline::codec::default_parallelism(),
            reshape: ReshapeStrategy::Fixed(n),
            layout: pipeline::StreamLayout::V1,
        };
        let (bytes, _) = pipeline::compress(data, &cfg)?;
        rows.push(ReshapeHistRow {
            n,
            k,
            entropy,
            compressed_bytes: bytes.len(),
            histogram: freqs,
        });
    }
    Ok(rows)
}

/// Fig. 3 row: encode/decode latency at one reshape dimension.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    /// Reshape rows N.
    pub n: usize,
    /// Encode timing (ms).
    pub enc: Measurement,
    /// Decode timing (ms).
    pub dec: Measurement,
}

/// Fig. 3: sweep N over divisors, measuring steady-state (Fixed-N)
/// encode and decode latency.
pub fn latency_vs_n(data: &[f32], q: u8, trials: usize) -> Result<Vec<LatencyRow>> {
    let (params, symbols) = fit_and_quantize(q, data)?;
    let t = symbols.len();
    let cfg0 = OptimizerConfig::paper(q);
    let domain = reshape::optimizer::candidate_domain(t, &cfg0);
    // Sample up to ~12 Ns spread across the domain.
    let step = (domain.len() / 12).max(1);
    let mut rows = Vec::new();
    for &n in domain.iter().step_by(step) {
        let cfg = PipelineConfig {
            q,
            lanes: 8,
            parallel: pipeline::codec::default_parallelism(),
            reshape: ReshapeStrategy::Fixed(n),
            layout: pipeline::StreamLayout::V1,
        };
        let (bytes, _) = pipeline::compress_quantized(&symbols, params, &cfg)?;
        let enc = measure(1, trials, || {
            pipeline::compress_quantized(&symbols, params, &cfg).expect("enc")
        });
        let dec = measure(1, trials, || pipeline::decompress(&bytes).expect("dec"));
        rows.push(LatencyRow { n, enc, dec });
    }
    Ok(rows)
}

/// Fig. 4 output for one Q.
#[derive(Debug, Clone)]
pub struct CostSweep {
    /// Bit-width.
    pub q: u8,
    /// Per-candidate (N, model-predicted bytes, actual container bytes).
    pub points: Vec<(usize, f64, usize)>,
    /// Algorithm-1 selection Ñ.
    pub n_tilde: usize,
    /// Exhaustive optimum N* (within the constrained domain).
    pub n_star: usize,
    /// Actual bytes at Ñ.
    pub bytes_at_tilde: usize,
    /// Actual bytes at N*.
    pub bytes_at_star: usize,
    /// Candidates Algorithm 1 evaluated before stopping.
    pub evaluated: usize,
    /// Size of the constrained domain.
    pub domain_size: usize,
}

impl CostSweep {
    /// Relative size gap of the approximate choice vs the oracle.
    pub fn gap(&self) -> f64 {
        self.bytes_at_tilde as f64 / self.bytes_at_star.max(1) as f64 - 1.0
    }
}

/// Fig. 4: for each Q, trace the cost model over the constrained domain
/// and compare Algorithm 1's Ñ with the exhaustive N*.
pub fn cost_model_sweep(data: &[f32], qs: &[u8]) -> Result<Vec<CostSweep>> {
    let mut out = Vec::new();
    for &q in qs {
        let (params, symbols) = fit_and_quantize(q, data)?;
        let ocfg = OptimizerConfig::paper(q);
        let approx = reshape::optimize(&symbols, params.zero_symbol(), &ocfg)?;
        let oracle = exhaustive_search(&symbols, params.zero_symbol(), &ocfg, true)?;

        let mut points = Vec::new();
        // Sample the oracle trace (it covers the full domain).
        let step = (oracle.trace.len() / 24).max(1);
        for c in oracle.trace.iter().step_by(step) {
            let cfg = PipelineConfig {
                q,
                lanes: 8,
                parallel: pipeline::codec::default_parallelism(),
                reshape: ReshapeStrategy::Fixed(c.n),
                layout: pipeline::StreamLayout::V1,
            };
            let (bytes, _) = pipeline::compress_quantized(&symbols, params, &cfg)?;
            points.push((c.n, c.predicted_bytes(), bytes.len()));
        }
        let actual_at = |n: usize| -> Result<usize> {
            let cfg = PipelineConfig {
                q,
                lanes: 8,
                parallel: pipeline::codec::default_parallelism(),
                reshape: ReshapeStrategy::Fixed(n),
                layout: pipeline::StreamLayout::V1,
            };
            Ok(pipeline::compress_quantized(&symbols, params, &cfg)?.0.len())
        };
        out.push(CostSweep {
            q,
            points,
            n_tilde: approx.best.n,
            n_star: oracle.best.n,
            bytes_at_tilde: actual_at(approx.best.n)?,
            bytes_at_star: actual_at(oracle.best.n)?,
            evaluated: approx.evaluated,
            domain_size: oracle.domain_size,
        });
    }
    Ok(out)
}

/// Latency terms measured for Eq. 7 completeness (α·T_enc / α·T_dec):
/// returns (mean enc ms, mean dec ms) at the optimizer's chosen N.
pub fn measured_latency_terms(data: &[f32], q: u8) -> Result<LatencyTerms> {
    let cfg = PipelineConfig::paper(q);
    let (bytes, stats) = pipeline::compress(data, &cfg)?;
    let fixed = PipelineConfig {
        reshape: ReshapeStrategy::Fixed(stats.n_rows),
        ..cfg
    };
    let enc = measure(1, 5, || pipeline::compress(data, &fixed).expect("enc"));
    let dec = measure(1, 5, || pipeline::decompress(&bytes).expect("dec"));
    Ok(LatencyTerms {
        alpha_enc: 1.0,
        alpha_dec: 1.0,
        t_enc: enc.mean_ms(),
        t_dec: dec.mean_ms(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::fixtures::synthetic_feature;

    fn fixture() -> Vec<f32> {
        synthetic_feature(11, 64, 14, 14, 0.35)
    }

    #[test]
    fn fig2_more_rows_lower_entropy_smaller_size() {
        // The Fig. 2 trend with the paper's own K ladder (128, 56, 16, 7):
        // growing N (shrinking K) skews the distribution, dropping the
        // entropy monotonically; the compressed size bottoms out in the
        // constrained-domain region (K ≤ 2^Q) rather than at the first
        // configuration.
        let data = fixture();
        let t = data.len(); // 12544 = 2^8 · 7^2
        let ns = vec![t / 128, t / 56, t / 16, t / 7];
        assert!(ns.iter().all(|n| t % n == 0));
        let rows = reshape_histogram(&data, 4, &ns).unwrap();
        for w in rows.windows(2) {
            assert!(
                w[1].entropy < w[0].entropy,
                "entropy not decreasing: {} -> {}",
                w[0].entropy,
                w[1].entropy
            );
        }
        let first = rows[0].compressed_bytes;
        let best_late = rows[2..].iter().map(|r| r.compressed_bytes).min().unwrap();
        assert!(best_late < first, "best {best_late} !< first {first}");
    }

    #[test]
    fn fig4_gap_small_and_pruning_real() {
        let data = fixture();
        let sweeps = cost_model_sweep(&data, &[4]).unwrap();
        let s = &sweeps[0];
        assert!(s.gap() <= 0.05, "gap {}", s.gap());
        assert!(s.evaluated <= s.domain_size);
        // Model tracks actual size within 20% on every sampled point.
        for &(n, pred, actual) in &s.points {
            let ratio = pred / actual as f64;
            assert!((0.7..1.3).contains(&ratio), "N={n}: pred {pred} vs {actual}");
        }
    }
}
