//! Table-1 driver: codec comparison (size / encode ms / decode ms).

use crate::baselines::{self, TensorCodec};
use crate::error::Result;
use crate::pipeline::{self, PipelineConfig};
use crate::util::timer::{measure, Measurement};

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct CodecRow {
    /// Codec label.
    pub name: String,
    /// Compressed bytes.
    pub size_bytes: usize,
    /// Encode timing.
    pub enc: Measurement,
    /// Decode timing.
    pub dec: Measurement,
    /// Whether decode(encode(x)) is bit-exact.
    pub lossless: bool,
}

impl CodecRow {
    /// Size in KB (paper units).
    pub fn size_kb(&self) -> f64 {
        self.size_bytes as f64 / 1000.0
    }
}

fn bench_codec(
    codec: &(dyn TensorCodec + Send + Sync),
    data: &[f32],
    warmup: usize,
    trials: usize,
) -> Result<CodecRow> {
    let bytes = codec.encode(data)?;
    let enc = measure(warmup, trials, || codec.encode(data).expect("encode"));
    let dec = measure(warmup, trials, || codec.decode(&bytes).expect("decode"));
    Ok(CodecRow {
        name: codec.name().to_string(),
        size_bytes: bytes.len(),
        enc,
        dec,
        lossless: codec.lossless(),
    })
}

/// Run the full Table-1 comparison over one IF tensor.
///
/// Rows: E-1 binary, E-2 tANS, E-3 DietGPU-like, lz77, byte-rans, then
/// Ours at each requested Q (v1 scalar lanes, plus a 4-state v2-stream
/// variant for the ILP decode column).
pub fn codec_comparison(
    data: &[f32],
    ours_qs: &[u8],
    warmup: usize,
    trials: usize,
) -> Result<Vec<CodecRow>> {
    let mut rows = Vec::new();
    for codec in baselines::paper_baselines() {
        rows.push(bench_codec(codec.as_ref(), data, warmup, trials)?);
    }
    rows.push(bench_codec(&baselines::general::Lz77Codec, data, warmup, trials)?);
    rows.push(bench_codec(&baselines::general::ByteRansCodec, data, warmup, trials)?);
    for &q in ours_qs {
        let cfg = PipelineConfig::paper(q);
        let (bytes, _) = pipeline::compress(data, &cfg)?;
        // Steady-state encode: reuse the chosen reshape via a fresh
        // compress call (the optimizer early-stops quickly, and the plan
        // cache in the coordinator removes it entirely; here we measure
        // the library call as-is plus a Fixed-N steady-state variant).
        let (_, stats) = pipeline::compress(data, &cfg)?;
        let fixed_cfg = PipelineConfig {
            reshape: pipeline::ReshapeStrategy::Fixed(stats.n_rows),
            ..cfg.clone()
        };
        let enc = measure(warmup, trials, || {
            pipeline::compress(data, &fixed_cfg).expect("compress")
        });
        let dec = measure(warmup, trials, || {
            pipeline::decompress(&bytes).expect("decompress")
        });
        rows.push(CodecRow {
            name: format!("Ours (Q={q})"),
            size_bytes: bytes.len(),
            enc,
            dec,
            lossless: false,
        });

        // v2 multi-state streams: same pipeline with 4 interleaved rANS
        // states per lane (ILP decode). Size differs only by the extra
        // per-lane state words; the decode column is the point.
        let ms_cfg = fixed_cfg.clone().with_states(4);
        let (ms_bytes, _) = pipeline::compress(data, &ms_cfg)?;
        let enc = measure(warmup, trials, || {
            pipeline::compress(data, &ms_cfg).expect("compress")
        });
        let dec = measure(warmup, trials, || {
            pipeline::decompress(&ms_bytes).expect("decompress")
        });
        rows.push(CodecRow {
            name: format!("Ours (Q={q}, 4-state)"),
            size_bytes: ms_bytes.len(),
            enc,
            dec,
            lossless: false,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::fixtures::synthetic_feature;

    #[test]
    fn table1_shape_holds() {
        // The paper's ordering: ours < E-3 < E-1 in size; tANS encode is
        // orders of magnitude slower than ours; all sub-second here.
        let data = synthetic_feature(7, 64, 14, 14, 0.35);
        let rows = codec_comparison(&data, &[4], 0, 2).unwrap();
        let get = |needle: &str| {
            rows.iter()
                .find(|r| r.name.contains(needle))
                .unwrap_or_else(|| panic!("missing row {needle}"))
        };
        let binary = get("E-1");
        let tans = get("E-2");
        let diet = get("E-3");
        let ours = get("Ours");
        assert!(ours.size_bytes < diet.size_bytes);
        assert!(diet.size_bytes < binary.size_bytes);
        assert!(tans.size_bytes < binary.size_bytes);
        // NOTE: the paper reports tANS encode ~3 orders of magnitude
        // slower than its pipeline (979 ms). Our E-2 is a competent
        // FSE-style codec with 4096-state tables, so the *size* ordering
        // reproduces but that timing gap does not (documented in
        // EXPERIMENTS.md §Table 1); timing assertions are also too flaky
        // under CI contention to gate on.
    }
}
