//! Accuracy-sweep driver (Tables 2, 4, 5).
//!
//! Runs the *real* split pipeline per sample — head artifact → AIQ
//! symbols → CSR+rANS container → decode → tail artifact — entirely
//! in-process (no transport), which is exactly the computation the
//! served path performs minus the socket.

use crate::data::VisionSet;
use crate::error::Result;
use crate::pipeline::{self, PipelineConfig, ReshapeStrategy};
use crate::runtime::VisionSplitExec;
use crate::util::stats::Summary;

/// One (Q, accuracy) measurement.
#[derive(Debug, Clone)]
pub struct AccuracyPoint {
    /// Bit-width; `None` = uncompressed baseline.
    pub q: Option<u8>,
    /// Top-1 accuracy over the evaluated samples.
    pub accuracy: f64,
    /// Mean container bytes per sample (raw f32 bytes for baseline).
    pub mean_payload_bytes: f64,
    /// Encode-time summary (ms; head + pipeline).
    pub enc_ms: Summary,
    /// Decode-time summary (ms; container → symbols).
    pub dec_ms: Summary,
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = (f32::NEG_INFINITY, 0);
    for (i, &x) in xs.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1
}

/// Sweep quantization bit-widths over the first `n_samples` of `set`.
///
/// The returned vector starts with the uncompressed baseline
/// (`q == None`) followed by one point per entry of `qs`.
pub fn accuracy_sweep(
    exec: &VisionSplitExec,
    set: &VisionSet,
    qs: &[u8],
    n_samples: usize,
) -> Result<Vec<AccuracyPoint>> {
    let n = n_samples.min(set.len()).max(1);
    let classes = exec.entry.num_classes;
    let batch = exec.split.batch;
    assert_eq!(batch, 1, "accuracy sweep expects batch-1 artifacts");

    let mut out = Vec::new();

    // Baseline: raw float path.
    {
        let mut correct = 0usize;
        let mut payload = Summary::new();
        let mut enc = Summary::new();
        for i in 0..n {
            let (xs, ys) = set.batch(i, 1);
            let t0 = crate::util::timer::Stopwatch::new();
            let feat = exec.run_head_raw(&xs)?;
            enc.add(t0.elapsed_ms());
            payload.add((feat.len() * 4) as f64);
            let logits = exec.run_tail_raw(&feat)?;
            if argmax(&logits[0..classes]) == ys[0] as usize {
                correct += 1;
            }
        }
        out.push(AccuracyPoint {
            q: None,
            accuracy: correct as f64 / n as f64,
            mean_payload_bytes: payload.mean(),
            enc_ms: enc,
            dec_ms: Summary::new(),
        });
    }

    for &q in qs {
        let mut correct = 0usize;
        let mut payload = Summary::new();
        let mut enc = Summary::new();
        let mut dec = Summary::new();
        let mut plan: Option<usize> = None;
        for i in 0..n {
            let (xs, ys) = set.batch(i, 1);
            let t0 = crate::util::timer::Stopwatch::new();
            let (symbols, params) = exec.run_head(&xs, q)?;
            let reshape = match plan {
                Some(np) => ReshapeStrategy::Fixed(np),
                None => ReshapeStrategy::Optimize,
            };
            let cfg = PipelineConfig {
                q,
                lanes: 8,
                parallel: crate::pipeline::codec::default_parallelism(),
                reshape,
                layout: pipeline::StreamLayout::V1,
            };
            let (container, stats) = pipeline::compress_quantized(&symbols, params, &cfg)?;
            plan.get_or_insert(stats.n_rows);
            enc.add(t0.elapsed_ms());
            payload.add(container.len() as f64);

            let t1 = crate::util::timer::Stopwatch::new();
            let (dec_syms, dec_params) = pipeline::decompress_to_symbols(&container)?;
            dec.add(t1.elapsed_ms());
            let logits = exec.run_tail(&dec_syms, &dec_params)?;
            if argmax(&logits[0..classes]) == ys[0] as usize {
                correct += 1;
            }
        }
        out.push(AccuracyPoint {
            q: Some(q),
            accuracy: correct as f64 / n as f64,
            mean_payload_bytes: payload.mean(),
            enc_ms: enc,
            dec_ms: dec,
        });
    }
    Ok(out)
}
