//! Experiment input fixtures.
//!
//! Codec-level experiments (Table 1, Figs. 2–4) need a representative
//! intermediate-feature tensor. When artifacts exist, the fixture is a
//! *real* IF: the head of the configured model run on a test image.
//! Without artifacts (unit-test / early-dev settings), a synthetic
//! post-ReLU tensor with matched sparsity/skew stands in, and the
//! returned [`FixtureSource`] records which one was used.

use crate::data::VisionSet;
use crate::error::Result;
use crate::runtime::{Engine, ExecPool, Manifest, VisionSplitExec};
use crate::util::prng::Rng;
use std::sync::Arc;

/// Where a fixture tensor came from.
#[derive(Debug, Clone, PartialEq)]
pub enum FixtureSource {
    /// Real head output: (model name, split layer).
    Artifact(String, usize),
    /// Synthetic stand-in with the given seed.
    Synthetic(u64),
}

/// Synthetic post-ReLU IF with channel-skewed sparsity; the Fig. 2
/// reference shape `128×28×28` by default.
pub fn synthetic_feature(seed: u64, c: usize, h: usize, w: usize, density: f64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut out = vec![0.0f32; c * h * w];
    for ch in 0..c {
        let act = rng.next_f64();
        for i in 0..h * w {
            if rng.next_f64() < density * act * 2.0 {
                out[ch * h * w + i] = (rng.normal().abs() as f32) * (0.3 + act as f32);
            }
        }
    }
    out
}

/// Produce the experiment feature tensor.
///
/// Tries `artifacts_dir` first (head of `model` at SL`sl` on the first
/// test image); falls back to [`synthetic_feature`] when artifacts are
/// unavailable.
pub fn feature_tensor(
    artifacts_dir: &str,
    model: &str,
    sl: usize,
) -> Result<(Vec<f32>, FixtureSource)> {
    match try_artifact_feature(artifacts_dir, model, sl) {
        Ok(feat) => Ok((feat, FixtureSource::Artifact(model.to_string(), sl))),
        Err(_) => Ok((
            synthetic_feature(4242, 128, 28, 28, 0.35),
            FixtureSource::Synthetic(4242),
        )),
    }
}

fn try_artifact_feature(artifacts_dir: &str, model: &str, sl: usize) -> Result<Vec<f32>> {
    let manifest = Manifest::load(artifacts_dir)?;
    let engine = Arc::new(Engine::cpu()?);
    let pool = ExecPool::new(engine, artifacts_dir);
    let exec = VisionSplitExec::load(&pool, &manifest, model, sl, 1)?;
    let set = VisionSet::load(manifest.resolve(&exec.entry.test_data))?;
    let (xs, _) = set.batch(0, 1);
    exec.run_head_raw(&xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_feature_is_sparse_and_positive() {
        let f = synthetic_feature(1, 32, 14, 14, 0.35);
        let zeros = f.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > f.len() / 4, "{zeros}/{}", f.len());
        assert!(f.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn fallback_to_synthetic_without_artifacts() {
        let (f, src) = feature_tensor("/nonexistent", "resnet_mini_synth_a", 2).unwrap();
        assert_eq!(f.len(), 128 * 28 * 28);
        assert!(matches!(src, FixtureSource::Synthetic(_)));
    }
}
