//! Experiment drivers shared by `benches/` and `examples/`.
//!
//! Each paper table/figure has a driver here returning structured rows;
//! the bench binaries format them. Keeping the logic in the library
//! means integration tests can assert on the *shape* of every result
//! (who wins, direction of trends) without parsing bench output.

pub mod codecs;
pub mod fixtures;
pub mod lm;
pub mod reshape_exp;
pub mod vision;

pub use codecs::{codec_comparison, CodecRow};
pub use fixtures::{feature_tensor, FixtureSource};
pub use lm::{lm_task_sweep, LmRow};
pub use reshape_exp::{cost_model_sweep, latency_vs_n, reshape_histogram, CostSweep};
pub use vision::{accuracy_sweep, AccuracyPoint};
