//! tANS stream codec built on [`super::tables::TansTables`].
//!
//! Encoding walks symbols forward, buffering the per-symbol bit chunks;
//! the chunks are then written in reverse so the decoder (which pops
//! symbols LIFO) reads the bitstream strictly forward. The per-call
//! table build — `O(L + m)` plus the spread walk — is charged to
//! `encode`, matching how the paper's E-2 baseline is measured (tables
//! cannot be amortized across tensors whose statistics change).
//!
//! Stream layout: `[varint final_state] [varint bit_len] [bit payload]`.

use crate::error::{Error, Result};
use crate::rans::freq::FreqTable;
use crate::util::bitio::{BitReader, BitWriter};
use crate::util::varint;

use super::tables::TansTables;

/// Encode `symbols` with freshly built tANS tables for `table`.
pub fn encode(symbols: &[u32], table: &FreqTable) -> Result<Vec<u8>> {
    let tables = TansTables::build(table)?;
    let mut state = 0u32;
    // Buffer (bits, nb) per symbol, then emit in reverse.
    let mut chunks: Vec<(u32, u8)> = Vec::with_capacity(symbols.len());
    for &sym in symbols {
        if sym > u16::MAX as u32 {
            return Err(Error::codec(format!("symbol {sym} exceeds u16")));
        }
        let (bits, nb, next) = tables.encode_step(state, sym as u16)?;
        chunks.push((bits, nb));
        state = next;
    }
    let mut w = BitWriter::new();
    for &(bits, nb) in chunks.iter().rev() {
        w.write_bits(bits as u64, nb as u32);
    }
    let bit_len = w.bit_len();
    let payload = w.finish();

    let mut out = Vec::with_capacity(payload.len() + 10);
    varint::write_u64(&mut out, state as u64);
    varint::write_usize(&mut out, bit_len);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Decode `count` symbols encoded by [`encode`] under the same table.
pub fn decode(bytes: &[u8], count: usize, table: &FreqTable) -> Result<Vec<u32>> {
    let tables = TansTables::build(table)?;
    let mut pos = 0usize;
    let state = varint::read_u64(bytes, &mut pos)?;
    if state >= tables.table_size as u64 {
        return Err(Error::corrupt("tANS state out of range"));
    }
    let bit_len = varint::read_usize(bytes, &mut pos)?;
    let payload = &bytes[pos..];
    if bit_len > payload.len() * 8 {
        return Err(Error::corrupt("tANS bitstream truncated"));
    }
    let mut reader = BitReader::new(payload);
    let mut state = state as u32;
    // Symbols pop in reverse encode order.
    let mut out = vec![0u32; count];
    for slot in out.iter_mut().rev() {
        let e = tables.decode_step(state);
        *slot = e.symbol as u32;
        let bits = reader
            .read_bits(e.nb_bits as u32)
            .ok_or_else(|| Error::corrupt("tANS bitstream exhausted"))? as u32;
        state = e.new_state_base + bits;
    }
    if state != 0 {
        return Err(Error::corrupt("tANS final state mismatch"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_distributions() {
        let mut rng = Rng::new(31);
        for alphabet in [2usize, 16, 64, 256] {
            for len in [0usize, 1, 100, 20_000] {
                let symbols: Vec<u32> =
                    (0..len).map(|_| rng.zipf(alphabet, 1.4) as u32).collect();
                let table = FreqTable::from_symbols(&symbols, alphabet);
                let bytes = encode(&symbols, &table).unwrap();
                let back = decode(&bytes, symbols.len(), &table).unwrap();
                assert_eq!(back, symbols, "alphabet {alphabet} len {len}");
            }
        }
    }

    #[test]
    fn size_competitive_with_entropy() {
        let mut rng = Rng::new(32);
        let symbols: Vec<u32> = (0..50_000).map(|_| rng.zipf(32, 1.5) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 32);
        let bytes = encode(&symbols, &table).unwrap();
        let freqs = crate::util::stats::histogram(&symbols, 32);
        let bound = crate::util::stats::entropy_bits(&freqs) / 8.0;
        assert!(
            (bytes.len() as f64) < bound * 1.10 + 16.0,
            "tANS {} bytes vs entropy bound {bound}",
            bytes.len()
        );
    }

    #[test]
    fn tans_and_rans_sizes_comparable() {
        let mut rng = Rng::new(33);
        let symbols: Vec<u32> = (0..30_000).map(|_| rng.zipf(64, 1.2) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 64);
        let t = encode(&symbols, &table).unwrap().len() as f64;
        let r = crate::rans::encode(&symbols, &table).unwrap().len() as f64;
        assert!((t / r - 1.0).abs() < 0.05, "tANS {t} vs rANS {r}");
    }

    #[test]
    fn corrupt_stream_rejected() {
        let mut rng = Rng::new(34);
        let symbols: Vec<u32> = (0..1000).map(|_| rng.zipf(16, 1.1) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, 16);
        let bytes = encode(&symbols, &table).unwrap();
        assert!(decode(&bytes[..bytes.len() / 2], symbols.len(), &table).is_err());
        let mut garbled = bytes.clone();
        let last = garbled.len() - 1;
        garbled[last] ^= 0xFF;
        match decode(&garbled, symbols.len(), &table) {
            Err(_) => {}
            Ok(dec) => assert_ne!(dec, symbols),
        }
    }
}
