//! Table-based ANS (tANS / FSE) — the paper's E-2 baseline.
//!
//! tANS drives encoding and decoding from precomputed state-transition
//! tables over `L = 2^R` states. The tables must be rebuilt from the
//! symbol statistics of every tensor (there is no stationary model in
//! split computing), which is exactly the overhead the paper's Table 1
//! attributes to E-2: competitive compressed sizes but encoding three
//! orders of magnitude slower than the streaming rANS pipeline.

pub mod codec;
pub mod tables;

pub use codec::{decode as tans_decode, encode as tans_encode};
pub use tables::TansTables;
