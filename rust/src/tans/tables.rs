//! tANS state-table construction (FSE-style).
//!
//! States are `t ∈ [0, L)` standing for the ANS state `x = t + L`.
//! Symbols are spread over the state table with the coprime-step walk
//! used by FSE; the decode table is built first and the encode table is
//! derived as its exact inverse, so the pair is consistent by
//! construction.

use crate::error::{Error, Result};
use crate::rans::freq::{FreqTable, SCALE, SCALE_BITS};

/// One decode-table entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeEntry {
    /// Decoded symbol.
    pub symbol: u16,
    /// Bits to pull from the stream after emitting `symbol`.
    pub nb_bits: u8,
    /// Next-state base; next state = base + read_bits(nb_bits).
    pub new_state_base: u32,
}

/// Full encode+decode tables for one frequency distribution.
#[derive(Debug, Clone)]
pub struct TansTables {
    /// `L = 2^R` (we reuse the rANS precision, R = SCALE_BITS).
    pub table_size: u32,
    /// Decode table, `L` entries.
    pub decode: Vec<DecodeEntry>,
    /// Per-symbol start offset into `encode_states`.
    sym_offset: Vec<u32>,
    /// Per-symbol normalized frequency (copied from the table).
    sym_freq: Vec<u32>,
    /// For symbol `s` and sub-state `x ∈ [freq, 2·freq)`:
    /// `encode_states[sym_offset[s] + (x − freq)]` is the table state `t`
    /// whose decode entry yields `(s, x)`.
    encode_states: Vec<u32>,
}

impl TansTables {
    /// Build tables from a normalized frequency table.
    pub fn build(freq: &FreqTable) -> Result<Self> {
        let l = SCALE;
        let m = freq.alphabet();
        // Spread symbols: classic FSE step keeps the walk coprime with L.
        let step = (l >> 1) + (l >> 3) + 3;
        let mask = l - 1;
        let mut spread = vec![0u16; l as usize];
        let mut pos: u32 = 0;
        for s in 0..m {
            for _ in 0..freq.freq_of(s as u32) {
                spread[pos as usize] = s as u16;
                pos = (pos + step) & mask;
            }
        }
        if pos != 0 {
            return Err(Error::codec("tANS spread did not complete a full cycle"));
        }

        // Decode table + inverse (encode) table in one pass.
        let mut counter: Vec<u32> = (0..m).map(|s| freq.freq_of(s as u32)).collect();
        let mut sym_offset = vec![0u32; m];
        let mut acc = 0u32;
        for s in 0..m {
            sym_offset[s] = acc;
            acc += freq.freq_of(s as u32);
        }
        debug_assert_eq!(acc, l);
        let mut encode_states = vec![0u32; l as usize];
        let mut decode = Vec::with_capacity(l as usize);
        for t in 0..l {
            let s = spread[t as usize] as usize;
            let x = counter[s]; // sub-state in [freq, 2*freq)
            counter[s] += 1;
            let nb_bits = (SCALE_BITS - (31 - x.leading_zeros())) as u8;
            let new_state_base = (x << nb_bits) - l;
            decode.push(DecodeEntry { symbol: s as u16, nb_bits, new_state_base });
            let f = freq.freq_of(s as u32);
            encode_states[(sym_offset[s] + (x - f)) as usize] = t;
        }

        Ok(TansTables {
            table_size: l,
            decode,
            sym_offset,
            sym_freq: (0..m).map(|s| freq.freq_of(s as u32)).collect(),
            encode_states,
        })
    }

    /// Alphabet size.
    pub fn alphabet(&self) -> usize {
        self.sym_freq.len()
    }

    /// Encode step: from table state `t` (x = t + L), encode `sym`.
    /// Returns `(bits_value, nb_bits, next_state)`.
    #[inline]
    pub fn encode_step(&self, t: u32, sym: u16) -> Result<(u32, u8, u32)> {
        let s = sym as usize;
        if s >= self.sym_freq.len() {
            return Err(Error::codec(format!("symbol {sym} outside alphabet")));
        }
        let f = self.sym_freq[s];
        if f == 0 {
            return Err(Error::codec(format!("symbol {sym} has zero frequency")));
        }
        let x = t + self.table_size;
        // Emit bits until x >> nb lands in [f, 2f).
        let mut nb = 0u8;
        while (x >> nb) >= 2 * f {
            nb += 1;
        }
        let bits = x & ((1u32 << nb) - 1);
        let sub = (x >> nb) - f;
        let next = self.encode_states[(self.sym_offset[s] + sub) as usize];
        Ok((bits, nb, next))
    }

    /// Decode step: from table state `t`, return `(symbol, nb_bits, base)`;
    /// caller supplies the next state as `base + bits`.
    #[inline]
    pub fn decode_step(&self, t: u32) -> DecodeEntry {
        self.decode[t as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn table_for(seed: u64, alphabet: usize) -> (FreqTable, TansTables) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..50_000).map(|_| rng.zipf(alphabet, 1.3) as u32).collect();
        let f = FreqTable::from_symbols(&symbols, alphabet);
        let t = TansTables::build(&f).unwrap();
        (f, t)
    }

    #[test]
    fn decode_table_covers_all_states() {
        let (freq, tables) = table_for(1, 32);
        // Each symbol appears exactly freq times in the decode table.
        let mut counts = vec![0u32; 32];
        for e in &tables.decode {
            counts[e.symbol as usize] += 1;
        }
        for s in 0..32u32 {
            assert_eq!(counts[s as usize], freq.freq_of(s));
        }
    }

    #[test]
    fn encode_decode_steps_are_inverse() {
        let (_, tables) = table_for(2, 64);
        let mut rng = Rng::new(7);
        let mut t = 0u32;
        let mut stack = Vec::new();
        // Walk 10k random encodable symbols forward.
        for _ in 0..10_000 {
            let sym = loop {
                let s = rng.below(64) as u16;
                if tables.sym_freq[s as usize] > 0 {
                    break s;
                }
            };
            let (bits, nb, next) = tables.encode_step(t, sym).unwrap();
            stack.push((t, sym, bits, nb));
            t = next;
        }
        // Walk back via decode steps.
        for (prev_t, sym, bits, nb) in stack.into_iter().rev() {
            let e = tables.decode_step(t);
            assert_eq!(e.symbol, sym);
            assert_eq!(e.nb_bits, nb);
            t = e.new_state_base + bits;
            assert_eq!(t, prev_t);
        }
    }

    #[test]
    fn new_state_base_in_range() {
        let (_, tables) = table_for(3, 16);
        for e in &tables.decode {
            let max_next = e.new_state_base + ((1u32 << e.nb_bits) - 1);
            assert!(max_next < tables.table_size);
        }
    }

    #[test]
    fn zero_freq_symbol_rejected_on_encode() {
        let f = FreqTable::from_symbols(&[0, 0, 1], 4);
        let tables = TansTables::build(&f).unwrap();
        assert!(tables.encode_step(0, 3).is_err());
    }
}
