//! Persistent chunk-parallel compression engine.
//!
//! The original hot path spawned scoped threads for every
//! `compress`/`decompress` call — roughly a millisecond of pure fan-out
//! overhead per request on a loaded host (measured in
//! `benches/perf_hotpath.rs`), paid millions of times under serving
//! traffic. The engine amortizes that cost:
//!
//! * a **long-lived worker pool** ([`crate::util::threadpool`]) created
//!   once and shared by every caller — coordinator nodes, the batcher,
//!   and the plain [`crate::pipeline`] entry points all dispatch onto
//!   the same workers instead of each oversubscribing the host;
//! * a **reshape-plan cache** ([`PlanCache`]) so Algorithm 1 runs once
//!   per `(T, Q)` tensor shape, not per request;
//! * **chunk-parallel encode/decode**: the concatenated stream is split
//!   into per-lane spans ([`crate::rans::interleaved::lane_spans`]) and
//!   dispatched to pooled workers.
//!
//! Two container formats are supported. [`ContainerFormat::V1`] emits
//! bitstreams **byte-identical** to the pre-engine serial pipeline for
//! the same [`PipelineConfig`] (the framing is shared via
//! [`crate::rans::interleaved::assemble_stream_with_layout`], so this
//! holds by construction). [`ContainerFormat::ChunkedV2`] adds per-chunk
//! framing and checksums for streaming/partial decode ([`chunked`]).
//! The decoder sniffs the magic and accepts both.
//!
//! Orthogonally, [`PipelineConfig::layout`]
//! ([`crate::rans::StreamLayout`]) selects the per-lane stream layout
//! inside the v1 container's payload: v1 scalar lanes (default) or v2
//! multi-state lanes (2–8 interleaved rANS states per lane for
//! ILP/SIMD decode — 4- and 8-state lanes pick up the vectorized
//! gather decoder through the cross-ISA backend seam: SSE4.1/AVX2 on
//! x86_64, NEON on aarch64). Decoders need no knob — the stream is
//! self-describing. The [`autotune`] module picks the `lanes × states`
//! shape per machine with a one-shot microbenchmark when the config
//! doesn't pin it.
//!
//! The public codec surface is **dtype-generic and zero-copy**:
//! [`Engine::compress_tensor`] takes a borrowed
//! [`crate::tensor::TensorRef`] (f32, f16, or bf16) and fuses the
//! half→f32 conversion into the quantize passes, so half-precision LM
//! features never materialize an `f32` copy; [`Engine::decompress_into`]
//! dequantizes straight into a caller-owned
//! [`crate::tensor::TensorMut`] of the container's (sniffed) dtype,
//! removing the per-request output allocation. Decode-side threading is
//! config-carried ([`EngineConfig::decode_parallel`]) instead of a
//! `parallel: bool` argument on every call.

pub mod autotune;
pub mod chunked;
pub mod plan_cache;

pub use chunked::{Chunk, ChunkedContainer};
pub use plan_cache::PlanCache;

use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};
use crate::pipeline::codec::{CompressStats, DecodeInfo, PipelineConfig, ReshapeStrategy};
use crate::pipeline::container::{self, Container, ContainerRef};
use crate::quant::{self, QuantParams};
use crate::rans::freq::FreqTable;
use crate::rans::interleaved::{
    assemble_stream_with_layout, lane_spans, parse_stream_spans, MAX_LANES,
};
use crate::rans::multistate::{decode_multistate, encode_multistate, supported_states};
use crate::reshape::{self, optimizer::OptimizerConfig};
use crate::sparse::ModCsr;
use crate::tensor::{Dtype, TensorMut, TensorRef};
use crate::util::stats;
use crate::util::threadpool::ThreadPool;

/// Which container layout the engine emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerFormat {
    /// The v1 single-payload container — byte-identical to the
    /// pre-engine serial pipeline.
    V1,
    /// The v2 chunked container with per-chunk checksums
    /// (streaming/partial decode).
    ChunkedV2,
}

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads in the pool. `0` sizes to the machine
    /// (`available_parallelism`, minimum 1).
    pub workers: usize,
    /// Output container format (default [`ContainerFormat::V1`]).
    pub format: ContainerFormat,
    /// Target symbols per chunk for [`ContainerFormat::ChunkedV2`].
    pub chunk_symbols: usize,
    /// Decode-side lane/chunk threading. The loose `parallel: bool`
    /// that used to ride on every `decompress*` call now lives here:
    /// `None` (the default) adapts to the pool size — threaded exactly
    /// when the pool has more than one worker — while `Some(b)` forces
    /// it (tests and latency-sensitive single-request paths).
    pub decode_parallel: Option<bool>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 0,
            format: ContainerFormat::V1,
            chunk_symbols: 1 << 16,
            decode_parallel: None,
        }
    }
}

/// The persistent compression engine.
///
/// Construction is cheap relative to its lifetime but not free (it
/// spawns the worker threads); create one per process — or just use
/// [`Engine::shared`] — and clone the `Arc` everywhere a codec handle is
/// needed.
pub struct Engine {
    pool: ThreadPool,
    plans: PlanCache,
    format: ContainerFormat,
    chunk_symbols: usize,
    decode_parallel: bool,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::new(EngineConfig::default())
    }
}

impl Engine {
    /// Build an engine with `cfg`.
    pub fn new(cfg: EngineConfig) -> Self {
        let workers = if cfg.workers == 0 { Self::auto_pool_size() } else { cfg.workers };
        Engine {
            pool: ThreadPool::new(workers),
            plans: PlanCache::new(),
            format: cfg.format,
            chunk_symbols: cfg.chunk_symbols.max(1),
            decode_parallel: cfg.decode_parallel.unwrap_or(workers > 1),
        }
    }

    /// Pool size an auto-sized engine (`workers: 0`) gets on this
    /// machine. This is the single definition of the machine-sizing
    /// heuristic; it does **not** construct a pool, so pure queries
    /// like [`crate::pipeline::codec::default_parallelism`] can consult
    /// it without spawning the shared engine's workers as a side
    /// effect.
    pub fn auto_pool_size() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// The process-wide default engine (machine-sized pool, v1 format).
    ///
    /// The plain [`crate::pipeline::compress`]/[`crate::pipeline::decompress`]
    /// wrappers route through this instance, so every caller in the
    /// process shares one worker pool.
    pub fn shared() -> &'static Arc<Engine> {
        static SHARED: OnceLock<Arc<Engine>> = OnceLock::new();
        SHARED.get_or_init(|| Arc::new(Engine::default()))
    }

    /// Worker threads in the pool.
    pub fn pool_size(&self) -> usize {
        self.pool.size()
    }

    /// The single source of truth for the serial/parallel decision:
    /// threading lanes only helps with more than one pooled worker.
    /// `pipeline::codec::default_parallelism` delegates here.
    pub fn parallel_by_default(&self) -> bool {
        self.pool_size() > 1
    }

    /// The engine's reshape-plan cache.
    pub fn plans(&self) -> &PlanCache {
        &self.plans
    }

    /// The configured output format.
    pub fn format(&self) -> ContainerFormat {
        self.format
    }

    /// Whether this engine threads lane/chunk fan-out on decode
    /// ([`EngineConfig::decode_parallel`], defaulting to "pool has more
    /// than one worker"). Decode entry points take no per-call flag —
    /// this is the config-carried setting they consult.
    pub fn decode_parallel(&self) -> bool {
        self.decode_parallel
    }
}

/// A codec handle held by long-lived components (coordinator nodes):
/// either a dedicated engine, or — the default — the process-wide
/// shared engine resolved *lazily*, so a component that is immediately
/// given a dedicated engine never spawns the shared pool at all.
#[derive(Default)]
pub struct EngineHandle(Option<Arc<Engine>>);

impl EngineHandle {
    /// Resolve to [`Engine::shared`] on first use.
    pub fn shared() -> Self {
        EngineHandle(None)
    }

    /// Always use `engine`.
    pub fn dedicated(engine: Arc<Engine>) -> Self {
        EngineHandle(Some(engine))
    }

    /// The engine behind this handle.
    pub fn get(&self) -> &Engine {
        match &self.0 {
            Some(e) => e.as_ref(),
            None => Engine::shared().as_ref(),
        }
    }

    /// True when a dedicated engine was installed.
    pub fn is_dedicated(&self) -> bool {
        self.0.is_some()
    }
}

impl Engine {
    // ------------------------------------------------------------ encode

    /// Compress pre-quantized symbols (the serving hot path). The
    /// container is tagged `f32`; symbol producers for half-precision
    /// models use [`Engine::compress_quantized_dtype`] (or the fused
    /// [`Engine::compress_tensor`]).
    pub fn compress_quantized(
        &self,
        symbols: &[u16],
        params: QuantParams,
        cfg: &PipelineConfig,
    ) -> Result<(Vec<u8>, CompressStats)> {
        self.compress_quantized_dtype(symbols, params, Dtype::F32, cfg)
    }

    /// Compress pre-quantized symbols into a container tagged with the
    /// original tensor's `dtype` (the reconstruction target decoders
    /// sniff). `f32` emits the legacy byte-identical header.
    pub fn compress_quantized_dtype(
        &self,
        symbols: &[u16],
        params: QuantParams,
        dtype: Dtype,
        cfg: &PipelineConfig,
    ) -> Result<(Vec<u8>, CompressStats)> {
        let t = symbols.len();
        if t == 0 {
            return Err(Error::invalid("cannot compress empty tensor"));
        }
        let background = params.zero_symbol();
        let (n_rows, reshape_evaluated) = resolve_n(symbols, background, cfg)?;
        let k = t / n_rows;

        // Modified CSR + concat (§3.1).
        let csr = ModCsr::encode(symbols, n_rows, k, background)?;
        let d = csr.concat();
        let alphabet = csr.concat_alphabet(params.alphabet());

        // Summed frequency table over D = v ⊕ c ⊕ r. One histogram pass
        // serves both the normalized coding table and the entropy stat.
        let freqs = stats::histogram(&d, alphabet);
        let entropy = stats::shannon_entropy(&freqs);
        let table = if d.is_empty() {
            FreqTable::from_symbols(&d, alphabet)
        } else {
            FreqTable::from_counts(&freqs)?
        };
        // Arc up front so pooled lane jobs share the table without a
        // per-request deep copy; serialization below borrows through the
        // Arc too, so the table is never cloned on this path.
        let table = Arc::new(table);
        let nnz = csr.nnz();
        if !supported_states(cfg.layout.states_per_lane()) {
            return Err(Error::invalid(format!(
                "unsupported states-per-lane {} (supported: 1, 2, 4, 8)",
                cfg.layout.states_per_lane()
            )));
        }

        match self.format {
            ContainerFormat::V1 => {
                let lanes = cfg.lanes.clamp(1, MAX_LANES);
                let states = cfg.layout.states_per_lane();
                let (pairs, symbol_count) =
                    self.encode_spans(d, &table, lanes, states, cfg.parallel)?;
                let payloads: Vec<Vec<u8>> = pairs.into_iter().map(|(_, p)| p).collect();
                let payload =
                    assemble_stream_with_layout(cfg.layout, lanes, symbol_count, &payloads);
                // Serialize through the borrowed view: the table stays
                // behind its `Arc` (shared with any pooled lane jobs) and
                // is never deep-copied just to emit bytes.
                let bytes = ContainerRef {
                    dtype,
                    params,
                    orig_len: t,
                    n_rows,
                    nnz,
                    alphabet,
                    table: table.as_ref(),
                    payload: &payload,
                }
                .to_bytes();
                let stats = CompressStats {
                    n_rows,
                    n_cols: k,
                    nnz,
                    entropy,
                    total_bytes: bytes.len(),
                    payload_bytes: payload.len(),
                    side_info_bytes: bytes.len() - payload.len(),
                    reshape_evaluated,
                };
                Ok((bytes, stats))
            }
            ContainerFormat::ChunkedV2 => {
                // Clamp to the format's header bound so the encoder can
                // never emit a container its own decoder rejects.
                let n_chunks =
                    d.len().div_ceil(self.chunk_symbols).clamp(1, chunked::MAX_CHUNKS);
                // Chunked containers keep scalar per-chunk streams: the
                // chunk header carries no state count, and chunk-level
                // fan-out is already the format's parallelism story.
                let (pairs, symbol_count) =
                    self.encode_spans(d, &table, n_chunks, 1, cfg.parallel)?;
                debug_assert_eq!(symbol_count, 2 * nnz + n_rows);
                // Each chunk's symbol count comes paired with its payload
                // straight from encode_spans, so header and payload can
                // never drift.
                let chunks: Vec<Chunk> = pairs
                    .into_iter()
                    .map(|(span, payload)| Chunk::new(span.len(), payload))
                    .collect();
                let payload_bytes: usize = chunks.iter().map(|c| c.payload.len()).sum();
                // Borrowed-parts serialization: same no-deep-copy story
                // as the v1 path above.
                let bytes = chunked::serialize_chunked(
                    dtype, params, t, n_rows, nnz, alphabet, table.as_ref(), &chunks,
                );
                let stats = CompressStats {
                    n_rows,
                    n_cols: k,
                    nnz,
                    entropy,
                    total_bytes: bytes.len(),
                    payload_bytes,
                    side_info_bytes: bytes.len() - payload_bytes,
                    reshape_evaluated,
                };
                Ok((bytes, stats))
            }
        }
    }

    /// Compress a dtype-tagged tensor view (quantization inside). This
    /// is the dtype-generic entry point: the fused
    /// [`quant::fit_and_quantize_tensor`] converts f16/bf16 elements to
    /// `f32` on load — two passes over the borrowed storage, **no
    /// intermediate `f32` `Vec` for any dtype** — then the symbol
    /// pipeline runs and the container is tagged with the view's dtype.
    pub fn compress_tensor(
        &self,
        tensor: TensorRef<'_>,
        cfg: &PipelineConfig,
    ) -> Result<(Vec<u8>, CompressStats)> {
        let (params, symbols) = quant::fit_and_quantize_tensor(cfg.q, &tensor)?;
        self.compress_quantized_dtype(&symbols, params, tensor.dtype(), cfg)
    }

    /// Compress an `f32` tensor — a thin shim over
    /// [`Engine::compress_tensor`] kept so pre-dtype call sites keep
    /// compiling (and keep their byte-identical output).
    pub fn compress(
        &self,
        data: &[f32],
        cfg: &PipelineConfig,
    ) -> Result<(Vec<u8>, CompressStats)> {
        self.compress_tensor(TensorRef::from_f32(data), cfg)
    }

    /// Compress with the engine's plan cache resolving the reshape:
    /// Algorithm 1 runs only on the first sighting of a `(T, Q)` shape.
    ///
    /// This is the library entry point for steady-state callers that
    /// have no coordinator around them. The coordinator's edge nodes
    /// deliberately do **not** use it: each node owns a [`PlanCache`]
    /// so its `plan_cache_stats()` reflect that route alone, while the
    /// engine-level cache here is process-wide. Both are the same type;
    /// a fix to one mechanism is a fix to both.
    pub fn compress_quantized_cached(
        &self,
        symbols: &[u16],
        params: QuantParams,
        cfg: &PipelineConfig,
    ) -> Result<(Vec<u8>, CompressStats)> {
        let resolved = match cfg.reshape {
            ReshapeStrategy::Optimize => PipelineConfig {
                reshape: self.plans.strategy(symbols, &params)?,
                ..cfg.clone()
            },
            _ => cfg.clone(),
        };
        self.compress_quantized(symbols, params, &resolved)
    }

    /// Split `d` into `n_spans` contiguous spans and rANS-encode each
    /// with `states` interleaved coder states per span (1 = scalar), on
    /// pooled workers when `parallel` (and the pool) allow it. Returns
    /// each span paired with its payload (so callers never re-derive
    /// the partition) plus the total symbol count.
    fn encode_spans(
        &self,
        d: Vec<u32>,
        table: &Arc<FreqTable>,
        n_spans: usize,
        states: usize,
        parallel: bool,
    ) -> Result<(Vec<(std::ops::Range<usize>, Vec<u8>)>, usize)> {
        let symbol_count = d.len();
        let spans = lane_spans(symbol_count, n_spans);
        let use_pool = parallel && spans.len() > 1 && self.pool_size() > 1;
        let payloads: Vec<Vec<u8>> = if use_pool {
            let d = Arc::new(d);
            let jobs: Vec<_> = spans
                .iter()
                .map(|span| {
                    let d = Arc::clone(&d);
                    let table = Arc::clone(table);
                    let span = span.clone();
                    move || encode_multistate(&d[span], &table, states)
                })
                .collect();
            collect_lane_results(self.pool.run_batch(jobs), "encode")?
        } else {
            spans
                .iter()
                .map(|span| encode_multistate(&d[span.clone()], table, states))
                .collect::<Result<_>>()?
        };
        Ok((spans.into_iter().zip(payloads).collect(), symbol_count))
    }

    // ------------------------------------------------------------ decode

    /// Decompress a container (v1 or v2, detected by magic) to quantized
    /// symbols plus the quantization parameters. Lane/chunk threading
    /// follows the engine's config-carried setting
    /// ([`Engine::decode_parallel`]); there is no per-call knob.
    pub fn decompress_to_symbols(&self, bytes: &[u8]) -> Result<(Vec<u16>, QuantParams)> {
        let (symbols, params, _) = self.decode_symbols(bytes)?;
        Ok((symbols, params))
    }

    /// Decompress all the way to an `f32` vector, whatever the
    /// container's dtype tag (the quantization grid is dtype-agnostic;
    /// this is the lossy-reconstruction view of any container). For
    /// zero-copy decode into a caller buffer of the container's own
    /// dtype, use [`Engine::decompress_into`].
    pub fn decompress(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let (symbols, params) = self.decompress_to_symbols(bytes)?;
        Ok(quant::dequantize(&symbols, &params))
    }

    /// Decompress a container straight into a caller-owned output
    /// buffer — the zero-copy decode path. The buffer's dtype must
    /// match the container's dtype tag and its capacity must cover the
    /// decoded element count (both are rejected from the header alone,
    /// before any rANS work); elements `0..info.elements` are written
    /// and any tail is left untouched. Returns what was decoded.
    pub fn decompress_into(
        &self,
        bytes: &[u8],
        mut out: TensorMut<'_>,
    ) -> Result<DecodeInfo> {
        // Cheap header peek: reject dtype/capacity mismatches before
        // paying for CRC validation and the full symbol decode.
        let (dtype, elements) = if bytes.len() >= 4 && &bytes[0..4] == chunked::MAGIC_V2 {
            chunked::peek_dtype_and_len(bytes)?
        } else {
            container::peek_dtype_and_len(bytes)?
        };
        if dtype != out.dtype() {
            return Err(Error::invalid(format!(
                "container holds {dtype} elements but the output buffer is {}",
                out.dtype()
            )));
        }
        if out.len() < elements {
            return Err(Error::invalid(format!(
                "output buffer of {} elements too small for {elements} decoded elements",
                out.len()
            )));
        }
        let (symbols, params, dtype) = self.decode_symbols(bytes)?;
        quant::dequantize_into(&symbols, &params, &mut out)?;
        Ok(DecodeInfo { elements: symbols.len(), dtype, params })
    }

    fn decode_symbols(&self, bytes: &[u8]) -> Result<(Vec<u16>, QuantParams, Dtype)> {
        if bytes.len() >= 4 && &bytes[0..4] == chunked::MAGIC_V2 {
            self.decompress_v2(bytes)
        } else {
            self.decompress_v1(bytes)
        }
    }

    fn decompress_v1(&self, bytes: &[u8]) -> Result<(Vec<u16>, QuantParams, Dtype)> {
        let parallel = self.decode_parallel;
        let c = Container::from_bytes(bytes)?;
        let parsed = parse_stream_spans(&c.payload)?;
        // The stream's declared symbol count must equal ℓ_D *before* any
        // decoding: a degenerate table can legally decode an arbitrary
        // number of symbols from a few bytes, so checking afterwards
        // would let a forged header burn unbounded memory/CPU first.
        if parsed.symbol_count != c.ell_d() {
            return Err(Error::corrupt(format!(
                "stream declares {} symbols, header ℓ_D = {}",
                parsed.symbol_count,
                c.ell_d()
            )));
        }
        let states = parsed.states_per_lane;
        let shape = DecodedShape::of_v1(&c);
        let use_pool = parallel && parsed.lanes.len() > 1 && self.pool_size() > 1;
        let decoded: Vec<Vec<u32>> = if use_pool {
            // Share the parsed container itself with the lane jobs —
            // no per-request copy of the payload or table.
            let c = Arc::new(c);
            let jobs: Vec<_> = parsed
                .lanes
                .into_iter()
                .map(|(count, range)| {
                    let c = Arc::clone(&c);
                    move || decode_multistate(&c.payload[range], count, &c.table, states)
                })
                .collect();
            collect_lane_results(self.pool.run_batch(jobs), "decode")?
        } else {
            parsed
                .lanes
                .into_iter()
                .map(|(count, range)| {
                    decode_multistate(&c.payload[range], count, &c.table, states)
                })
                .collect::<Result<_>>()?
        };
        shape.reassemble(decoded)
    }

    fn decompress_v2(&self, bytes: &[u8]) -> Result<(Vec<u16>, QuantParams, Dtype)> {
        let parallel = self.decode_parallel;
        let c = ChunkedContainer::from_bytes(bytes)?;
        let shape = DecodedShape::of_v2(&c);
        let use_pool = parallel && c.chunks.len() > 1 && self.pool_size() > 1;
        let decoded: Vec<Vec<u32>> = if use_pool {
            let c = Arc::new(c);
            let jobs: Vec<_> = (0..c.chunks.len())
                .map(|i| {
                    let c = Arc::clone(&c);
                    move || c.decode_chunk(i)
                })
                .collect();
            collect_lane_results(self.pool.run_batch(jobs), "chunk decode")?
        } else {
            (0..c.chunks.len()).map(|i| c.decode_chunk(i)).collect::<Result<_>>()?
        };
        shape.reassemble(decoded)
    }
}

/// The header fields both container formats share, copied out before the
/// container is handed to pooled lane jobs — one reassembly path for v1
/// and v2, so the ℓ_D consistency check and CSR rebuild can never drift
/// between formats.
#[derive(Clone, Copy)]
struct DecodedShape {
    dtype: Dtype,
    params: QuantParams,
    nnz: usize,
    n_rows: usize,
    n_cols: usize,
    ell_d: usize,
}

impl DecodedShape {
    fn of_v1(c: &Container) -> Self {
        DecodedShape {
            dtype: c.dtype,
            params: c.params,
            nnz: c.nnz,
            n_rows: c.n_rows,
            n_cols: c.n_cols(),
            ell_d: c.ell_d(),
        }
    }

    fn of_v2(c: &ChunkedContainer) -> Self {
        DecodedShape {
            dtype: c.dtype,
            params: c.params,
            nnz: c.nnz,
            n_rows: c.n_rows,
            n_cols: c.n_cols(),
            ell_d: c.ell_d(),
        }
    }

    /// Concatenate decoded lane/chunk symbols and rebuild the tensor.
    fn reassemble(self, decoded: Vec<Vec<u32>>) -> Result<(Vec<u16>, QuantParams, Dtype)> {
        let mut d = Vec::with_capacity(self.ell_d.min(1 << 20));
        for part in decoded {
            d.extend(part);
        }
        if d.len() != self.ell_d {
            return Err(Error::corrupt(format!(
                "decoded {} symbols, expected ℓ_D = {}",
                d.len(),
                self.ell_d
            )));
        }
        let csr =
            ModCsr::from_concat(&d, self.nnz, self.n_rows, self.n_cols, self.params.zero_symbol())?;
        Ok((csr.decode()?, self.params, self.dtype))
    }
}

/// Flatten pooled lane results, converting a panicked lane into a codec
/// error instead of poisoning the caller.
fn collect_lane_results<T>(
    results: Vec<std::thread::Result<Result<T>>>,
    what: &str,
) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(results.len());
    for (i, r) in results.into_iter().enumerate() {
        match r {
            Ok(Ok(v)) => out.push(v),
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(Error::codec(format!("{what} lane {i} panicked"))),
        }
    }
    Ok(out)
}

/// Resolve the reshape strategy to a concrete `N` (shared by every
/// engine format path; moved here from `pipeline::codec`).
fn resolve_n(symbols: &[u16], background: u16, cfg: &PipelineConfig) -> Result<(usize, usize)> {
    let t = symbols.len();
    match &cfg.reshape {
        ReshapeStrategy::Fixed(n) => {
            if *n == 0 || t % n != 0 {
                return Err(Error::invalid(format!("fixed N={n} does not divide T={t}")));
            }
            Ok((*n, 0))
        }
        ReshapeStrategy::Flat => Ok((t.max(1), 0)),
        ReshapeStrategy::Optimize => {
            let out = reshape::optimize(symbols, background, &OptimizerConfig::paper(cfg.q))?;
            Ok((out.best.n, out.evaluated))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::codec::StreamLayout;
    use crate::util::prng::Rng;

    fn synth(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len)
            .map(|_| if rng.next_f64() < 0.6 { 0.0 } else { rng.normal().abs() as f32 })
            .collect()
    }

    #[test]
    fn v1_roundtrip_parallel_and_serial_identical() {
        let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
        let data = synth(1, 16_384);
        for q in [2u8, 4, 6, 8] {
            let par = PipelineConfig {
                q,
                lanes: 8,
                parallel: true,
                reshape: ReshapeStrategy::Optimize,
                layout: StreamLayout::V1,
            };
            let ser = PipelineConfig { parallel: false, ..par.clone() };
            let (b_par, s_par) = engine.compress(&data, &par).unwrap();
            let (b_ser, s_ser) = engine.compress(&data, &ser).unwrap();
            assert_eq!(b_par, b_ser, "q={q}");
            assert_eq!(s_par.total_bytes, s_ser.total_bytes);
            let back = engine.decompress(&b_par).unwrap();
            assert_eq!(back.len(), data.len());
        }
    }

    #[test]
    fn v2_roundtrip_matches_v1_symbols() {
        let data = synth(2, 8192);
        let v1 = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
        let v2 = Engine::new(EngineConfig {
            workers: 2,
            format: ContainerFormat::ChunkedV2,
            chunk_symbols: 512,
            // Exercise the forced-serial decode override alongside v1's
            // pool-adaptive default.
            decode_parallel: Some(false),
        });
        let cfg = PipelineConfig::paper(4);
        let (b1, _) = v1.compress(&data, &cfg).unwrap();
        let (b2, _) = v2.compress(&data, &cfg).unwrap();
        assert_eq!(&b2[0..4], chunked::MAGIC_V2);
        assert!(v1.decode_parallel());
        assert!(!v2.decode_parallel());
        // Either engine decodes either container (magic sniffing).
        let (s1, p1) = v1.decompress_to_symbols(&b1).unwrap();
        let (s2, p2) = v1.decompress_to_symbols(&b2).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(p1, p2);
        let (s3, _) = v2.decompress_to_symbols(&b1).unwrap();
        assert_eq!(s1, s3);
    }

    #[test]
    fn v2_splits_into_expected_chunk_count() {
        let data = synth(3, 20_000);
        let engine = Engine::new(EngineConfig {
            workers: 2,
            format: ContainerFormat::ChunkedV2,
            chunk_symbols: 1000,
            decode_parallel: None,
        });
        let (bytes, stats) = engine.compress(&data, &PipelineConfig::paper(4)).unwrap();
        let c = ChunkedContainer::from_bytes(&bytes).unwrap();
        let ell_d = 2 * stats.nnz + stats.n_rows;
        assert_eq!(c.chunks.len(), ell_d.div_ceil(1000));
        assert_eq!(c.ell_d(), ell_d);
    }

    #[test]
    fn single_worker_engine_is_fully_serial_but_correct() {
        let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
        assert!(!engine.parallel_by_default());
        let data = synth(4, 4096);
        let cfg = PipelineConfig {
            q: 4,
            lanes: 8,
            parallel: true,
            reshape: ReshapeStrategy::Flat,
            layout: StreamLayout::V1,
        };
        let (bytes, _) = engine.compress(&data, &cfg).unwrap();
        let back = engine.decompress(&bytes).unwrap();
        assert_eq!(back.len(), data.len());
    }

    #[test]
    fn cached_compression_reuses_plans() {
        let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
        let data = synth(5, 8192);
        let cfg = PipelineConfig::paper(4);
        let params = QuantParams::fit(4, &data).unwrap();
        let symbols = quant::quantize(&data, &params);
        let (a, _) = engine.compress_quantized_cached(&symbols, params, &cfg).unwrap();
        let (b, _) = engine.compress_quantized_cached(&symbols, params, &cfg).unwrap();
        assert_eq!(a, b);
        let (hits, misses) = engine.plans().stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 1);
    }

    #[test]
    fn empty_tensor_rejected() {
        let engine = Engine::new(EngineConfig::default());
        assert!(engine.compress(&[], &PipelineConfig::paper(4)).is_err());
    }

    #[test]
    fn multistate_roundtrip_parallel_and_serial_identical() {
        let engine = Engine::new(EngineConfig { workers: 4, ..EngineConfig::default() });
        let serial = Engine::new(EngineConfig {
            workers: 4,
            decode_parallel: Some(false),
            ..EngineConfig::default()
        });
        let data = synth(6, 16_384);
        for q in [2u8, 4, 8] {
            for states in [2usize, 4, 8] {
                let par = PipelineConfig {
                    q,
                    lanes: 8,
                    parallel: true,
                    reshape: ReshapeStrategy::Optimize,
                    layout: StreamLayout::MultiState(states),
                };
                let ser = PipelineConfig { parallel: false, ..par.clone() };
                let (b_par, _) = engine.compress(&data, &par).unwrap();
                let (b_ser, _) = engine.compress(&data, &ser).unwrap();
                assert_eq!(b_par, b_ser, "q={q} states={states}");
                // Decoders need no layout knob: both the threaded and
                // the forced-serial engines sniff the stream marker.
                for eng in [&engine, &serial] {
                    let back = eng.decompress(&b_par).unwrap();
                    assert_eq!(back.len(), data.len());
                }
            }
        }
    }

    #[test]
    fn multistate_layout_changes_payload_not_header() {
        let engine = Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() });
        let data = synth(7, 8192);
        let v1 = PipelineConfig::paper(4);
        let ms = PipelineConfig::paper(4).with_states(4);
        let (b1, s1) = engine.compress(&data, &v1).unwrap();
        let (b2, s2) = engine.compress(&data, &ms).unwrap();
        assert_eq!(&b1[0..4], b"RSC1");
        assert_eq!(&b2[0..4], b"RSC1");
        assert_ne!(b1, b2, "multi-state payload must differ from scalar");
        // Same symbols decode from both; side info is identical.
        assert_eq!(s1.nnz, s2.nnz);
        let (d1, p1) = engine.decompress_to_symbols(&b1).unwrap();
        let (d2, p2) = engine.decompress_to_symbols(&b2).unwrap();
        assert_eq!(d1, d2);
        assert_eq!(p1, p2);
    }

    #[test]
    fn chunked_v2_keeps_scalar_chunks_under_multistate_layout() {
        // The chunked container has no per-chunk state count; the layout
        // knob applies to the v1 container's payload only.
        let data = synth(8, 8192);
        let engine = Engine::new(EngineConfig {
            workers: 2,
            format: ContainerFormat::ChunkedV2,
            chunk_symbols: 512,
            decode_parallel: None,
        });
        let v1 = engine.compress(&data, &PipelineConfig::paper(4)).unwrap().0;
        let ms =
            engine.compress(&data, &PipelineConfig::paper(4).with_states(4)).unwrap().0;
        assert_eq!(v1, ms, "chunked output must not depend on the lane layout");
        let back = engine.decompress(&ms).unwrap();
        assert_eq!(back.len(), data.len());
    }

    #[test]
    fn dtyped_tensor_roundtrip_through_both_container_formats() {
        use crate::tensor::half;
        let data = synth(10, 8192);
        let bf16: Vec<u16> = data.iter().map(|&x| half::f32_to_bf16(x)).collect();
        let f16: Vec<u16> = data.iter().map(|&x| half::f32_to_f16(x)).collect();
        let cfg = PipelineConfig::paper(4);
        for format in [ContainerFormat::V1, ContainerFormat::ChunkedV2] {
            let engine = Engine::new(EngineConfig {
                workers: 2,
                format,
                chunk_symbols: 1024,
                decode_parallel: None,
            });
            for (dtype, bits) in [(Dtype::Bf16, &bf16), (Dtype::F16, &f16)] {
                let tensor = match dtype {
                    Dtype::Bf16 => TensorRef::from_bf16_bits(bits),
                    _ => TensorRef::from_f16_bits(bits),
                };
                let (bytes, stats) = engine.compress_tensor(tensor, &cfg).unwrap();
                assert_eq!(stats.total_bytes, bytes.len());
                let mut out = vec![0u16; bits.len()];
                let view = match dtype {
                    Dtype::Bf16 => TensorMut::from_bf16_bits(&mut out),
                    _ => TensorMut::from_f16_bits(&mut out),
                };
                let info = engine.decompress_into(&bytes, view).unwrap();
                assert_eq!(info.dtype, dtype);
                assert_eq!(info.elements, bits.len());
                // Reconstruction error bounded by one quantization step
                // plus half-dtype rounding.
                for (i, &b) in out.iter().enumerate() {
                    let orig = match dtype {
                        Dtype::Bf16 => half::bf16_to_f32(bits[i]),
                        _ => half::f16_to_f32(bits[i]),
                    };
                    let got = match dtype {
                        Dtype::Bf16 => half::bf16_to_f32(b),
                        _ => half::f16_to_f32(b),
                    };
                    let tol = info.params.scale * 1.01 + orig.abs() * 0.01 + 1e-5;
                    assert!((orig - got).abs() <= tol, "{format:?} {dtype} i={i}");
                }
            }
        }
    }

    #[test]
    fn decompress_into_rejects_mismatch_and_short_buffers() {
        let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() });
        let data = synth(11, 2048);
        let bf16: Vec<u16> =
            data.iter().map(|&x| crate::tensor::half::f32_to_bf16(x)).collect();
        let (bytes, _) = engine
            .compress_tensor(TensorRef::from_bf16_bits(&bf16), &PipelineConfig::paper(4))
            .unwrap();
        // Wrong dtype buffer.
        let mut f32_out = vec![0.0f32; data.len()];
        assert!(engine.decompress_into(&bytes, TensorMut::from_f32(&mut f32_out)).is_err());
        // Short buffer.
        let mut short = vec![0u16; data.len() - 1];
        assert!(engine
            .decompress_into(&bytes, TensorMut::from_bf16_bits(&mut short))
            .is_err());
        // Exact-size buffer succeeds; oversize writes a prefix.
        let mut exact = vec![0u16; data.len()];
        engine.decompress_into(&bytes, TensorMut::from_bf16_bits(&mut exact)).unwrap();
        let mut wide = vec![0xFFFFu16; data.len() + 7];
        let info =
            engine.decompress_into(&bytes, TensorMut::from_bf16_bits(&mut wide)).unwrap();
        assert_eq!(info.elements, data.len());
        assert_eq!(&wide[..data.len()], exact.as_slice());
        assert!(wide[data.len()..].iter().all(|&x| x == 0xFFFF));
    }

    #[test]
    fn unsupported_states_rejected_at_compress() {
        let engine = Engine::new(EngineConfig::default());
        let data = synth(9, 2048);
        for states in [0usize, 3, 5, 6, 7, 9] {
            let cfg = PipelineConfig {
                layout: StreamLayout::MultiState(states),
                ..PipelineConfig::paper(4)
            };
            assert!(engine.compress(&data, &cfg).is_err(), "states={states}");
        }
    }
}
