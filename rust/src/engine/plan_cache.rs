//! Reshape-plan cache: Algorithm 1 runs once per tensor shape.
//!
//! The optimizer's choice of `Ñ` depends on the symbol distribution, but
//! in steady-state serving every request for a given route carries the
//! same `(T, Q)` and near-identical statistics (the paper's GPU pipeline
//! makes the same assumption). Caching the chosen `N` by `(T, Q)` keeps
//! Algorithm 1 entirely off the hot path after the first sighting of a
//! shape; subsequent requests compress with
//! [`ReshapeStrategy::Fixed`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::Result;
use crate::pipeline::codec::ReshapeStrategy;
use crate::quant::QuantParams;

/// Thread-safe `(T, Q) → N` cache with hit/miss counters.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<(usize, u8), usize>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve the reshape strategy for a tensor, running Algorithm 1 on
    /// the first sighting of a `(T, Q)` pair.
    pub fn strategy(&self, symbols: &[u16], params: &QuantParams) -> Result<ReshapeStrategy> {
        let key = (symbols.len(), params.q);
        if let Some(&n) = self.plans.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(ReshapeStrategy::Fixed(n));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let cfg = crate::reshape::optimizer::OptimizerConfig::paper(params.q);
        let out = crate::reshape::optimize(symbols, params.zero_symbol(), &cfg)?;
        self.plans.lock().unwrap().insert(key, out.best.n);
        Ok(ReshapeStrategy::Fixed(out.best.n))
    }

    /// `(hits, misses)` counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// True when no plan has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, QuantParams};
    use crate::util::prng::Rng;

    fn symbols(seed: u64, len: usize, q: u8) -> (Vec<u16>, QuantParams) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..len)
            .map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.normal().abs() as f32 })
            .collect();
        let p = QuantParams::fit(q, &data).unwrap();
        (quantize(&data, &p), p)
    }

    #[test]
    fn first_sighting_misses_then_hits() {
        let cache = PlanCache::new();
        let (syms, p) = symbols(1, 4096, 4);
        let a = cache.strategy(&syms, &p).unwrap();
        let b = cache.strategy(&syms, &p).unwrap();
        match (&a, &b) {
            (ReshapeStrategy::Fixed(x), ReshapeStrategy::Fixed(y)) => assert_eq!(x, y),
            other => panic!("expected Fixed plans, got {other:?}"),
        }
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_shapes_get_distinct_entries() {
        let cache = PlanCache::new();
        let (a, pa) = symbols(2, 4096, 4);
        let (b, pb) = symbols(3, 8192, 4);
        let (c, pc) = symbols(4, 4096, 6);
        cache.strategy(&a, &pa).unwrap();
        cache.strategy(&b, &pb).unwrap();
        cache.strategy(&c, &pc).unwrap();
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.stats(), (0, 3));
    }

    #[test]
    fn concurrent_resolution_is_consistent() {
        let cache = std::sync::Arc::new(PlanCache::new());
        let (syms, p) = symbols(5, 4096, 4);
        let chosen: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let cache = std::sync::Arc::clone(&cache);
                    let syms = syms.clone();
                    s.spawn(move || match cache.strategy(&syms, &p).unwrap() {
                        ReshapeStrategy::Fixed(n) => n,
                        other => panic!("unexpected {other:?}"),
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(chosen.windows(2).all(|w| w[0] == w[1]));
        let (hits, misses) = cache.stats();
        assert_eq!(hits + misses, 8);
        assert!(misses >= 1);
    }
}
