//! One-shot `lanes × states` microbenchmark autotuner.
//!
//! The best stream shape is a property of the *machine*, not the model:
//! an 8-state lane only pays off where a SIMD backend covers it (AVX2
//! on x86_64, NEON on aarch64), thread-level lanes only pay off with
//! cores to fan out to, and the crossover points differ between a Xeon
//! and a Jetson. Rather than shipping x86-tuned defaults to every edge
//! device, the tuner times one round-trip of each candidate
//! `lanes × states` shape on a synthetic feature-shaped workload at
//! first use, picks the fastest decode, and caches the pick for the
//! life of the process ([`tuned`]).
//!
//! [`apply`] is the config hook: it adopts the pick into an
//! [`AppConfig`] unless the user pinned the knob (`--set lanes=…` /
//! `--set states=…` always win) or disabled tuning
//! (`--set autotune=off`). Recorded experiment configs re-pin both
//! knobs on load, so a JSON config replayed on a different machine
//! reproduces the recorded shape instead of re-tuning.
//!
//! The workload is deliberately small (a few milliseconds total): Zipf
//! symbols at the alphabet size the paper's Q=4 pipeline produces after
//! AIQ, long enough that per-round-trip cost is dominated by the steady
//! state of the coders, short enough that first-request latency stays
//! negligible. The pick only changes *performance*, never bytes: every
//! candidate shape is a self-describing wire format any decoder
//! accepts.

use std::sync::OnceLock;

use crate::config::AppConfig;
use crate::rans::freq::FreqTable;
use crate::rans::interleaved::{decode_interleaved, encode_interleaved_with_layout, StreamLayout};
use crate::rans::simd;
use crate::util::prng::Rng;
use crate::util::timer;

/// Symbols in the tuning workload — feature-map sized (a 64×8×8
/// activation block), big enough to amortize per-call setup.
const TUNE_SYMBOLS: usize = 32 * 1024;

/// Alphabet of the tuning workload: 6-bit, the upper end of the
/// paper's AIQ bit-widths.
const TUNE_ALPHABET: usize = 64;

/// The shape the tuner picked for this machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tuning {
    /// Thread-level lanes.
    pub lanes: usize,
    /// Interleaved rANS states per lane.
    pub states: usize,
    /// Decode backend the winning shape dispatches to (diagnostics).
    pub backend: simd::Backend,
}

/// Time one candidate shape; `None` if the shape fails outright (it
/// never should — all candidates are supported layouts — but a tuner
/// must not be able to take the pipeline down).
fn time_candidate(
    symbols: &[u32],
    table: &FreqTable,
    lanes: usize,
    states: usize,
) -> Option<f64> {
    let layout = if states == 1 { StreamLayout::V1 } else { StreamLayout::MultiState(states) };
    let bytes = encode_interleaved_with_layout(symbols, table, lanes, layout, lanes > 1).ok()?;
    let decoded = decode_interleaved(&bytes, table, lanes > 1).ok()?;
    if decoded != symbols {
        return None;
    }
    // Decode-side throughput is what the shape choice actually moves
    // (the edge device decodes on the critical path), so that is what
    // scores a candidate. Best-of-3 after one warmup absorbs first-use
    // table builds and cold caches.
    let m = timer::measure(1, 3, || decode_interleaved(&bytes, table, lanes > 1));
    let best = m.samples_ms.iter().copied().fold(f64::INFINITY, f64::min);
    if best.is_finite() {
        Some(best)
    } else {
        None
    }
}

fn run_tuner() -> Tuning {
    let mut rng = Rng::new(0xA070);
    let symbols: Vec<u32> =
        (0..TUNE_SYMBOLS).map(|_| rng.zipf(TUNE_ALPHABET, 1.2) as u32).collect();
    let table = FreqTable::from_symbols(&symbols, TUNE_ALPHABET);

    // The safe default if every candidate fails: the config defaults.
    let mut best = (f64::INFINITY, AppConfig::default().lanes, AppConfig::default().states);
    for &states in &[1usize, 2, 4, 8] {
        for &lanes in &[1usize, 2, 4, 8] {
            if let Some(ms) = time_candidate(&symbols, &table, lanes, states) {
                if ms < best.0 {
                    best = (ms, lanes, states);
                }
            }
        }
    }
    let (_, lanes, states) = best;
    Tuning { lanes, states, backend: simd::backend_for(states).unwrap_or(simd::Backend::Scalar) }
}

/// The machine's tuned shape, measured once per process and cached.
pub fn tuned() -> Tuning {
    static TUNED: OnceLock<Tuning> = OnceLock::new();
    *TUNED.get_or_init(run_tuner)
}

/// Adopt the tuned shape into `cfg`, honoring the escape hatches:
/// no-op when `autotune=off`, and explicitly set knobs
/// ([`AppConfig::lanes_pinned`] / [`AppConfig::states_pinned`]) are
/// never overridden. Returns the tuning when it was consulted.
pub fn apply(cfg: &mut AppConfig) -> Option<Tuning> {
    if !cfg.autotune || (cfg.lanes_pinned() && cfg.states_pinned()) {
        return None;
    }
    let t = tuned();
    if !cfg.lanes_pinned() {
        cfg.lanes = t.lanes;
    }
    if !cfg.states_pinned() {
        cfg.states = t.states;
    }
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tuner must always land on a valid, supported shape and be
    /// stable within a process (OnceLock semantics).
    #[test]
    fn tuner_picks_a_supported_shape() {
        let t = tuned();
        assert!(matches!(t.lanes, 1 | 2 | 4 | 8), "lanes {}", t.lanes);
        assert!(crate::rans::multistate::supported_states(t.states), "states {}", t.states);
        assert!(t.backend.supports(t.states));
        assert_eq!(tuned(), t);
    }

    #[test]
    fn apply_honors_pins_and_escape_hatch() {
        // autotune=off is a strict no-op.
        let mut off = AppConfig::default();
        off.apply_override("autotune=off").unwrap();
        let (lanes, states) = (off.lanes, off.states);
        assert_eq!(apply(&mut off), None);
        assert_eq!((off.lanes, off.states), (lanes, states));

        // Pinned knobs survive tuning; unpinned ones adopt the pick.
        let mut pinned = AppConfig::default();
        pinned.apply_override("states=2").unwrap();
        let t = apply(&mut pinned).expect("tuner consulted");
        assert_eq!(pinned.states, 2, "explicit states must win");
        assert_eq!(pinned.lanes, t.lanes);

        // Fully pinned: the tuner is not even consulted.
        let mut both = AppConfig::default();
        both.apply_override("lanes=2").unwrap();
        both.apply_override("states=2").unwrap();
        assert_eq!(apply(&mut both), None);
        assert_eq!((both.lanes, both.states), (2, 2));
    }
}
