//! Chunked container format v2: per-chunk framing and checksums.
//!
//! The v1 container ([`crate::pipeline::container`]) frames the whole
//! interleaved rANS payload as one unit under one trailing CRC — fine
//! for request/response serving, but a single flipped byte can only be
//! localized to "somewhere", and nothing can be decoded until the full
//! container has arrived. v2 splits the concatenated stream
//! `D = v ⊕ c ⊕ r` into independently decodable chunks, each with its
//! own rANS coder state and its own CRC-32:
//!
//! ```text
//! magic  "RSC2"                    4 bytes
//! version                         1 byte  (2 = f32, 3 = dtype-tagged)
//! q                               1 byte
//! dtype tag                       1 byte  (version 3 only)
//! scale                           4 bytes f32 LE
//! zero                            varint (zigzag)
//! orig_len  T                     varint
//! n_rows    N                     varint
//! nnz                             varint
//! alphabet                        varint
//! freq table                      FreqTable::serialize
//! chunk_count                     varint
//! per chunk: symbol_count         varint
//!            payload_len          varint
//!            payload crc32        4 bytes LE
//! crc32 of everything above       4 bytes LE   ← header checksum
//! chunk payloads, concatenated    (covered by the per-chunk CRCs)
//! ```
//!
//! The header CRC covers the header + chunk table only; payload bytes
//! are covered chunk-by-chunk. That split is what buys streaming: a
//! receiver can validate the header as soon as it arrives, then decode
//! and verify each chunk independently (and in parallel) as payload
//! bytes stream in, without buffering the whole container first.
//!
//! **Dtype tagging** mirrors the v1 container: `f32` tensors keep the
//! legacy version-2 header byte-identically; f16/bf16 tensors emit
//! version 3 with a one-byte [`Dtype`] tag after `q`, sniffed by the
//! decoder.

use crate::error::{Error, Result};
use crate::quant::QuantParams;
use crate::rans::FreqTable;
use crate::tensor::Dtype;
use crate::util::{crc32, varint};

/// v2 container magic bytes.
pub const MAGIC_V2: &[u8; 4] = b"RSC2";
/// Legacy v2 container version byte (implicit `f32` dtype, no tag).
pub const VERSION_V2: u8 = 2;
/// Dtype-tagged v2 container version: a [`Dtype::tag`] byte follows `q`.
pub const VERSION_V2_DTYPED: u8 = 3;
/// Upper bound on chunks per container (header sanity check).
pub const MAX_CHUNKS: usize = 1 << 20;

/// One independently decodable span of the concatenated stream.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Symbols of `D` coded in this chunk.
    pub symbol_count: usize,
    /// CRC-32 of `payload`.
    pub crc: u32,
    /// Scalar rANS stream for this span.
    pub payload: Vec<u8>,
}

impl Chunk {
    /// Build a chunk from its payload, stamping the checksum.
    pub fn new(symbol_count: usize, payload: Vec<u8>) -> Self {
        let crc = crc32::hash(&payload);
        Chunk { symbol_count, crc, payload }
    }

    /// Verify the payload against the stored checksum.
    pub fn verify(&self, index: usize) -> Result<()> {
        let actual = crc32::hash(&self.payload);
        if actual != self.crc {
            return Err(Error::corrupt(format!(
                "chunk {index} checksum mismatch: stored {:#010x}, computed {actual:#010x}",
                self.crc
            )));
        }
        Ok(())
    }
}

/// Parsed v2 container: shared header + side information + chunk list.
#[derive(Debug, Clone)]
pub struct ChunkedContainer {
    /// Element type of the original tensor (reconstruction target).
    pub dtype: Dtype,
    /// Quantization parameters used by the encoder.
    pub params: QuantParams,
    /// Original flat length `T`.
    pub orig_len: usize,
    /// Reshape rows `N`.
    pub n_rows: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Entropy-coding alphabet for `D`.
    pub alphabet: usize,
    /// Frequency table shared by every chunk.
    pub table: FreqTable,
    /// Independently decodable chunks, in stream order.
    pub chunks: Vec<Chunk>,
}

impl ChunkedContainer {
    /// Columns `K = T / N`.
    pub fn n_cols(&self) -> usize {
        if self.n_rows == 0 { 0 } else { self.orig_len / self.n_rows }
    }

    /// Length of the concatenated stream `ℓ_D = 2·nnz + N`.
    pub fn ell_d(&self) -> usize {
        2 * self.nnz + self.n_rows
    }

    /// Total payload bytes across chunks (excluding framing).
    pub fn payload_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.payload.len()).sum()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        serialize_chunked(
            self.dtype,
            self.params,
            self.orig_len,
            self.n_rows,
            self.nnz,
            self.alphabet,
            &self.table,
            &self.chunks,
        )
    }

    /// Parse and structurally validate a v2 container.
    ///
    /// The header CRC and all size arithmetic are checked here; chunk
    /// *payload* checksums are checked on decode ([`Chunk::verify`]), so
    /// a partial decoder only pays for the chunks it touches.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < MAGIC_V2.len() + 2 + 4 + 4 {
            return Err(Error::corrupt("v2 container shorter than minimum header"));
        }
        if &bytes[0..4] != MAGIC_V2 {
            return Err(Error::corrupt("bad v2 magic"));
        }
        if bytes[4] != VERSION_V2 && bytes[4] != VERSION_V2_DTYPED {
            return Err(Error::corrupt(format!("unsupported v2 version {}", bytes[4])));
        }
        let q = bytes[5];
        let mut pos = 6usize;
        let dtype = if bytes[4] == VERSION_V2_DTYPED {
            if pos >= bytes.len() {
                return Err(Error::corrupt("dtype-tagged v2 header truncated"));
            }
            let d = Dtype::from_tag(bytes[pos])?;
            pos += 1;
            d
        } else {
            Dtype::F32
        };
        if pos + 4 > bytes.len() {
            return Err(Error::corrupt("v2 header truncated"));
        }
        let scale =
            f32::from_le_bytes([bytes[pos], bytes[pos + 1], bytes[pos + 2], bytes[pos + 3]]);
        pos += 4;
        let zero = varint::read_i64(bytes, &mut pos)?;
        let zero = i32::try_from(zero).map_err(|_| Error::corrupt("zero point overflow"))?;
        let orig_len = varint::read_usize(bytes, &mut pos)?;
        let n_rows = varint::read_usize(bytes, &mut pos)?;
        let nnz = varint::read_usize(bytes, &mut pos)?;
        let alphabet = varint::read_usize(bytes, &mut pos)?;
        let table = FreqTable::deserialize(bytes, &mut pos)?;
        let chunk_count = varint::read_usize(bytes, &mut pos)?;
        if chunk_count == 0 || chunk_count > MAX_CHUNKS {
            return Err(Error::corrupt(format!("bad chunk count {chunk_count}")));
        }
        let mut metas = Vec::with_capacity(chunk_count);
        for _ in 0..chunk_count {
            let symbol_count = varint::read_usize(bytes, &mut pos)?;
            let payload_len = varint::read_usize(bytes, &mut pos)?;
            if pos + 4 > bytes.len() {
                return Err(Error::corrupt("chunk table truncated"));
            }
            let crc = u32::from_le_bytes([
                bytes[pos],
                bytes[pos + 1],
                bytes[pos + 2],
                bytes[pos + 3],
            ]);
            pos += 4;
            metas.push((symbol_count, payload_len, crc));
        }
        // Header checksum covers everything up to here.
        if pos + 4 > bytes.len() {
            return Err(Error::corrupt("v2 header checksum missing"));
        }
        let stored = u32::from_le_bytes([
            bytes[pos],
            bytes[pos + 1],
            bytes[pos + 2],
            bytes[pos + 3],
        ]);
        let actual = crc32::hash(&bytes[..pos]);
        if stored != actual {
            return Err(Error::corrupt(format!(
                "v2 header crc mismatch: stored {stored:#010x}, computed {actual:#010x}"
            )));
        }
        pos += 4;

        // Structural sanity (mirrors the v1 checks).
        if !(1..=16).contains(&q) {
            return Err(Error::corrupt(format!("bad Q {q}")));
        }
        if orig_len > crate::pipeline::container::MAX_DECODE_SYMBOLS {
            return Err(Error::corrupt(format!(
                "declared tensor length {orig_len} exceeds decode cap"
            )));
        }
        if !scale.is_finite() || scale <= 0.0 {
            return Err(Error::corrupt("bad scale"));
        }
        if n_rows == 0 && orig_len != 0 {
            return Err(Error::corrupt("zero rows for nonempty tensor"));
        }
        if n_rows != 0 && orig_len % n_rows != 0 {
            return Err(Error::corrupt("N does not divide T"));
        }
        if nnz > orig_len {
            return Err(Error::corrupt("nnz exceeds tensor size"));
        }
        if table.alphabet() != alphabet {
            return Err(Error::corrupt("alphabet / table size mismatch"));
        }
        let ell_d = nnz
            .checked_mul(2)
            .and_then(|x| x.checked_add(n_rows))
            .ok_or_else(|| Error::corrupt("ℓ_D overflows"))?;
        let mut total_symbols = 0usize;
        for &(s, _, _) in &metas {
            total_symbols = total_symbols
                .checked_add(s)
                .ok_or_else(|| Error::corrupt("chunk symbol counts overflow"))?;
        }
        if total_symbols != ell_d {
            return Err(Error::corrupt(format!(
                "chunk symbols {total_symbols} != ℓ_D = {ell_d}"
            )));
        }

        let mut chunks = Vec::with_capacity(chunk_count);
        for (symbol_count, payload_len, crc) in metas {
            let end = pos
                .checked_add(payload_len)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| Error::corrupt("chunk payload truncated"))?;
            chunks.push(Chunk { symbol_count, crc, payload: bytes[pos..end].to_vec() });
            pos = end;
        }
        if pos != bytes.len() {
            return Err(Error::corrupt("trailing bytes after last chunk"));
        }
        let params = QuantParams { q, scale, zero };
        Ok(ChunkedContainer { dtype, params, orig_len, n_rows, nnz, alphabet, table, chunks })
    }

    /// Decode a single chunk's symbols, verifying its checksum first —
    /// the partial/streaming entry point. Every chunk decodes through
    /// the container's one shared [`FreqTable`], so the fused
    /// slot-table build is paid once per container, not per chunk.
    pub fn decode_chunk(&self, index: usize) -> Result<Vec<u32>> {
        let chunk = self
            .chunks
            .get(index)
            .ok_or_else(|| Error::invalid(format!("chunk index {index} out of range")))?;
        chunk.verify(index)?;
        crate::rans::decode(&chunk.payload, chunk.symbol_count, &self.table)
    }
}

/// Serialize a v2 container from borrowed parts — the single definition
/// of the v2 wire format. [`ChunkedContainer::to_bytes`] delegates
/// here, and the engine's pooled encode path calls it directly with the
/// `Arc`-shared frequency table so emitting bytes never deep-copies the
/// table (with its 32 KiB fused decode table).
#[allow(clippy::too_many_arguments)]
pub fn serialize_chunked(
    dtype: Dtype,
    params: QuantParams,
    orig_len: usize,
    n_rows: usize,
    nnz: usize,
    alphabet: usize,
    table: &FreqTable,
    chunks: &[Chunk],
) -> Vec<u8> {
    let mut head = Vec::with_capacity(64 + 10 * chunks.len());
    head.extend_from_slice(MAGIC_V2);
    // f32 keeps the legacy version-2 header (byte-identical wire
    // format); non-f32 tensors emit version 3 with a dtype tag.
    if dtype == Dtype::F32 {
        head.push(VERSION_V2);
        head.push(params.q);
    } else {
        head.push(VERSION_V2_DTYPED);
        head.push(params.q);
        head.push(dtype.tag());
    }
    head.extend_from_slice(&params.scale.to_le_bytes());
    varint::write_i64(&mut head, params.zero as i64);
    varint::write_usize(&mut head, orig_len);
    varint::write_usize(&mut head, n_rows);
    varint::write_usize(&mut head, nnz);
    varint::write_usize(&mut head, alphabet);
    table.serialize(&mut head);
    varint::write_usize(&mut head, chunks.len());
    for c in chunks {
        varint::write_usize(&mut head, c.symbol_count);
        varint::write_usize(&mut head, c.payload.len());
        head.extend_from_slice(&c.crc.to_le_bytes());
    }
    let header_crc = crc32::hash(&head);
    let mut out = head;
    out.extend_from_slice(&header_crc.to_le_bytes());
    for c in chunks {
        out.extend_from_slice(&c.payload);
    }
    out
}

/// Cheap `(dtype, orig_len)` header peek for the chunked container —
/// the shared `peek_header` specialized to `RSC2` (both formats carry
/// the same header prefix, so the parse logic lives in exactly one
/// place: `pipeline::container`).
pub(crate) fn peek_dtype_and_len(bytes: &[u8]) -> Result<(Dtype, usize)> {
    crate::pipeline::container::peek_header(bytes, MAGIC_V2, VERSION_V2, VERSION_V2_DTYPED)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rans::encode;
    use crate::util::prng::Rng;

    fn sample_container(seed: u64, n_chunks: usize) -> (ChunkedContainer, Vec<u32>) {
        let mut rng = Rng::new(seed);
        // A structurally consistent D-stream: nnz values + nnz cols + N counts.
        let nnz = 40usize;
        let n_rows = 20usize;
        let alphabet = 16usize;
        let mut d: Vec<u32> = Vec::new();
        for _ in 0..nnz {
            d.push(1 + rng.below(14) as u32); // values (≠ background 0)
        }
        for _ in 0..nnz {
            d.push(rng.below(8) as u32); // cols
        }
        for _ in 0..n_rows {
            d.push(2); // row counts: 20 rows × 2 = 40 = nnz
        }
        let table = FreqTable::from_symbols(&d, alphabet);
        let spans = crate::rans::interleaved::lane_spans(d.len(), n_chunks);
        let chunks: Vec<Chunk> = spans
            .iter()
            .map(|s| Chunk::new(s.len(), encode(&d[s.clone()], &table).unwrap()))
            .collect();
        let c = ChunkedContainer {
            dtype: Dtype::F32,
            params: QuantParams { q: 4, scale: 0.5, zero: 0 },
            orig_len: n_rows * 8,
            n_rows,
            nnz,
            alphabet,
            table,
            chunks,
        };
        (c, d)
    }

    #[test]
    fn dtyped_roundtrip_and_f32_header_unchanged() {
        let (c32, _) = sample_container(9, 2);
        let f32_bytes = c32.to_bytes();
        assert_eq!(f32_bytes[4], VERSION_V2, "f32 keeps the legacy version byte");
        for dtype in [Dtype::F16, Dtype::Bf16] {
            let (mut c, d) = sample_container(9, 2);
            c.dtype = dtype;
            let bytes = c.to_bytes();
            assert_eq!(bytes[4], VERSION_V2_DTYPED);
            assert_eq!(bytes[6], dtype.tag());
            assert_eq!(bytes.len(), f32_bytes.len() + 1);
            let back = ChunkedContainer::from_bytes(&bytes).unwrap();
            assert_eq!(back.dtype, dtype);
            assert_eq!(peek_dtype_and_len(&bytes).unwrap(), (dtype, c.orig_len));
            let mut decoded = Vec::new();
            for i in 0..back.chunks.len() {
                decoded.extend(back.decode_chunk(i).unwrap());
            }
            assert_eq!(decoded, d);
            // Dtyped truncations error cleanly too.
            for cut in 0..24 {
                assert!(ChunkedContainer::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
            }
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        for n_chunks in [1usize, 2, 5] {
            let (c, d) = sample_container(1, n_chunks);
            let bytes = c.to_bytes();
            let back = ChunkedContainer::from_bytes(&bytes).unwrap();
            assert_eq!(back.params, c.params);
            assert_eq!(back.orig_len, c.orig_len);
            assert_eq!(back.n_rows, c.n_rows);
            assert_eq!(back.nnz, c.nnz);
            assert_eq!(back.chunks.len(), n_chunks);
            let mut decoded = Vec::new();
            for i in 0..back.chunks.len() {
                decoded.extend(back.decode_chunk(i).unwrap());
            }
            assert_eq!(decoded, d, "chunks={n_chunks}");
        }
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let (c, _) = sample_container(2, 3);
        let bytes = c.to_bytes();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x5A;
            let rejected = match ChunkedContainer::from_bytes(&bad) {
                Err(_) => true,
                Ok(parsed) => (0..parsed.chunks.len())
                    .any(|k| parsed.decode_chunk(k).is_err()),
            };
            assert!(rejected, "flip at byte {i} undetected");
        }
    }

    #[test]
    fn truncation_detected() {
        let (c, _) = sample_container(3, 2);
        let bytes = c.to_bytes();
        for cut in [0, 1, 4, 10, bytes.len() / 2, bytes.len() - 1] {
            assert!(ChunkedContainer::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn partial_decode_ignores_other_chunks() {
        // Corrupting chunk 2's payload must not stop chunk 0 from
        // decoding — the streaming property the format exists for.
        let (c, d) = sample_container(4, 3);
        let mut bytes = c.to_bytes();
        let last = bytes.len() - 1; // inside the final chunk's payload
        bytes[last] ^= 0xFF;
        let parsed = ChunkedContainer::from_bytes(&bytes).unwrap();
        let first = parsed.decode_chunk(0).unwrap();
        assert_eq!(first, d[..first.len()].to_vec());
        assert!(parsed.decode_chunk(2).is_err());
    }

    #[test]
    fn chunk_index_out_of_range_is_invalid() {
        let (c, _) = sample_container(5, 2);
        assert!(c.decode_chunk(9).is_err());
    }
}
