//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json`.
//! * [`executor`] — thin wrapper over the `xla` crate: HLO-text load,
//!   compile, literal marshalling, tuple-output execution.
//! * [`pool`] — compile-once executable cache (compilation is tens of
//!   milliseconds to seconds; the serving path must never recompile).
//! * [`split_model`] — typed head/tail pairs for vision and LM models,
//!   including the quantized (Pallas epilogue/prologue) and raw float
//!   variants.
//! * [`registry`] — the signed, content-addressed deployment path:
//!   chunked artifacts with streaming SHA-256 verification, signed
//!   manifests binding halves + serving params + a monotonic
//!   `model_version`, and the atomic hot-swap slot.

pub mod executor;
pub mod manifest;
pub mod pool;
pub mod registry;
pub mod split_model;
pub mod xla_stub;

pub use executor::{Engine, Executable};
pub use manifest::{LmEntry, Manifest, SplitEntry, VisionEntry};
pub use pool::ExecPool;
pub use registry::{ChunkStore, HmacSha256Signer, ModelSlot, RegistryManifest, SignedManifest};
pub use split_model::{LmSplitExec, VisionSplitExec};
