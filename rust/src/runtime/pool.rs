//! Compile-once executable cache.
//!
//! PJRT compilation of a head/tail artifact costs milliseconds-to-seconds;
//! the serving path must amortize it. The pool maps artifact-relative
//! paths to compiled executables, compiling lazily under a per-entry
//! lock so concurrent first-touch requests compile once.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::error::Result;

use super::executor::{Engine, Executable};

/// Lazy, thread-safe executable cache rooted at an artifact directory.
pub struct ExecPool {
    engine: Arc<Engine>,
    base_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl ExecPool {
    /// Create a pool over `base_dir` using `engine`.
    pub fn new(engine: Arc<Engine>, base_dir: impl Into<PathBuf>) -> Self {
        ExecPool { engine, base_dir: base_dir.into(), cache: Mutex::new(HashMap::new()) }
    }

    /// The PJRT engine.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Get (compiling if needed) the executable for a manifest-relative
    /// artifact path.
    pub fn get(&self, rel_path: &str) -> Result<Arc<Executable>> {
        // Fast path.
        if let Some(exe) = self.cache.lock().unwrap().get(rel_path) {
            return Ok(Arc::clone(exe));
        }
        // Compile outside the map lock so unrelated requests proceed;
        // a race compiles twice but installs once — acceptable for the
        // cold path and simpler than per-key locks.
        let exe = Arc::new(self.engine.load_hlo_text(self.base_dir.join(rel_path))?);
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.entry(rel_path.to_string()).or_insert_with(|| Arc::clone(&exe));
        Ok(Arc::clone(entry))
    }

    /// Number of compiled entries.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// True if nothing has been compiled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
