//! Typed head/tail execution for vision and LM split models.
//!
//! Wraps the raw executables with the quantization-parameter plumbing:
//! the quantized head returns `(symbols, scale, zero)` (the Pallas
//! epilogue's outputs), which map onto [`QuantParams`]; the quantized
//! tail takes the decoded symbols plus those parameters back.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::quant::QuantParams;

use super::executor::{lit_f32, lit_i32, lit_scalar_f32, to_f32s, to_i32s, to_scalar_f32};
use super::manifest::{ArtifactPaths, LmEntry, Manifest, SplitEntry, VisionEntry};
use super::pool::ExecPool;
use super::xla_stub as xla;

/// Convert head outputs `(sym i32[T], scale f32, zero f32)` into
/// `(Vec<u16>, QuantParams)`.
fn head_outputs_to_symbols(
    outs: &[xla::Literal],
    q: u8,
    expect_len: usize,
) -> Result<(Vec<u16>, QuantParams)> {
    if outs.len() != 3 {
        return Err(Error::runtime(format!("head returned {} outputs, expected 3", outs.len())));
    }
    let sym_i32 = to_i32s(&outs[0])?;
    if sym_i32.len() != expect_len {
        return Err(Error::runtime(format!(
            "head returned {} symbols, expected {expect_len}",
            sym_i32.len()
        )));
    }
    let scale = to_scalar_f32(&outs[1])?;
    let zero = to_scalar_f32(&outs[2])?;
    let params = QuantParams { q, scale, zero: zero as i32 };
    let max_sym = (1u32 << q) - 1;
    let mut symbols = Vec::with_capacity(sym_i32.len());
    for s in sym_i32 {
        if s < 0 || s as u32 > max_sym {
            return Err(Error::runtime(format!("head emitted symbol {s} outside Q={q}")));
        }
        symbols.push(s as u16);
    }
    Ok((symbols, params))
}

/// Compiled artifact set for one vision (model, dataset, split, batch).
pub struct VisionSplitExec {
    /// Manifest entry metadata.
    pub entry: VisionEntry,
    /// Split metadata.
    pub split: SplitEntry,
    head: Arc<super::Executable>,
    tail: Arc<super::Executable>,
    head_raw: Arc<super::Executable>,
    tail_raw: Arc<super::Executable>,
}

impl VisionSplitExec {
    /// Compile (or fetch cached) all four artifacts for a split.
    pub fn load(pool: &ExecPool, manifest: &Manifest, name: &str, sl: usize, batch: usize) -> Result<Self> {
        let entry = manifest.vision_entry(name)?.clone();
        let split = entry.split(sl, batch)?.clone();
        let ArtifactPaths { head, tail, head_raw, tail_raw } = split.artifacts.clone();
        Ok(VisionSplitExec {
            head: pool.get(&head)?,
            tail: pool.get(&tail)?,
            head_raw: pool.get(&head_raw)?,
            tail_raw: pool.get(&tail_raw)?,
            entry,
            split,
        })
    }

    fn input_dims(&self) -> Vec<i64> {
        let mut dims: Vec<i64> = self.entry.input_shape.iter().map(|&d| d as i64).collect();
        dims[0] = self.split.batch as i64;
        dims
    }

    /// Edge compute: image batch → quantized IF symbols + params.
    pub fn run_head(&self, x: &[f32], q: u8) -> Result<(Vec<u16>, QuantParams)> {
        let levels = ((1u32 << q) - 1) as f32;
        let outs = self.head.run(&[lit_f32(x, &self.input_dims())?, lit_scalar_f32(levels)])?;
        head_outputs_to_symbols(&outs, q, self.split.feature_len)
    }

    /// Cloud compute: symbols + params → logits (batch × classes).
    pub fn run_tail(&self, symbols: &[u16], params: &QuantParams) -> Result<Vec<f32>> {
        if symbols.len() != self.split.feature_len {
            return Err(Error::invalid(format!(
                "{} symbols, artifact expects {}",
                symbols.len(),
                self.split.feature_len
            )));
        }
        let sym_i32: Vec<i32> = symbols.iter().map(|&s| s as i32).collect();
        let outs = self.tail.run(&[
            lit_i32(&sym_i32, &[symbols.len() as i64])?,
            lit_scalar_f32(params.scale),
            lit_scalar_f32(params.zero as f32),
        ])?;
        to_f32s(&outs[0])
    }

    /// Uncompressed baseline: image batch → float IF.
    pub fn run_head_raw(&self, x: &[f32]) -> Result<Vec<f32>> {
        let outs = self.head_raw.run(&[lit_f32(x, &self.input_dims())?])?;
        to_f32s(&outs[0])
    }

    /// Uncompressed baseline: float IF → logits.
    pub fn run_tail_raw(&self, feat: &[f32]) -> Result<Vec<f32>> {
        let outs = self.tail_raw.run(&[lit_f32(feat, &[feat.len() as i64])?])?;
        to_f32s(&outs[0])
    }
}

/// Compiled artifact set for one LM size.
pub struct LmSplitExec {
    /// Manifest entry metadata.
    pub entry: LmEntry,
    head: Arc<super::Executable>,
    tail: Arc<super::Executable>,
    head_raw: Arc<super::Executable>,
    tail_raw: Arc<super::Executable>,
}

impl LmSplitExec {
    /// Compile (or fetch cached) the LM artifacts.
    pub fn load(pool: &ExecPool, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.lm_entry(name)?.clone();
        let ArtifactPaths { head, tail, head_raw, tail_raw } = entry.artifacts.clone();
        Ok(LmSplitExec {
            head: pool.get(&head)?,
            tail: pool.get(&tail)?,
            head_raw: pool.get(&head_raw)?,
            tail_raw: pool.get(&tail_raw)?,
            entry,
        })
    }

    fn tok_dims(&self) -> [i64; 2] {
        [self.entry.batch as i64, self.entry.seq_len as i64]
    }

    /// Edge compute: token batch (n_choices × seq_len) → quantized
    /// hidden-state symbols + params.
    pub fn run_head(&self, tokens: &[i32], q: u8) -> Result<(Vec<u16>, QuantParams)> {
        let levels = ((1u32 << q) - 1) as f32;
        let outs = self.head.run(&[lit_i32(tokens, &self.tok_dims())?, lit_scalar_f32(levels)])?;
        head_outputs_to_symbols(&outs, q, self.entry.hidden_len)
    }

    /// Cloud compute: symbols + params → logits (batch × seq × vocab).
    pub fn run_tail(&self, symbols: &[u16], params: &QuantParams) -> Result<Vec<f32>> {
        let sym_i32: Vec<i32> = symbols.iter().map(|&s| s as i32).collect();
        let outs = self.tail.run(&[
            lit_i32(&sym_i32, &[symbols.len() as i64])?,
            lit_scalar_f32(params.scale),
            lit_scalar_f32(params.zero as f32),
        ])?;
        to_f32s(&outs[0])
    }

    /// Uncompressed baseline head.
    pub fn run_head_raw(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let outs = self.head_raw.run(&[lit_i32(tokens, &self.tok_dims())?])?;
        to_f32s(&outs[0])
    }

    /// Uncompressed baseline tail.
    pub fn run_tail_raw(&self, hidden: &[f32]) -> Result<Vec<f32>> {
        let outs = self.tail_raw.run(&[lit_f32(hidden, &[hidden.len() as i64])?])?;
        to_f32s(&outs[0])
    }
}
