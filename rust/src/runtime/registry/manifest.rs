//! The registry's deployable unit: a signed manifest binding the model
//! halves, the serving parameters, and a monotonic `model_version`.
//!
//! Two layers:
//!
//! * [`RegistryManifest`] — the inner document: model name, version,
//!   [`DeployParams`] (the `EdgeConfig`-shaped serving knobs both halves
//!   were exported for), and one [`ArtifactDescriptor`] per half listing
//!   the content-addressed chunks.
//! * [`SignedManifest`] — the on-disk wrapper `{algo, key_id,
//!   signature, manifest}`. The inner document travels as an **embedded
//!   JSON string** and the HMAC covers exactly those raw string bytes,
//!   so verification never depends on re-serializing JSON canonically —
//!   what was signed is byte-for-byte what is checked.

use crate::error::{Error, Result};
use crate::runtime::registry::signer::Signer;
use crate::util::json::{self, ObjBuilder, Value};
use crate::util::sha256;

/// Serving parameters a (head, tail) pair was exported for. Mirrors the
/// `EdgeConfig` knobs that change the wire format or the tensor shapes;
/// a fetched deployment reconstructs its edge/cloud config from these.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployParams {
    /// Split layer index.
    pub sl: usize,
    /// Batch size the halves were lowered for.
    pub batch: usize,
    /// AIQ bit-width `Q`.
    pub q: u8,
    /// rANS lanes.
    pub lanes: usize,
    /// Interleaved states per lane (1 = v1 scalar layout).
    pub states: usize,
    /// Feature dtype on the wire: `"f32"`, `"f16"` or `"bf16"`.
    pub dtype: String,
}

impl DeployParams {
    /// Paper-default parameters at bit-width `q`.
    pub fn paper(q: u8) -> Self {
        DeployParams { sl: 0, batch: 1, q, lanes: 8, states: 1, dtype: "f32".into() }
    }

    fn to_value(&self) -> Value {
        ObjBuilder::new()
            .field("sl", self.sl)
            .field("batch", self.batch)
            .field("q", self.q as usize)
            .field("lanes", self.lanes)
            .field("states", self.states)
            .field("dtype", self.dtype.as_str())
            .build()
    }

    fn from_value(v: &Value) -> Result<Self> {
        let q = v.usize_field("q")?;
        if q == 0 || q > 16 {
            return Err(Error::corrupt(format!("deploy params: Q={q} out of range 1..=16")));
        }
        Ok(DeployParams {
            sl: v.usize_field("sl")?,
            batch: v.usize_field("batch")?,
            q: q as u8,
            lanes: v.usize_field("lanes")?,
            states: v.usize_field("states")?,
            dtype: v.str_field("dtype")?.to_string(),
        })
    }
}

/// One content-addressed chunk of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRef {
    /// Payload length in bytes.
    pub len: u64,
    /// Lowercase hex SHA-256 of the payload (also its store address).
    pub sha256: String,
}

/// A whole model half: total length, whole-artifact digest, and the
/// ordered chunk list. The double digesting (per chunk + whole) means a
/// fetch rejects a corrupt chunk *before* requesting the next one and
/// still proves end-to-end integrity of the reassembled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactDescriptor {
    pub len: u64,
    pub sha256: String,
    pub chunks: Vec<ChunkRef>,
}

impl ArtifactDescriptor {
    /// Parse the hex digest, rejecting malformed addresses loudly.
    pub fn digest(&self) -> Result<[u8; 32]> {
        parse_digest(&self.sha256, "artifact digest")
    }

    fn to_value(&self) -> Value {
        let chunks: Vec<Value> = self
            .chunks
            .iter()
            .map(|c| {
                ObjBuilder::new()
                    .field("len", c.len as usize)
                    .field("sha256", c.sha256.as_str())
                    .build()
            })
            .collect();
        ObjBuilder::new()
            .field("len", self.len as usize)
            .field("sha256", self.sha256.as_str())
            .field("chunks", chunks)
            .build()
    }

    fn from_value(v: &Value) -> Result<Self> {
        let chunks = v
            .req("chunks")?
            .as_arr()
            .ok_or_else(|| Error::corrupt("artifact descriptor: 'chunks' is not an array"))?
            .iter()
            .map(|c| {
                Ok(ChunkRef {
                    len: c.usize_field("len")? as u64,
                    sha256: c.str_field("sha256")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let desc = ArtifactDescriptor {
            len: v.usize_field("len")? as u64,
            sha256: v.str_field("sha256")?.to_string(),
            chunks,
        };
        let sum: u64 = desc.chunks.iter().map(|c| c.len).sum();
        if sum != desc.len {
            return Err(Error::corrupt(format!(
                "artifact descriptor: chunk lengths sum to {sum}, artifact says {}",
                desc.len
            )));
        }
        desc.digest()?;
        for c in &desc.chunks {
            parse_digest(&c.sha256, "chunk digest")?;
        }
        Ok(desc)
    }
}

/// Parse a 64-hex-char SHA-256 digest field.
pub fn parse_digest(hex: &str, what: &str) -> Result<[u8; 32]> {
    let bytes = sha256::from_hex(hex)
        .filter(|b| b.len() == 32)
        .ok_or_else(|| Error::corrupt(format!("{what}: malformed sha256 hex '{hex}'")))?;
    let mut out = [0u8; 32];
    out.copy_from_slice(&bytes);
    Ok(out)
}

/// The inner (signed) manifest document.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistryManifest {
    pub model: String,
    /// Monotonically increasing deployment version; the protocol's
    /// `ModelVersion` handshake field carries this number.
    pub model_version: u64,
    pub deploy: DeployParams,
    pub head: ArtifactDescriptor,
    pub tail: ArtifactDescriptor,
}

/// Registry manifest format version (independent of the artifact
/// `manifest.json` loaded by [`crate::runtime::Manifest`]).
pub const REGISTRY_FORMAT: usize = 1;

impl RegistryManifest {
    /// Serialize to the canonical-enough JSON text that gets signed.
    /// Only this exact string is ever verified, so writer stability
    /// across versions is a non-goal by design.
    pub fn to_json_text(&self) -> String {
        ObjBuilder::new()
            .field("format", REGISTRY_FORMAT)
            .field("model", self.model.as_str())
            .field("model_version", self.model_version as usize)
            .field("deploy", self.deploy.to_value())
            .field("head", self.head.to_value())
            .field("tail", self.tail.to_value())
            .build()
            .to_string_compact()
    }

    /// Every chunk across both halves in fetch order (head, then
    /// tail) — the unit the delta planner diffs over.
    pub fn all_chunks(&self) -> impl Iterator<Item = &ChunkRef> {
        self.head.chunks.iter().chain(self.tail.chunks.iter())
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = json::parse(text)
            .map_err(|e| Error::corrupt(format!("registry manifest: {e}")))?;
        let format = v.usize_field("format")?;
        if format != REGISTRY_FORMAT {
            return Err(Error::corrupt(format!(
                "registry manifest format {format} unsupported (want {REGISTRY_FORMAT})"
            )));
        }
        let version = v.f64_field("model_version")?;
        if version < 1.0 || version.fract() != 0.0 {
            return Err(Error::corrupt(format!(
                "registry manifest: model_version {version} must be a positive integer"
            )));
        }
        Ok(RegistryManifest {
            model: v.str_field("model")?.to_string(),
            model_version: version as u64,
            deploy: DeployParams::from_value(v.req("deploy")?)?,
            head: ArtifactDescriptor::from_value(v.req("head")?)?,
            tail: ArtifactDescriptor::from_value(v.req("tail")?)?,
        })
    }
}

/// The signed on-disk wrapper. `manifest_text` is the exact byte string
/// the signature covers.
#[derive(Debug, Clone)]
pub struct SignedManifest {
    pub algo: String,
    pub key_id: String,
    pub signature: Vec<u8>,
    pub manifest_text: String,
}

impl SignedManifest {
    /// Sign `manifest` with `signer`, producing the wrapper to store.
    pub fn seal(manifest: &RegistryManifest, signer: &dyn Signer) -> Self {
        let text = manifest.to_json_text();
        SignedManifest {
            algo: signer.algo().to_string(),
            key_id: signer.key_id().to_string(),
            signature: signer.sign(text.as_bytes()),
            manifest_text: text,
        }
    }

    pub fn to_json_text(&self) -> String {
        ObjBuilder::new()
            .field("algo", self.algo.as_str())
            .field("key_id", self.key_id.as_str())
            .field("signature", sha256::to_hex(&self.signature))
            .field("manifest", self.manifest_text.as_str())
            .build()
            .to_string_compact()
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = json::parse(text)
            .map_err(|e| Error::corrupt(format!("signed manifest: {e}")))?;
        let sig_hex = v.str_field("signature")?;
        let signature = sha256::from_hex(sig_hex).ok_or_else(|| {
            Error::corrupt(format!("signed manifest: malformed signature hex '{sig_hex}'"))
        })?;
        Ok(SignedManifest {
            algo: v.str_field("algo")?.to_string(),
            key_id: v.str_field("key_id")?.to_string(),
            signature,
            manifest_text: v.str_field("manifest")?.to_string(),
        })
    }

    /// Check scheme, key id and signature, then parse the inner
    /// document. Every failure is a fatal typed error — an unsigned or
    /// tampered manifest must never be deployable.
    pub fn verify(&self, signer: &dyn Signer) -> Result<RegistryManifest> {
        if self.algo != signer.algo() {
            return Err(Error::corrupt(format!(
                "signed manifest: algo '{}' does not match verifier '{}'",
                self.algo,
                signer.algo()
            )));
        }
        if self.key_id != signer.key_id() {
            return Err(Error::corrupt(format!(
                "signed manifest: key_id '{}' does not match verifier key '{}'",
                self.key_id,
                signer.key_id()
            )));
        }
        if !signer.verify(self.manifest_text.as_bytes(), &self.signature) {
            return Err(Error::corrupt(
                "signed manifest: signature verification failed (tampered or wrong key)",
            ));
        }
        RegistryManifest::from_json_text(&self.manifest_text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::signer::HmacSha256Signer;

    fn sample() -> RegistryManifest {
        let chunk = |len: u64, seed: u8| ChunkRef {
            len,
            sha256: sha256::to_hex(&sha256::hash(&[seed])),
        };
        RegistryManifest {
            model: "resnet50".into(),
            model_version: 3,
            deploy: DeployParams {
                sl: 2,
                batch: 8,
                q: 4,
                lanes: 8,
                states: 4,
                dtype: "bf16".into(),
            },
            head: ArtifactDescriptor {
                len: 300,
                sha256: sha256::to_hex(&sha256::hash(b"head")),
                chunks: vec![chunk(100, 1), chunk(200, 2)],
            },
            tail: ArtifactDescriptor {
                len: 50,
                sha256: sha256::to_hex(&sha256::hash(b"tail")),
                chunks: vec![chunk(50, 3)],
            },
        }
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let m = sample();
        let back = RegistryManifest::from_json_text(&m.to_json_text()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn seal_verify_roundtrip() {
        let signer = HmacSha256Signer::new(b"k".to_vec(), "fleet-1");
        let sealed = SignedManifest::seal(&sample(), &signer);
        let wire = sealed.to_json_text();
        let back = SignedManifest::from_json_text(&wire).unwrap();
        assert_eq!(back.verify(&signer).unwrap(), sample());
    }

    #[test]
    fn every_wrapper_tamper_is_fatal() {
        let signer = HmacSha256Signer::new(b"k".to_vec(), "fleet-1");
        let sealed = SignedManifest::seal(&sample(), &signer);

        // Flipped manifest byte (version 3 -> 4 inside the signed text).
        let mut t = sealed.clone();
        t.manifest_text = t.manifest_text.replace("\"model_version\":3", "\"model_version\":4");
        assert_ne!(t.manifest_text, sealed.manifest_text);
        let err = t.verify(&signer).unwrap_err();
        assert!(matches!(err, Error::Corrupt { .. }) && !err.is_retryable(), "{err}");

        // Flipped signature bit.
        let mut t = sealed.clone();
        t.signature[0] ^= 0x80;
        assert!(t.verify(&signer).is_err());

        // Wrong key.
        let other = HmacSha256Signer::new(b"other".to_vec(), "fleet-1");
        assert!(sealed.verify(&other).is_err());

        // Wrong key id / algo labels.
        let mut t = sealed.clone();
        t.key_id = "rotated".into();
        assert!(t.verify(&signer).is_err());
        let mut t = sealed.clone();
        t.algo = "ed25519".into();
        assert!(t.verify(&signer).is_err());
    }

    #[test]
    fn malformed_documents_are_typed_errors() {
        assert!(RegistryManifest::from_json_text("{not json").is_err());
        assert!(SignedManifest::from_json_text("[1,2]").is_err());
        // Version 0 and fractional versions are rejected.
        let m = sample();
        let t = m.to_json_text().replace("\"model_version\":3", "\"model_version\":0");
        assert!(RegistryManifest::from_json_text(&t).is_err());
        // Chunk lengths must sum to the artifact length.
        let t = m.to_json_text().replace("\"len\":300", "\"len\":301");
        assert!(RegistryManifest::from_json_text(&t).is_err());
        // Malformed digest hex.
        let t = m.to_json_text().replace(&m.head.sha256, "zz");
        assert!(RegistryManifest::from_json_text(&t).is_err());
    }
}
