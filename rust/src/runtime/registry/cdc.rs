//! Content-defined chunking (gear rolling hash).
//!
//! Fixed-size chunking ([`DEFAULT_CHUNK_LEN`](super::DEFAULT_CHUNK_LEN))
//! is simple but brittle across versions: one early insertion in a
//! weight file shifts every later boundary, so chunks that are
//! byte-identical in content no longer align and the delta planner sees
//! a near-total rewrite. Content-defined chunking cuts where the *data*
//! says to cut — a boundary lands wherever the rolling hash of the last
//! few bytes matches a mask — so after an insertion the boundaries
//! resynchronize within roughly one chunk and the unchanged tail dedups
//! again.
//!
//! The rolling hash is the "gear" construction: one table lookup and a
//! shift per byte (`h = (h << 1) + GEAR[b]`), with the boundary test
//! `h & mask == 0` applied only once the chunk has reached `min_len`.
//! The 256-entry gear table is derived deterministically from the
//! repo's own seeded xoshiro256++ PRNG, so chunk boundaries — and
//! therefore chunk *addresses* — are identical across builds, machines,
//! and sessions. That determinism is load-bearing: two registries that
//! chunk the same artifact must agree on every address or delta sync
//! degenerates to a full fetch.

use std::sync::OnceLock;

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Seed for the deterministic gear table. Changing it changes every
/// CDC chunk address ever produced; treat it like a wire constant.
const GEAR_SEED: u64 = 0x5243_4443_4745_4152; // "RCDCGEAR"

fn gear_table() -> &'static [u64; 256] {
    static TABLE: OnceLock<[u64; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut rng = Rng::new(GEAR_SEED);
        let mut t = [0u64; 256];
        for slot in t.iter_mut() {
            *slot = rng.next_u64();
        }
        t
    })
}

/// Boundary policy for content-defined chunking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CdcParams {
    /// No boundary before this many bytes (also the floor for the final
    /// chunk's predecessors; the final chunk itself may be shorter).
    pub min_len: usize,
    /// Boundary mask: a cut lands where `hash & mask == 0`, so the
    /// expected chunk length is roughly `min_len + 1/density(mask)`.
    /// Must be one less than a power of two.
    pub mask: u64,
    /// Hard cap: force a boundary at this many bytes even if the hash
    /// never matches (pathological inputs, e.g. all-zero weights).
    pub max_len: usize,
}

impl CdcParams {
    /// Params targeting an average chunk length of `avg` bytes (must be
    /// a power of two ≥ 256): min = avg/4, mask = avg-1, max = 4·avg.
    pub fn with_avg(avg: usize) -> Result<Self> {
        if !avg.is_power_of_two() || avg < 256 {
            return Err(Error::invalid(format!(
                "cdc avg chunk length must be a power of two >= 256, got {avg}"
            )));
        }
        Ok(CdcParams { min_len: avg / 4, mask: (avg - 1) as u64, max_len: avg * 4 })
    }

    fn validate(&self) -> Result<()> {
        if self.min_len == 0 || self.max_len < self.min_len {
            return Err(Error::invalid(format!(
                "cdc params invalid: min_len {} max_len {}",
                self.min_len, self.max_len
            )));
        }
        if self.mask.wrapping_add(1) & self.mask != 0 {
            return Err(Error::invalid(format!(
                "cdc mask {:#x} must be one less than a power of two",
                self.mask
            )));
        }
        Ok(())
    }
}

impl Default for CdcParams {
    /// 256 KiB average: min 64 KiB, max 1 MiB — small enough that one
    /// flipped region re-fetches little, large enough to amortize the
    /// 12-byte frame + one store object per chunk.
    fn default() -> Self {
        CdcParams::with_avg(1 << 18).expect("default cdc params are valid")
    }
}

/// Split `bytes` into content-defined chunk lengths. The lengths sum to
/// `bytes.len()` exactly; an empty input yields one empty chunk so the
/// descriptor shape matches [`put_artifact`]'s empty-artifact contract.
///
/// [`put_artifact`]: super::ChunkStore::put_artifact
pub fn split(bytes: &[u8], params: &CdcParams) -> Result<Vec<usize>> {
    params.validate()?;
    let gear = gear_table();
    let mut lens = Vec::new();
    let mut start = 0usize;
    while start < bytes.len() {
        let remain = &bytes[start..];
        let mut cut = remain.len().min(params.max_len);
        if remain.len() > params.min_len {
            let mut h: u64 = 0;
            let scan_end = remain.len().min(params.max_len);
            for (i, &b) in remain[..scan_end].iter().enumerate() {
                h = (h << 1).wrapping_add(gear[b as usize]);
                if i + 1 >= params.min_len && h & params.mask == 0 {
                    cut = i + 1;
                    break;
                }
            }
        }
        lens.push(cut);
        start += cut;
    }
    if lens.is_empty() {
        lens.push(0);
    }
    Ok(lens)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic(seed: u64, n: usize) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.next_u64() & 0xff) as u8).collect()
    }

    #[test]
    fn lengths_partition_the_input() {
        let params = CdcParams::with_avg(1 << 10).unwrap();
        for n in [0usize, 1, 255, 4096, 100_000] {
            let data = synthetic(7 + n as u64, n);
            let lens = split(&data, &params).unwrap();
            assert_eq!(lens.iter().sum::<usize>(), n, "n={n}");
            assert!(!lens.is_empty());
            for (i, &l) in lens.iter().enumerate() {
                if n == 0 {
                    assert_eq!(l, 0);
                    continue;
                }
                assert!(l <= params.max_len, "chunk {i} over max: {l}");
                assert!(l > 0, "zero-length chunk {i} in non-empty input");
                if i + 1 < lens.len() {
                    assert!(l >= params.min_len, "non-final chunk {i} under min: {l}");
                }
            }
        }
    }

    #[test]
    fn boundaries_are_deterministic() {
        let data = synthetic(42, 200_000);
        let params = CdcParams::default();
        assert_eq!(split(&data, &params).unwrap(), split(&data, &params).unwrap());
    }

    #[test]
    fn max_len_forces_cut_on_pathological_input() {
        let data = vec![0u8; 1 << 20];
        let params = CdcParams::with_avg(1 << 12).unwrap();
        let lens = split(&data, &params).unwrap();
        assert!(lens.iter().all(|&l| l <= params.max_len));
        assert!(lens.len() >= (1 << 20) / params.max_len);
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(CdcParams::with_avg(300).is_err());
        assert!(CdcParams::with_avg(128).is_err());
        let bad = CdcParams { min_len: 0, mask: 0xff, max_len: 10 };
        assert!(split(b"abc", &bad).is_err());
        let bad_mask = CdcParams { min_len: 1, mask: 0xfe, max_len: 10 };
        assert!(split(b"abc", &bad_mask).is_err());
    }

    #[test]
    fn early_insertion_resynchronizes_boundaries() {
        // The CDC promise: insert a few bytes near the front and the
        // chunking realigns, so most tail chunk payloads are identical.
        let base = synthetic(99, 1 << 18);
        let mut edited = base.clone();
        for (i, b) in synthetic(100, 13).into_iter().enumerate() {
            edited.insert(1000 + i, b);
        }
        let params = CdcParams::with_avg(1 << 12).unwrap();
        let cuts = |d: &[u8]| -> Vec<Vec<u8>> {
            let mut out = Vec::new();
            let mut off = 0;
            for l in split(d, &params).unwrap() {
                out.push(d[off..off + l].to_vec());
                off += l;
            }
            out
        };
        let a = cuts(&base);
        let b = cuts(&edited);
        let a_set: std::collections::HashSet<&Vec<u8>> = a.iter().collect();
        let shared = b.iter().filter(|c| a_set.contains(c)).count();
        assert!(
            shared * 2 > b.len(),
            "only {shared}/{} chunks survived a 13-byte early insertion",
            b.len()
        );
    }
}
