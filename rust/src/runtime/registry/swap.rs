//! Atomic hot-swap: staged load → verify → pointer flip → drain.
//!
//! [`SwapCell`] is a hand-rolled `ArcSwap` (a `Mutex<Arc<T>>` — the
//! offline container has no external crates): readers clone the `Arc`
//! under a short lock and then run lock-free on their snapshot, so an
//! in-flight request keeps serving the version it started on while a
//! swap lands. The old version drains naturally as those `Arc`s drop.
//!
//! [`ModelSlot`] layers the deployment state machine on top: versions
//! are strictly monotonic, and the smoke check runs on the **staged**
//! value *before* the flip — on any verification or smoke failure the
//! previous version simply keeps serving (rollback is the absence of a
//! flip, so there is no window where a bad model is live).

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::runtime::registry::manifest::DeployParams;

/// Atomic shared pointer with copy-on-swap semantics.
pub struct SwapCell<T> {
    cur: Mutex<Arc<T>>,
}

impl<T> SwapCell<T> {
    pub fn new(value: T) -> Self {
        SwapCell { cur: Mutex::new(Arc::new(value)) }
    }

    /// Snapshot the current value. The returned `Arc` stays valid (and
    /// the value it points to stays alive) across any number of
    /// subsequent swaps.
    pub fn load(&self) -> Arc<T> {
        Arc::clone(&self.cur.lock().unwrap())
    }

    /// Install `next`, returning the previous value.
    pub fn swap(&self, next: Arc<T>) -> Arc<T> {
        std::mem::replace(&mut *self.cur.lock().unwrap(), next)
    }
}

/// One model version plus its payload.
#[derive(Debug)]
pub struct Versioned<T> {
    /// Monotonic deployment version; 0 = unversioned (legacy serving,
    /// no skew checks on the wire).
    pub version: u64,
    pub value: T,
}

/// The serving slot a node reads its active model from.
pub struct ModelSlot<T> {
    cell: SwapCell<Versioned<T>>,
}

impl<T> ModelSlot<T> {
    pub fn new(version: u64, value: T) -> Self {
        ModelSlot { cell: SwapCell::new(Versioned { version, value }) }
    }

    /// Snapshot the active deployment.
    pub fn active(&self) -> Arc<Versioned<T>> {
        self.cell.load()
    }

    /// Active version (0 = unversioned).
    pub fn version(&self) -> u64 {
        self.cell.load().version
    }

    /// Stage → verify → flip. `smoke` runs against the staged value
    /// while the old version is still serving; only a clean result
    /// flips the pointer. Returns the displaced deployment on success.
    ///
    /// Failure modes (all leave the prior version active):
    /// * non-monotonic `version` → [`Error::VersionSkew`];
    /// * `smoke` error → propagated as-is (rollback by construction).
    pub fn hot_swap(
        &self,
        version: u64,
        staged: T,
        smoke: impl FnOnce(&T) -> Result<()>,
    ) -> Result<Arc<Versioned<T>>> {
        let active = self.version();
        if version <= active {
            return Err(Error::version_skew(
                active,
                version,
                format!("hot-swap rejected: staged version {version} is not above active {active}"),
            ));
        }
        smoke(&staged)?;
        Ok(self.cell.swap(Arc::new(Versioned { version, value: staged })))
    }
}

/// The registry's standard smoke check: a synthetic-tensor compress →
/// decompress roundtrip at the deployment's exact codec parameters
/// (Q, lanes, states). Runs without any model artifacts, so a node can
/// gate a swap even in the offline container; a corrupt codec config
/// (or a build that cannot decode its own output) fails loudly here
/// instead of serving garbage.
pub fn smoke_decode(deploy: &DeployParams) -> Result<()> {
    use crate::pipeline::{self, PipelineConfig};

    let cfg = PipelineConfig {
        q: deploy.q,
        lanes: deploy.lanes.max(1),
        parallel: false,
        ..PipelineConfig::paper(deploy.q)
    }
    .with_states(deploy.states.max(1));
    let mut rng = crate::util::prng::Rng::new(0x5310_7E57 ^ u64::from(deploy.q));
    let data: Vec<f32> = (0..2048)
        .map(|_| if rng.next_f64() < 0.4 { 0.0 } else { rng.normal().abs() as f32 })
        .collect();
    let (bytes, _) = pipeline::compress(&data, &cfg)
        .map_err(|e| Error::runtime(format!("smoke compress failed: {e}")))?;
    let (symbols, params) = pipeline::decompress_to_symbols(&bytes)
        .map_err(|e| Error::runtime(format!("smoke decode failed: {e}")))?;
    if symbols.is_empty() {
        return Err(Error::runtime("smoke decode returned no symbols"));
    }
    // Reconstruction must be finite everywhere.
    let back = crate::quant::dequantize(&symbols, &params);
    if back.iter().any(|x| !x.is_finite()) {
        return Err(Error::runtime("smoke decode produced non-finite values"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn swap_cell_snapshots_survive_swaps() {
        let cell = SwapCell::new(1u32);
        let old = cell.load();
        let displaced = cell.swap(Arc::new(2));
        assert_eq!(*displaced, 1);
        assert_eq!(*old, 1, "pre-swap snapshot still serves the old value");
        assert_eq!(*cell.load(), 2);
    }

    #[test]
    fn hot_swap_enforces_monotonic_versions() {
        let slot = ModelSlot::new(3, "v3");
        let err = slot.hot_swap(3, "again", |_| Ok(())).unwrap_err();
        assert!(matches!(err, Error::VersionSkew { .. }), "{err}");
        assert!(!err.is_retryable());
        let err = slot.hot_swap(2, "older", |_| Ok(())).unwrap_err();
        assert!(matches!(err, Error::VersionSkew { .. }), "{err}");
        assert_eq!(slot.version(), 3, "failed swaps leave the active version");
        slot.hot_swap(4, "v4", |_| Ok(())).unwrap();
        assert_eq!(slot.version(), 4);
        assert_eq!(slot.active().value, "v4");
    }

    #[test]
    fn smoke_failure_rolls_back_to_prior() {
        let slot = ModelSlot::new(1, 10u64);
        let err = slot
            .hot_swap(2, 20, |_| Err(Error::corrupt("staged model failed smoke decode")))
            .unwrap_err();
        assert!(err.to_string().contains("smoke decode"), "{err}");
        assert_eq!(slot.version(), 1, "prior version restored (never left)");
        assert_eq!(slot.active().value, 10);
    }

    #[test]
    fn smoke_runs_before_flip() {
        let slot = ModelSlot::new(1, 0u8);
        let observed = AtomicUsize::new(0);
        slot.hot_swap(2, 7, |staged| {
            // During the smoke the slot still serves version 1.
            observed.store(slot.version() as usize, Ordering::Relaxed);
            assert_eq!(*staged, 7);
            Ok(())
        })
        .unwrap();
        assert_eq!(observed.load(Ordering::Relaxed), 1);
        assert_eq!(slot.version(), 2);
    }

    #[test]
    fn smoke_decode_passes_paper_configs() {
        for (q, states) in [(2u8, 1usize), (4, 4), (8, 8)] {
            let mut d = DeployParams::paper(q);
            d.states = states;
            smoke_decode(&d).unwrap_or_else(|e| panic!("q={q} states={states}: {e}"));
        }
    }
}
