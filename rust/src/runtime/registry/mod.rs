//! Signed, content-addressed split-model registry.
//!
//! Production split computing has a fleet-trust problem: thousands of
//! edges must fetch the right model half, prove every byte of it, and
//! hot-swap to new versions without dropping in-flight requests. This
//! module is that deployment path, built failure-first like the PR 7
//! request path:
//!
//! * [`store::ChunkStore`] — content-addressed chunk objects
//!   (`objects/<aa>/<sha256>.chunk`, CRC-framed) plus signed manifests
//!   (`manifests/<model>/<version>.json`). Fetches verify
//!   **incrementally**: each chunk's CRC and SHA-256 address before the
//!   next chunk is opened ([`sha256_reader::Sha256Reader`] hashes the
//!   bytes as they stream), then the whole-artifact digest.
//! * [`manifest::SignedManifest`] — the deployable unit: model halves +
//!   [`manifest::DeployParams`] (dtype, Q, lanes, states) + a monotonic
//!   `model_version`, HMAC-signed over the exact manifest bytes behind
//!   the pluggable [`signer::Signer`] trait.
//! * [`swap::ModelSlot`] — staged load → smoke verify → atomic pointer
//!   flip → old version drained; rollback is the absence of a flip.
//! * [`delta`] — version N → N+1 distribution as a chunk-set
//!   difference: [`delta::DeltaPlan`] diffs two manifests,
//!   [`delta::sync_deployment`] consults the local store before pulling
//!   each missing chunk from a [`delta::ChunkSource`] and records
//!   durable progress in a sidecar so an interrupted fetch resumes from
//!   verified partial state.
//! * [`cdc`] — content-defined chunking (gear rolling hash) so an early
//!   insertion in a weight file shifts only nearby chunk boundaries
//!   instead of rewriting every later address.
//!
//! The wire side lives in `coordinator`: frames carry an optional
//! `ModelVersion` header, and a cloud serving a different version
//! answers `VersionSkew`, which the edge treats as fatal-until-resync
//! (re-fetch from the registry, never silently decode with the wrong
//! tail). The tamper wall in `rust/tests/registry_tamper.rs` asserts
//! every flipped bit and every mismatched pairing is a loud typed
//! error.

pub mod cdc;
pub mod delta;
pub mod manifest;
pub mod sha256_reader;
pub mod signer;
pub mod store;
pub mod swap;

pub use cdc::CdcParams;
pub use delta::{
    sync_artifact, sync_deployment, ChunkSource, DeltaPlan, StoreSource, SyncOptions, SyncReport,
};
pub use manifest::{
    ArtifactDescriptor, ChunkRef, DeployParams, RegistryManifest, SignedManifest,
};
pub use sha256_reader::Sha256Reader;
pub use signer::{hmac_sha256, HmacSha256Signer, Signer};
pub use store::{ChunkStore, Deployment, DEFAULT_CHUNK_LEN};
pub use swap::{smoke_decode, ModelSlot, SwapCell, Versioned};
