//! Manifest signing: a [`Signer`] seam with a hand-rolled HMAC-SHA256
//! implementation for the offline container.
//!
//! The registry never trusts an unsigned manifest. The trait keeps the
//! scheme pluggable — a production deployment would slot an Ed25519 (or
//! HSM-backed) signer behind the same three methods — while the default
//! [`HmacSha256Signer`] gives the tamper wall real cryptographic teeth
//! with zero dependencies: RFC 2104 HMAC over [`crate::util::sha256`],
//! pinned against the RFC 4231 test vectors below and differentially
//! against CPython's `hmac` module by `gen_golden.py`.

use crate::util::sha256::{self, Sha256};

/// HMAC-SHA256 block size in bytes (SHA-256 operates on 64-byte blocks).
const BLOCK: usize = 64;

/// RFC 2104 HMAC-SHA256. Keys longer than one block are hashed first;
/// shorter keys are zero-padded.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&sha256::hash(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Sha256::new();
    let ipad: Vec<u8> = k.iter().map(|b| b ^ 0x36).collect();
    inner.update(&ipad);
    inner.update(msg);
    let inner_digest = inner.finalize();
    let mut outer = Sha256::new();
    let opad: Vec<u8> = k.iter().map(|b| b ^ 0x5C).collect();
    outer.update(&opad);
    outer.update(&inner_digest);
    outer.finalize()
}

/// A manifest signing/verification scheme. Implementations must make
/// `verify` constant-time in the signature comparison (use
/// [`sha256::ct_eq`]) so tampered signatures cannot be searched
/// byte-by-byte.
pub trait Signer: Send + Sync {
    /// Scheme identifier recorded in the signed wrapper (e.g.
    /// `"hmac-sha256"`); verification rejects a wrapper whose `algo`
    /// does not match.
    fn algo(&self) -> &str;
    /// Key identifier recorded in the wrapper so a fleet can rotate
    /// keys; verification rejects a mismatching `key_id`.
    fn key_id(&self) -> &str;
    /// Sign raw message bytes.
    fn sign(&self, msg: &[u8]) -> Vec<u8>;
    /// Verify a signature over raw message bytes (constant-time).
    fn verify(&self, msg: &[u8], sig: &[u8]) -> bool;
}

/// Keyed HMAC-SHA256 signer: both ends of the fleet share `key`.
pub struct HmacSha256Signer {
    key: Vec<u8>,
    key_id: String,
}

impl HmacSha256Signer {
    pub fn new(key: impl Into<Vec<u8>>, key_id: impl Into<String>) -> Self {
        HmacSha256Signer { key: key.into(), key_id: key_id.into() }
    }
}

impl Signer for HmacSha256Signer {
    fn algo(&self) -> &str {
        "hmac-sha256"
    }

    fn key_id(&self) -> &str {
        &self.key_id
    }

    fn sign(&self, msg: &[u8]) -> Vec<u8> {
        hmac_sha256(&self.key, msg).to_vec()
    }

    fn verify(&self, msg: &[u8], sig: &[u8]) -> bool {
        sha256::ct_eq(&hmac_sha256(&self.key, msg), sig)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sha256::to_hex;

    /// RFC 4231 test cases 1 and 2 (also asserted executable by
    /// `gen_golden.py` against CPython's `hmac`).
    #[test]
    fn rfc4231_vectors() {
        assert_eq!(
            to_hex(&hmac_sha256(&[0x0B; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        assert_eq!(
            to_hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    /// Keys longer than the block size hash down first (RFC 4231 TC 6
    /// shape, value cross-checked by the gen_golden.py differential).
    #[test]
    fn long_key_hashes_first() {
        let long = vec![0xAA; 131];
        let hashed_key = crate::util::sha256::hash(&long);
        assert_eq!(hmac_sha256(&long, b"m"), hmac_sha256(&hashed_key, b"m"));
        // And a short key is NOT equivalent to its hash.
        assert_ne!(hmac_sha256(b"short", b"m"), hmac_sha256(&crate::util::sha256::hash(b"short"), b"m"));
    }

    #[test]
    fn signer_roundtrip_and_rejections() {
        let signer = HmacSha256Signer::new(b"fleet-key".to_vec(), "k1");
        let sig = signer.sign(b"manifest bytes");
        assert!(signer.verify(b"manifest bytes", &sig));
        assert!(!signer.verify(b"manifest bytez", &sig), "message tamper");
        let mut bad = sig.clone();
        bad[7] ^= 1;
        assert!(!signer.verify(b"manifest bytes", &bad), "signature tamper");
        let other = HmacSha256Signer::new(b"other-key".to_vec(), "k1");
        assert!(!other.verify(b"manifest bytes", &sig), "wrong key");
        assert!(!signer.verify(b"manifest bytes", &sig[..31]), "truncated signature");
    }
}
