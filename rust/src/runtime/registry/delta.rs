//! Delta-sync artifact distribution: chunk-set diffing, a
//! store-consulting fetch path, and durable resume.
//!
//! The registry gives every artifact a content-addressed chunk list,
//! which turns version N → N+1 distribution into a *set-difference*
//! problem: an edge holding v(N) already has most of v(N+1)'s chunks
//! (fine-tuned halves share the bulk of their weights), so a sync
//! should transfer only the missing addresses. Three layers:
//!
//! * [`DeltaPlan`] — pure planner: diff two manifests' chunk sets and
//!   report the missing addresses plus `delta_bytes` / `full_bytes`.
//! * [`ChunkSource`] — where missing chunks come from:
//!   [`StoreSource`] reads another on-disk registry (mirror /
//!   USB-sneakernet sync), [`WireSource`](crate::coordinator::WireSource)
//!   pulls them over a [`Session`](crate::coordinator::Session) with the
//!   tag 17–20 frames. Nothing a source returns is trusted: the signed
//!   manifest is verified against the local key, and every chunk
//!   payload is re-hashed against the address it was requested by.
//! * [`sync_deployment`] / [`sync_artifact`] — the fetch path. Each
//!   chunk is looked up in the local [`ChunkStore`] first (safe because
//!   dedup hits verify the on-disk object, see
//!   [`ChunkStore::put_chunk`]), fetched only when absent or invalid,
//!   and recorded in a sidecar (`state/<artifact-sha>.sync` under the
//!   registry root) after each verified write — so a fetch dropped
//!   mid-`Session` resumes from verified partial progress without
//!   re-downloading a single completed chunk.

use std::collections::HashSet;
use std::fs;
use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::runtime::registry::manifest::{
    ArtifactDescriptor, ChunkRef, RegistryManifest, SignedManifest,
};
use crate::runtime::registry::signer::Signer;
use crate::runtime::registry::store::{atomic_write, ChunkStore};
use crate::util::json::{self, ObjBuilder};
use crate::util::sha256;

/// The chunk-set difference between two versions of one model.
#[derive(Debug, Clone)]
pub struct DeltaPlan {
    /// Version the edge already holds.
    pub from_version: u64,
    /// Version being synced to.
    pub to_version: u64,
    /// Chunks of `to` absent from `from`, deduplicated by address, in
    /// fetch order (head chunks before tail chunks).
    pub missing: Vec<ChunkRef>,
    /// Bytes a delta fetch transfers (sum of `missing` lengths).
    pub delta_bytes: u64,
    /// Bytes a cold full fetch of `to` transfers (unique chunks only —
    /// even a full fetch never pulls one address twice).
    pub full_bytes: u64,
    /// Unique chunks in `to`.
    pub total_chunks: usize,
    /// Unique chunks of `to` already present in `from`.
    pub shared_chunks: usize,
}

impl DeltaPlan {
    /// Diff `to`'s chunk set against `from`'s.
    pub fn plan(from: &RegistryManifest, to: &RegistryManifest) -> DeltaPlan {
        let have: HashSet<&str> = from.all_chunks().map(|c| c.sha256.as_str()).collect();
        let mut seen: HashSet<&str> = HashSet::new();
        let mut missing = Vec::new();
        let mut delta_bytes = 0u64;
        let mut full_bytes = 0u64;
        let mut total_chunks = 0usize;
        let mut shared_chunks = 0usize;
        for chunk in to.all_chunks() {
            if !seen.insert(chunk.sha256.as_str()) {
                continue;
            }
            total_chunks += 1;
            full_bytes += chunk.len;
            if have.contains(chunk.sha256.as_str()) {
                shared_chunks += 1;
            } else {
                delta_bytes += chunk.len;
                missing.push(chunk.clone());
            }
        }
        DeltaPlan {
            from_version: from.model_version,
            to_version: to.model_version,
            missing,
            delta_bytes,
            full_bytes,
            total_chunks,
            shared_chunks,
        }
    }

    /// Bytes a delta fetch avoids relative to a cold full fetch.
    pub fn bytes_saved(&self) -> u64 {
        self.full_bytes - self.delta_bytes
    }

    /// One-line JSON summary (the CLI `registry delta` output).
    pub fn to_json(&self) -> String {
        ObjBuilder::new()
            .field("from_version", self.from_version as usize)
            .field("to_version", self.to_version as usize)
            .field("total_chunks", self.total_chunks)
            .field("shared_chunks", self.shared_chunks)
            .field("missing_chunks", self.missing.len())
            .field("delta_bytes", self.delta_bytes as usize)
            .field("full_bytes", self.full_bytes as usize)
            .field("bytes_saved", self.bytes_saved() as usize)
            .build()
            .to_string_compact()
    }
}

/// Where missing chunks come from. Implementations transport bytes;
/// they do not authenticate them — the sync path verifies everything
/// it receives against the signed manifest and the content addresses.
pub trait ChunkSource {
    /// The signed-manifest wrapper text for `model` at `version`
    /// (`0` = latest published).
    fn fetch_manifest(&mut self, model: &str, version: u64) -> Result<String>;
    /// One chunk payload by SHA-256 address.
    fn fetch_chunk(&mut self, sha256: &str) -> Result<Vec<u8>>;
}

/// A [`ChunkSource`] backed by another on-disk registry (a mirror
/// directory, a mounted drive). Chunks come out of the source store
/// fully verified — a corrupt mirror yields a typed error, not bytes.
pub struct StoreSource {
    store: ChunkStore,
}

impl StoreSource {
    pub fn open(root: impl Into<PathBuf>) -> Self {
        StoreSource { store: ChunkStore::open(root) }
    }
}

impl ChunkSource for StoreSource {
    fn fetch_manifest(&mut self, model: &str, version: u64) -> Result<String> {
        let slot = if version == 0 { None } else { Some(version) };
        self.store.signed_manifest_text(model, slot)
    }

    fn fetch_chunk(&mut self, sha256: &str) -> Result<Vec<u8>> {
        self.store.get_chunk_by_addr(sha256)
    }
}

/// Deterministic fault injection for the resume wall: abort the sync
/// with a transport-class error after this many chunk *downloads*
/// (local-store hits don't count). `None` = never.
#[derive(Debug, Clone, Copy, Default)]
pub struct SyncOptions {
    pub abort_after: Option<u64>,
}

/// What one sync moved, and what it avoided moving.
#[derive(Debug, Clone, Default)]
pub struct SyncReport {
    /// Chunks pulled from the source this run.
    pub chunks_fetched: u64,
    /// Chunks satisfied by the local store (cross-version dedup or a
    /// previous partial sync). Includes `chunks_resumed`.
    pub chunks_reused: u64,
    /// Subset of `chunks_reused` recorded by an interrupted run's
    /// sidecar — verified partial progress that survived the drop.
    pub chunks_resumed: u64,
    /// Bytes pulled from the source this run.
    pub bytes_fetched: u64,
    /// Bytes the local store already held.
    pub bytes_reused: u64,
    /// Poisoned local objects repaired along the way (see
    /// [`ChunkStore::repair_count`]).
    pub repairs: u64,
}

impl SyncReport {
    pub fn to_json(&self) -> String {
        ObjBuilder::new()
            .field("chunks_fetched", self.chunks_fetched as usize)
            .field("chunks_reused", self.chunks_reused as usize)
            .field("chunks_resumed", self.chunks_resumed as usize)
            .field("bytes_fetched", self.bytes_fetched as usize)
            .field("bytes_reused", self.bytes_reused as usize)
            .field("repairs", self.repairs as usize)
            .build()
            .to_string_compact()
    }
}

/// Sidecar path for an artifact's in-progress sync state.
fn sidecar_path(store: &ChunkStore, desc: &ArtifactDescriptor) -> PathBuf {
    store.root().join("state").join(format!("{}.sync", desc.sha256))
}

/// Load the set of chunk addresses a previous (interrupted) sync
/// recorded as verified-and-stored. A missing or unparseable sidecar
/// just means "start from the store's own contents" — the sidecar is a
/// progress record, never an authority.
fn load_sidecar(store: &ChunkStore, desc: &ArtifactDescriptor) -> HashSet<String> {
    let path = sidecar_path(store, desc);
    let Ok(text) = fs::read_to_string(&path) else {
        return HashSet::new();
    };
    let Ok(v) = json::parse(&text) else {
        return HashSet::new();
    };
    if v.str_field("artifact").ok() != Some(desc.sha256.as_str()) {
        return HashSet::new();
    }
    let Some(done) = v.get("done").and_then(|d| d.as_arr()) else {
        return HashSet::new();
    };
    done.iter().filter_map(|d| d.as_str().map(str::to_string)).collect()
}

fn write_sidecar(
    store: &ChunkStore,
    desc: &ArtifactDescriptor,
    done: &HashSet<String>,
) -> Result<()> {
    let mut sorted: Vec<String> = done.iter().cloned().collect();
    sorted.sort();
    let text = ObjBuilder::new()
        .field("artifact", desc.sha256.as_str())
        .field("done", sorted)
        .build()
        .to_string_compact();
    atomic_write(&sidecar_path(store, desc), text.as_bytes())
}

/// Bring every chunk of `desc` into `store`, consulting the store
/// before pulling each chunk from `source`, and finish with a full
/// streaming verification of the artifact. Progress is durable: after
/// every verified chunk the sidecar is rewritten atomically, and a
/// sidecar from an interrupted run lets the next call skip local
/// verification probes for chunks it already completed — a resumed
/// fetch never re-downloads a verified chunk, by construction (the
/// store lookup would satisfy it even without the sidecar).
pub fn sync_artifact(
    store: &ChunkStore,
    source: &mut dyn ChunkSource,
    desc: &ArtifactDescriptor,
    opts: &SyncOptions,
    report: &mut SyncReport,
) -> Result<()> {
    let recorded = load_sidecar(store, desc);
    let mut done: HashSet<String> = HashSet::new();
    for chunk in &desc.chunks {
        if done.contains(&chunk.sha256) {
            continue; // repeated address within one artifact
        }
        // The local store is consulted before the source, whatever the
        // sidecar says: the sidecar's word is never trusted on its own
        // — a chunk counts as done only if the on-disk object still
        // fully verifies. A poisoned object is re-fetched.
        if store.get_chunk(chunk).is_ok() {
            report.chunks_reused += 1;
            report.bytes_reused += chunk.len;
            if recorded.contains(&chunk.sha256) {
                report.chunks_resumed += 1;
            }
        } else {
            if let Some(cap) = opts.abort_after {
                if report.chunks_fetched >= cap {
                    return Err(Error::transport(format!(
                        "sync aborted by fault injection after {cap} downloads \
                         (artifact {})",
                        desc.sha256
                    )));
                }
            }
            let payload = source.fetch_chunk(&chunk.sha256)?;
            if payload.len() as u64 != chunk.len {
                return Err(Error::corrupt(format!(
                    "chunk {}: source served {} bytes, manifest says {}",
                    chunk.sha256,
                    payload.len(),
                    chunk.len
                )));
            }
            let got = sha256::to_hex(&sha256::hash(&payload));
            if got != chunk.sha256 {
                return Err(Error::corrupt(format!(
                    "chunk {}: source served payload hashing to {got} \
                     (tampered source or link)",
                    chunk.sha256
                )));
            }
            store.put_chunk(&payload)?;
            report.chunks_fetched += 1;
            report.bytes_fetched += payload.len() as u64;
        }
        done.insert(chunk.sha256.clone());
        write_sidecar(store, desc, &done)?;
    }
    // End-to-end proof over the assembled chunk list, O(chunk) memory.
    store.verify_artifact(desc)?;
    let _ = fs::remove_file(sidecar_path(store, desc));
    Ok(())
}

/// Sync one model version end to end: fetch + verify the signed
/// manifest, delta-sync both halves against the local store, adopt the
/// manifest into the canonical version slot (only after every chunk
/// verified), and report what moved.
pub fn sync_deployment(
    store: &ChunkStore,
    source: &mut dyn ChunkSource,
    signer: &dyn Signer,
    model: &str,
    version: u64,
    opts: &SyncOptions,
) -> Result<(RegistryManifest, SyncReport)> {
    let signed_text = source.fetch_manifest(model, version)?;
    let manifest = SignedManifest::from_json_text(&signed_text)?.verify(signer)?;
    if manifest.model != model {
        return Err(Error::corrupt(format!(
            "source served manifest for model '{}', requested '{model}'",
            manifest.model
        )));
    }
    if version != 0 && manifest.model_version != version {
        return Err(Error::version_skew(
            manifest.model_version,
            version,
            format!(
                "source served model_version {} for requested slot {version}",
                manifest.model_version
            ),
        ));
    }
    let repairs_before = store.repair_count();
    let mut report = SyncReport::default();
    sync_artifact(store, source, &manifest.head, opts, &mut report)?;
    sync_artifact(store, source, &manifest.tail, opts, &mut report)?;
    store.adopt_manifest(model, &signed_text, signer)?;
    report.repairs = store.repair_count() - repairs_before;
    Ok((manifest, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::manifest::DeployParams;
    use crate::runtime::registry::signer::HmacSha256Signer;
    use crate::util::prng::Rng;

    struct Scratch(PathBuf);

    impl Scratch {
        fn new(tag: &str) -> Self {
            let dir = std::env::temp_dir().join(format!(
                "rans-sc-delta-{tag}-{}-{:x}",
                std::process::id(),
                Rng::new(0xD17A ^ tag.len() as u64).next_u64()
            ));
            fs::create_dir_all(&dir).unwrap();
            Scratch(dir)
        }
    }

    impl Drop for Scratch {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn bytes(seed: u64, n: usize) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.next_u64() & 0xff) as u8).collect()
    }

    fn manifest_for(
        store: &ChunkStore,
        version: u64,
        head: &[u8],
        tail: &[u8],
    ) -> RegistryManifest {
        RegistryManifest {
            model: "m".into(),
            model_version: version,
            deploy: DeployParams::paper(4),
            head: store.put_artifact(head, 64).unwrap(),
            tail: store.put_artifact(tail, 64).unwrap(),
        }
    }

    #[test]
    fn plan_reports_set_difference_not_positions() {
        let s = Scratch::new("plan");
        let store = ChunkStore::open(s.0.join("reg"));
        let head1 = bytes(1, 64 * 10);
        let tail1 = bytes(2, 64 * 4);
        // v2 appends one new chunk to the head and keeps the tail.
        let mut head2 = head1.clone();
        head2.extend_from_slice(&bytes(3, 64));
        let m1 = manifest_for(&store, 1, &head1, &tail1);
        let m2 = manifest_for(&store, 2, &head2, &tail1);
        let plan = DeltaPlan::plan(&m1, &m2);
        assert_eq!(plan.missing.len(), 1);
        assert_eq!(plan.delta_bytes, 64);
        assert_eq!(plan.full_bytes, 64 * 15);
        assert_eq!(plan.shared_chunks + plan.missing.len(), plan.total_chunks);
        assert_eq!(plan.bytes_saved(), 64 * 14);
        // Identical versions: nothing to move.
        let plan = DeltaPlan::plan(&m2, &m2);
        assert!(plan.missing.is_empty());
        assert_eq!(plan.delta_bytes, 0);
        let json = plan.to_json();
        assert!(json.contains("\"delta_bytes\":0"), "{json}");
    }

    #[test]
    fn store_source_sync_moves_only_missing_chunks() {
        let s = Scratch::new("sync");
        let publisher = ChunkStore::open(s.0.join("pub"));
        let signer = HmacSha256Signer::new(b"k".to_vec(), "fleet");
        let head1 = bytes(10, 64 * 20);
        let tail1 = bytes(11, 64 * 5);
        let m1 = manifest_for(&publisher, 1, &head1, &tail1);
        publisher.publish(&m1, &signer).unwrap();
        // v2: one chunk's worth of head changes, tail unchanged.
        let mut head2 = head1.clone();
        head2[0] ^= 0xFF;
        let m2 = manifest_for(&publisher, 2, &head2, &tail1);
        publisher.publish(&m2, &signer).unwrap();

        let edge = ChunkStore::open(s.0.join("edge"));
        let mut source = StoreSource::open(s.0.join("pub"));
        // Cold sync of v1: everything is fetched.
        let (_, r1) = sync_deployment(
            &edge, &mut source, &signer, "m", 1, &SyncOptions::default(),
        )
        .unwrap();
        assert_eq!(r1.chunks_reused, 0);
        assert_eq!(r1.bytes_fetched, (head1.len() + tail1.len()) as u64);
        // Delta sync to v2 (latest): only the flipped chunk moves.
        let (m, r2) = sync_deployment(
            &edge, &mut source, &signer, "m", 0, &SyncOptions::default(),
        )
        .unwrap();
        assert_eq!(m.model_version, 2);
        assert_eq!(r2.chunks_fetched, 1);
        assert_eq!(r2.bytes_fetched, 64);
        // The edge can now serve v2 offline.
        let dep = edge.fetch("m", Some(2), &signer).unwrap();
        assert_eq!(dep.head, head2);
        assert_eq!(dep.tail, tail1);
    }

    #[test]
    fn aborted_sync_resumes_without_refetching_done_chunks() {
        let s = Scratch::new("resume");
        let publisher = ChunkStore::open(s.0.join("pub"));
        let signer = HmacSha256Signer::new(b"k".to_vec(), "fleet");
        let m1 = manifest_for(&publisher, 1, &bytes(20, 64 * 12), &bytes(21, 64 * 3));
        publisher.publish(&m1, &signer).unwrap();

        let edge = ChunkStore::open(s.0.join("edge"));
        let mut source = StoreSource::open(s.0.join("pub"));
        let err = sync_deployment(
            &edge,
            &mut source,
            &signer,
            "m",
            1,
            &SyncOptions { abort_after: Some(5) },
        )
        .unwrap_err();
        assert!(err.is_retryable(), "injected abort must look like a link drop: {err}");
        // Sidecar survives the drop and records the 5 completed chunks.
        assert!(sidecar_path(&edge, &m1.head).exists());
        assert_eq!(load_sidecar(&edge, &m1.head).len(), 5);
        // Manifest must NOT be adopted for a half-synced deployment.
        assert!(edge.load_manifest("m", Some(1), &signer).is_err());

        let (_, r) = sync_deployment(
            &edge, &mut source, &signer, "m", 1, &SyncOptions::default(),
        )
        .unwrap();
        assert_eq!(r.chunks_reused, 5, "completed chunks must not be re-downloaded");
        assert_eq!(r.chunks_resumed, 5, "all reuse came from the interrupted run's sidecar");
        assert_eq!(r.chunks_fetched, 10);
        assert!(!sidecar_path(&edge, &m1.head).exists(), "sidecar cleaned up on completion");
        edge.fetch("m", Some(1), &signer).unwrap();
    }

    #[test]
    fn tampered_source_chunk_is_fatal_and_never_stored() {
        struct LyingSource(StoreSource);
        impl ChunkSource for LyingSource {
            fn fetch_manifest(&mut self, model: &str, version: u64) -> Result<String> {
                self.0.fetch_manifest(model, version)
            }
            fn fetch_chunk(&mut self, sha256: &str) -> Result<Vec<u8>> {
                let mut p = self.0.fetch_chunk(sha256)?;
                p[0] ^= 0x01;
                Ok(p)
            }
        }
        let s = Scratch::new("tamper");
        let publisher = ChunkStore::open(s.0.join("pub"));
        let signer = HmacSha256Signer::new(b"k".to_vec(), "fleet");
        let m1 = manifest_for(&publisher, 1, &bytes(30, 256), &bytes(31, 64));
        publisher.publish(&m1, &signer).unwrap();

        let edge = ChunkStore::open(s.0.join("edge"));
        let mut source = LyingSource(StoreSource::open(s.0.join("pub")));
        let err = sync_deployment(
            &edge, &mut source, &signer, "m", 1, &SyncOptions::default(),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Corrupt(_)), "{err}");
        assert!(!err.is_retryable());
        // Nothing tainted landed in the local store.
        for chunk in m1.all_chunks() {
            assert!(!edge.chunk_path(&chunk.sha256).exists());
        }
    }

    #[test]
    fn wrong_key_manifest_rejected_before_any_chunk_moves() {
        let s = Scratch::new("key");
        let publisher = ChunkStore::open(s.0.join("pub"));
        let signer = HmacSha256Signer::new(b"k".to_vec(), "fleet");
        let m1 = manifest_for(&publisher, 1, &bytes(40, 128), &bytes(41, 64));
        publisher.publish(&m1, &signer).unwrap();
        let edge = ChunkStore::open(s.0.join("edge"));
        let mut source = StoreSource::open(s.0.join("pub"));
        let other = HmacSha256Signer::new(b"not-k".to_vec(), "fleet");
        let err = sync_deployment(
            &edge, &mut source, &other, "m", 1, &SyncOptions::default(),
        )
        .unwrap_err();
        assert!(matches!(err, Error::Corrupt(_)), "{err}");
        assert!(!edge.root().join("objects").exists(), "no chunk may move under a bad key");
    }
}
