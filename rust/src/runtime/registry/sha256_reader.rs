//! Incremental digest verification for streaming reads.
//!
//! [`Sha256Reader`] wraps any [`Read`] source — a [`std::fs::File`], a
//! transport-backed stream — and hashes every byte as it passes
//! through, so an artifact's content address is verified *as the bytes
//! stream in* rather than after a full buffer lands. Reading past the
//! declared length fails immediately (a grown file can never sneak
//! extra bytes past the digest), and [`finish`](Sha256Reader::finish)
//! checks both the exact length and the digest, returning the typed
//! [`Error::Corrupt`](crate::error::Error) the tamper wall asserts on.

use std::io::{self, Read};

use crate::error::{Error, Result};
use crate::util::sha256::{self, Sha256};

/// A [`Read`] adapter that SHA-256-hashes everything it yields and
/// verifies the stream against an expected `(length, digest)` pair.
pub struct Sha256Reader<R: Read> {
    inner: R,
    hasher: Sha256,
    read: u64,
    expect_len: u64,
    expect: [u8; 32],
    /// Human context for error messages (chunk address, file path…).
    what: String,
}

impl<R: Read> Sha256Reader<R> {
    pub fn new(inner: R, expect_len: u64, expect: [u8; 32], what: impl Into<String>) -> Self {
        Sha256Reader {
            inner,
            hasher: Sha256::new(),
            read: 0,
            expect_len,
            expect,
            what: what.into(),
        }
    }

    /// Bytes hashed so far.
    pub fn bytes_read(&self) -> u64 {
        self.read
    }

    /// Consume the reader, requiring that exactly `expect_len` bytes
    /// were read and that they hash to the expected digest. Returns the
    /// inner reader so callers can keep reading past the verified span
    /// (e.g. a CRC trailer after a chunk payload).
    pub fn finish(self) -> Result<R> {
        let Sha256Reader { inner, hasher, read, expect_len, expect, what } = self;
        if read != expect_len {
            return Err(Error::corrupt(format!(
                "{what}: length mismatch: read {read} bytes, manifest says {expect_len}"
            )));
        }
        let got = hasher.finalize();
        if !sha256::ct_eq(&got, &expect) {
            return Err(Error::corrupt(format!(
                "{what}: sha256 mismatch: streamed {} != expected {}",
                sha256::to_hex(&got),
                sha256::to_hex(&expect)
            )));
        }
        Ok(inner)
    }

    /// Drain the remaining declared bytes into `buf` (appending), then
    /// [`finish`](Self::finish). The convenience path for fixed-length
    /// chunk payloads.
    pub fn read_exact_to_end(mut self, buf: &mut Vec<u8>) -> Result<()> {
        let want = (self.expect_len - self.read.min(self.expect_len)) as usize;
        let start = buf.len();
        buf.resize(start + want, 0);
        let what = self.what.clone();
        self.read_exact(&mut buf[start..])
            .map_err(|e| Error::corrupt(format!("{what}: short read: {e}")))?;
        self.finish().map(|_| ())
    }
}

impl<R: Read> Read for Sha256Reader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.read += n as u64;
        if self.read > self.expect_len {
            // Over-length is detectable before the digest: fail now so
            // a streaming consumer stops pulling corrupt data.
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: stream longer than declared {} bytes",
                    self.what, self.expect_len
                ),
            ));
        }
        self.hasher.update(&buf[..n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload() -> Vec<u8> {
        (0..1000u32).map(|i| (i * 37 + 5) as u8).collect()
    }

    #[test]
    fn verifies_good_stream() {
        let data = payload();
        let digest = sha256::hash(&data);
        let mut r = Sha256Reader::new(&data[..], data.len() as u64, digest, "chunk");
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        r.finish().unwrap();
    }

    #[test]
    fn rejects_flipped_bit() {
        let mut data = payload();
        let digest = sha256::hash(&data);
        data[123] ^= 0x10;
        let mut r = Sha256Reader::new(&data[..], data.len() as u64, digest, "chunk");
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        let err = r.finish().unwrap_err();
        assert!(matches!(err, Error::Corrupt { .. }), "{err}");
        assert!(!err.is_retryable());
        assert!(err.to_string().contains("sha256 mismatch"), "{err}");
    }

    #[test]
    fn rejects_truncation() {
        let data = payload();
        let digest = sha256::hash(&data);
        let cut = &data[..data.len() - 7];
        let mut r = Sha256Reader::new(cut, data.len() as u64, digest, "chunk");
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        let err = r.finish().unwrap_err();
        assert!(err.to_string().contains("length mismatch"), "{err}");
    }

    #[test]
    fn rejects_overlong_stream_mid_read() {
        let data = payload();
        let digest = sha256::hash(&data[..100]);
        let mut r = Sha256Reader::new(&data[..], 100, digest, "chunk");
        let mut out = Vec::new();
        let err = r.read_to_end(&mut out).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn read_exact_to_end_appends_and_verifies() {
        let data = payload();
        let digest = sha256::hash(&data);
        let r = Sha256Reader::new(&data[..], data.len() as u64, digest, "chunk");
        let mut buf = vec![9u8; 3];
        r.read_exact_to_end(&mut buf).unwrap();
        assert_eq!(&buf[..3], &[9, 9, 9]);
        assert_eq!(&buf[3..], &data[..]);
    }
}
