//! Content-addressed chunk store with signed manifests.
//!
//! On-disk layout under the registry root:
//!
//! ```text
//! objects/<aa>/<sha256-hex>.chunk      framed chunk, addressed by payload hash
//! manifests/<model>/<version>.json     SignedManifest wrapper
//! ```
//!
//! Chunk file framing (all little-endian):
//!
//! ```text
//! [4B magic "RGC1"][u32 payload_len][payload bytes][u32 crc32(payload)]
//! ```
//!
//! The CRC is the fast first-line check (same discipline as the RSC2
//! container's per-chunk CRCs); the SHA-256 content address is the
//! authenticated one, verified incrementally by
//! [`Sha256Reader`](super::sha256_reader::Sha256Reader) **as the bytes
//! stream in** — a corrupt chunk is rejected before the next chunk is
//! even opened. Writes are atomic (temp file + rename), so a crashed
//! publish can never leave a half-written object at a valid address.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::runtime::registry::manifest::{
    ArtifactDescriptor, ChunkRef, RegistryManifest, SignedManifest,
};
use crate::runtime::registry::sha256_reader::Sha256Reader;
use crate::runtime::registry::signer::Signer;
use crate::util::{crc32, sha256};

/// Chunk file magic.
const CHUNK_MAGIC: [u8; 4] = *b"RGC1";

/// Default chunk payload size for [`ChunkStore::put_artifact`]: large
/// enough to amortize per-chunk overhead, small enough that a corrupt
/// transfer is caught within one chunk of the flip.
pub const DEFAULT_CHUNK_LEN: usize = 1 << 20;

/// Everything a node needs to run one model version: the verified
/// manifest plus both halves' bytes.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub manifest: RegistryManifest,
    pub head: Vec<u8>,
    pub tail: Vec<u8>,
}

impl Deployment {
    /// Write both verified halves to disk atomically (temp + rename),
    /// so `registry fetch` produces deployable files rather than just
    /// printing sizes.
    pub fn write_to(&self, head_path: &Path, tail_path: &Path) -> Result<()> {
        atomic_write(head_path, &self.head)?;
        atomic_write(tail_path, &self.tail)
    }
}

/// A content-addressed artifact store rooted at one directory.
pub struct ChunkStore {
    root: PathBuf,
    /// Poisoned objects found at a valid address on a dedup hit and
    /// atomically rewritten with the good payload.
    repairs: AtomicU64,
}

/// Process-unique suffix counter for atomic temp files.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

pub(super) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path
        .parent()
        .ok_or_else(|| Error::invalid(format!("{}: no parent directory", path.display())))?;
    fs::create_dir_all(dir)
        .map_err(|e| Error::artifact(format!("{}: mkdir failed: {e}", dir.display())))?;
    let tmp = dir.join(format!(
        ".tmp-{}-{}",
        std::process::id(),
        TEMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let mut f = fs::File::create(&tmp)
        .map_err(|e| Error::artifact(format!("{}: create failed: {e}", tmp.display())))?;
    f.write_all(bytes)
        .and_then(|_| f.sync_all())
        .map_err(|e| Error::artifact(format!("{}: write failed: {e}", tmp.display())))?;
    drop(f);
    fs::rename(&tmp, path).map_err(|e| {
        let _ = fs::remove_file(&tmp);
        Error::artifact(format!("{}: rename failed: {e}", path.display()))
    })
}

impl ChunkStore {
    pub fn open(root: impl Into<PathBuf>) -> Self {
        ChunkStore { root: root.into(), repairs: AtomicU64::new(0) }
    }

    /// Number of poisoned on-disk objects repaired by
    /// [`put_chunk`](Self::put_chunk) dedup hits since this store
    /// handle was opened.
    pub fn repair_count(&self) -> u64 {
        self.repairs.load(Ordering::Relaxed)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path of the chunk object addressed by `hex`.
    pub fn chunk_path(&self, hex: &str) -> PathBuf {
        let shard = &hex[..hex.len().min(2)];
        self.root.join("objects").join(shard).join(format!("{hex}.chunk"))
    }

    fn manifest_path(&self, model: &str, version: u64) -> PathBuf {
        self.root.join("manifests").join(model).join(format!("{version}.json"))
    }

    /// Store one chunk payload, returning its content address. Already
    /// stored chunks are deduplicated by address — but only after the
    /// on-disk object passes the full frame check (magic, length, CRC,
    /// content digest). A poisoned object squatting at a valid address
    /// is atomically rewritten with the good payload instead of being
    /// trusted, so publish can never "succeed" over a chunk that every
    /// later fetch would reject.
    pub fn put_chunk(&self, payload: &[u8]) -> Result<String> {
        let hex = sha256::to_hex(&sha256::hash(payload));
        let path = self.chunk_path(&hex);
        if path.exists() {
            let probe = ChunkRef { len: payload.len() as u64, sha256: hex.clone() };
            match self.get_chunk(&probe) {
                Ok(_) => return Ok(hex),
                Err(_) => {
                    // Fall through to the atomic rewrite below: rename
                    // replaces the poisoned object in one step.
                    self.repairs.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let mut framed = Vec::with_capacity(payload.len() + 12);
        framed.extend_from_slice(&CHUNK_MAGIC);
        framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        framed.extend_from_slice(payload);
        framed.extend_from_slice(&crc32::hash(payload).to_le_bytes());
        atomic_write(&path, &framed)?;
        Ok(hex)
    }

    /// Fetch and fully verify one chunk: magic and length framing, the
    /// CRC-32 fast check, and the SHA-256 content address (hashed
    /// incrementally while reading). Every failure is a typed fatal
    /// error naming the chunk.
    pub fn get_chunk(&self, expect: &ChunkRef) -> Result<Vec<u8>> {
        self.read_chunk_frame(&expect.sha256, Some(expect.len))
    }

    /// Fetch and fully verify a chunk by address alone, trusting the
    /// framed length header for the size (the address still proves the
    /// content). The chunk-serving wire path uses this: a server knows
    /// only the requested address, not the requester's manifest.
    pub fn get_chunk_by_addr(&self, sha256: &str) -> Result<Vec<u8>> {
        self.read_chunk_frame(sha256, None)
    }

    fn read_chunk_frame(&self, sha256_hex: &str, expect_len: Option<u64>) -> Result<Vec<u8>> {
        let digest = super::manifest::parse_digest(sha256_hex, "chunk address")?;
        let path = self.chunk_path(sha256_hex);
        let file = fs::File::open(&path).map_err(|e| {
            Error::artifact(format!("chunk {} absent from store: {e}", path.display()))
        })?;
        let mut reader = std::io::BufReader::new(file);

        let mut header = [0u8; 8];
        reader.read_exact(&mut header).map_err(|e| {
            Error::corrupt(format!("chunk {sha256_hex}: truncated header: {e}"))
        })?;
        if header[..4] != CHUNK_MAGIC {
            return Err(Error::corrupt(format!(
                "chunk {sha256_hex}: bad magic {:02x?}",
                &header[..4]
            )));
        }
        let framed_len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as u64;
        if let Some(expect) = expect_len {
            if framed_len != expect {
                return Err(Error::corrupt(format!(
                    "chunk {sha256_hex}: framed length {framed_len} != manifest length {expect}"
                )));
            }
        }

        // Stream the payload through the digest verifier: the hash is
        // computed while the bytes come off the file, and the verdict
        // lands before the CRC trailer is even read.
        let mut hashed = Sha256Reader::new(
            reader.take(framed_len),
            framed_len,
            digest,
            format!("chunk {sha256_hex}"),
        );
        let mut payload = vec![0u8; framed_len as usize];
        hashed.read_exact(&mut payload).map_err(|e| {
            Error::corrupt(format!("chunk {sha256_hex}: truncated payload: {e}"))
        })?;
        let mut reader = hashed.finish()?.into_inner();

        // The CRC fast check must agree with what was hashed.
        let mut crc_bytes = [0u8; 4];
        reader.read_exact(&mut crc_bytes).map_err(|e| {
            Error::corrupt(format!("chunk {sha256_hex}: truncated crc trailer: {e}"))
        })?;
        if u32::from_le_bytes(crc_bytes) != crc32::hash(&payload) {
            return Err(Error::corrupt(format!(
                "chunk {sha256_hex}: crc mismatch (framing corrupt)"
            )));
        }
        let mut trailing = [0u8; 1];
        if reader.read(&mut trailing).unwrap_or(0) != 0 {
            return Err(Error::corrupt(format!(
                "chunk {sha256_hex}: trailing bytes after crc"
            )));
        }
        Ok(payload)
    }

    /// Chunk `bytes` at `chunk_len`, store every chunk, and return the
    /// descriptor binding the whole artifact.
    pub fn put_artifact(&self, bytes: &[u8], chunk_len: usize) -> Result<ArtifactDescriptor> {
        if chunk_len == 0 {
            return Err(Error::invalid("chunk_len must be > 0"));
        }
        let mut chunks = Vec::new();
        let mut off = 0;
        while off < bytes.len() || (bytes.is_empty() && chunks.is_empty()) {
            let end = (off + chunk_len).min(bytes.len());
            let payload = &bytes[off..end];
            let hex = self.put_chunk(payload)?;
            chunks.push(ChunkRef { len: payload.len() as u64, sha256: hex });
            if end == bytes.len() {
                break;
            }
            off = end;
        }
        Ok(ArtifactDescriptor {
            len: bytes.len() as u64,
            sha256: sha256::to_hex(&sha256::hash(bytes)),
            chunks,
        })
    }

    /// Like [`put_artifact`](Self::put_artifact) but with
    /// content-defined boundaries: an early insertion in the next
    /// version shifts only the chunks around the edit instead of
    /// rewriting every later address. The descriptor format is
    /// unchanged — chunk lengths were always per-chunk data — so CDC
    /// and fixed-size artifacts coexist in one store and one manifest
    /// schema.
    pub fn put_artifact_cdc(
        &self,
        bytes: &[u8],
        params: &super::cdc::CdcParams,
    ) -> Result<ArtifactDescriptor> {
        let mut chunks = Vec::new();
        let mut off = 0usize;
        for len in super::cdc::split(bytes, params)? {
            let payload = &bytes[off..off + len];
            let hex = self.put_chunk(payload)?;
            chunks.push(ChunkRef { len: len as u64, sha256: hex });
            off += len;
        }
        Ok(ArtifactDescriptor {
            len: bytes.len() as u64,
            sha256: sha256::to_hex(&sha256::hash(bytes)),
            chunks,
        })
    }

    /// Streaming core shared by [`read_artifact`](Self::read_artifact)
    /// and [`verify_artifact`](Self::verify_artifact): walk the chunk
    /// list in order, fully verify each chunk (CRC + content address)
    /// before the next one is opened, feed the payload through the
    /// whole-artifact hasher, and hand it to `sink`. Peak memory is one
    /// chunk, never the whole artifact. Returns the verified byte
    /// count; the length and whole-artifact digest checks run before
    /// the call returns, so a caller never sees an unverified total.
    pub fn stream_artifact(
        &self,
        desc: &ArtifactDescriptor,
        mut sink: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<u64> {
        let whole = desc.digest()?;
        let mut hasher = sha256::Sha256::new();
        let mut total: u64 = 0;
        for chunk in &desc.chunks {
            let payload = self.get_chunk(chunk)?;
            hasher.update(&payload);
            total += payload.len() as u64;
            sink(&payload)?;
        }
        if total != desc.len {
            return Err(Error::corrupt(format!(
                "artifact {}: reassembled {total} bytes, manifest says {}",
                desc.sha256, desc.len
            )));
        }
        if !sha256::ct_eq(&hasher.finalize(), &whole) {
            return Err(Error::corrupt(format!(
                "artifact {}: whole-artifact sha256 mismatch",
                desc.sha256
            )));
        }
        Ok(total)
    }

    /// Reassemble an artifact into memory: a thin collector over
    /// [`stream_artifact`](Self::stream_artifact), inheriting its
    /// incremental per-chunk and whole-artifact verification.
    pub fn read_artifact(&self, desc: &ArtifactDescriptor) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(desc.len as usize);
        self.stream_artifact(desc, |payload| {
            out.extend_from_slice(payload);
            Ok(())
        })?;
        Ok(out)
    }

    /// Verify every byte of an artifact with O(chunk) peak memory —
    /// the bytes are hashed as they stream and dropped chunk by chunk
    /// (the CLI `verify` path and the `registry_verify_mbps` bench).
    pub fn verify_artifact(&self, desc: &ArtifactDescriptor) -> Result<u64> {
        self.stream_artifact(desc, |_| Ok(()))
    }

    /// Highest published version for `model`, or `None` when the model
    /// has no manifests yet.
    pub fn latest_version(&self, model: &str) -> Result<Option<u64>> {
        let dir = self.root.join("manifests").join(model);
        let entries = match fs::read_dir(&dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(Error::artifact(format!("{}: read_dir failed: {e}", dir.display())))
            }
        };
        let mut latest = None;
        for entry in entries {
            let entry =
                entry.map_err(|e| Error::artifact(format!("{}: {e}", dir.display())))?;
            let name = entry.file_name();
            let Some(stem) = name.to_str().and_then(|n| n.strip_suffix(".json")) else {
                continue;
            };
            let Ok(v) = stem.parse::<u64>() else {
                continue;
            };
            // `manifest_path` writes canonical decimal stems only
            // (`7.json`), so a numeric-but-non-canonical stem like
            // `007.json` is an alias slot that would be reported latest
            // yet be unloadable — and could shadow the real `7.json`.
            // Reject it loudly instead of guessing.
            if stem != v.to_string() {
                return Err(Error::corrupt(format!(
                    "{}: non-canonical manifest filename (version {v} canonical slot is \
                     {v}.json); remove or rename the stray file",
                    dir.join(name.to_str().unwrap_or("?")).display()
                )));
            }
            latest = Some(latest.map_or(v, |l: u64| l.max(v)));
        }
        Ok(latest)
    }

    /// Sign and store `manifest`, enforcing the monotonic version
    /// contract: publishing a version at or below the registry's
    /// current latest is a loud typed error, never an overwrite.
    pub fn publish(&self, manifest: &RegistryManifest, signer: &dyn Signer) -> Result<PathBuf> {
        if manifest.model_version == 0 {
            return Err(Error::invalid("model_version 0 is reserved for unversioned serving"));
        }
        if let Some(latest) = self.latest_version(&manifest.model)? {
            if manifest.model_version <= latest {
                return Err(Error::invalid(format!(
                    "stale model_version {} for '{}': registry is already at {latest}",
                    manifest.model_version, manifest.model
                )));
            }
        }
        let sealed = SignedManifest::seal(manifest, signer);
        let path = self.manifest_path(&manifest.model, manifest.model_version);
        atomic_write(&path, sealed.to_json_text().as_bytes())?;
        Ok(path)
    }

    /// Raw `SignedManifest` wrapper text for a version slot (latest
    /// when `None`) — what a registry-serving node puts on the wire.
    /// The text travels verbatim so the requester verifies the
    /// *original* signature, not a re-serialization.
    pub fn signed_manifest_text(&self, model: &str, version: Option<u64>) -> Result<String> {
        let version = match version {
            Some(v) => v,
            None => self.latest_version(model)?.ok_or_else(|| {
                Error::artifact(format!(
                    "no manifest published for model '{model}' in {}",
                    self.root.display()
                ))
            })?,
        };
        let path = self.manifest_path(model, version);
        fs::read_to_string(&path)
            .map_err(|e| Error::artifact(format!("manifest absent: {}: {e}", path.display())))
    }

    /// Adopt a signed manifest replicated from another registry: verify
    /// the signature and the model binding, then store the wrapper text
    /// byte-for-byte in the canonical version slot. Re-adopting an
    /// identical manifest is a no-op; a *different* document squatting
    /// in the slot is a loud corruption error. Unlike
    /// [`publish`](Self::publish), adoption accepts any version —
    /// replicating an older version is how a fleet rolls back.
    pub fn adopt_manifest(
        &self,
        model: &str,
        signed_text: &str,
        signer: &dyn Signer,
    ) -> Result<RegistryManifest> {
        let manifest = SignedManifest::from_json_text(signed_text)?.verify(signer)?;
        if manifest.model != model {
            return Err(Error::corrupt(format!(
                "adopted manifest is for model '{}', expected '{model}'",
                manifest.model
            )));
        }
        if manifest.model_version == 0 {
            return Err(Error::invalid("model_version 0 is reserved for unversioned serving"));
        }
        let path = self.manifest_path(model, manifest.model_version);
        if let Ok(existing) = fs::read_to_string(&path) {
            if existing == signed_text {
                return Ok(manifest);
            }
            return Err(Error::corrupt(format!(
                "{}: version slot holds a different signed manifest; refusing to overwrite",
                path.display()
            )));
        }
        atomic_write(&path, signed_text.as_bytes())?;
        Ok(manifest)
    }

    /// Load and verify a manifest: signature, then inner parse, then
    /// the filename/content binding (a stale signed manifest copied
    /// over a newer version slot is caught here).
    pub fn load_manifest(
        &self,
        model: &str,
        version: Option<u64>,
        signer: &dyn Signer,
    ) -> Result<RegistryManifest> {
        let version = match version {
            Some(v) => v,
            None => self.latest_version(model)?.ok_or_else(|| {
                Error::artifact(format!(
                    "no manifest published for model '{model}' in {}",
                    self.root.display()
                ))
            })?,
        };
        let path = self.manifest_path(model, version);
        let text = fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!("manifest absent: {}: {e}", path.display()))
        })?;
        let manifest = SignedManifest::from_json_text(&text)
            .map_err(|e| Error::corrupt(format!("{}: {e}", path.display())))?
            .verify(signer)
            .map_err(|e| Error::corrupt(format!("{}: {e}", path.display())))?;
        if manifest.model != model {
            return Err(Error::corrupt(format!(
                "{}: manifest is for model '{}', expected '{model}'",
                path.display(),
                manifest.model
            )));
        }
        if manifest.model_version != version {
            return Err(Error::version_skew(
                version,
                manifest.model_version,
                format!(
                    "{}: embedded model_version {} does not match version slot {version} \
                     (stale manifest?)",
                    path.display(),
                    manifest.model_version
                ),
            ));
        }
        Ok(manifest)
    }

    /// Full fetch: verified manifest + both halves, every byte checked
    /// while streaming.
    pub fn fetch(
        &self,
        model: &str,
        version: Option<u64>,
        signer: &dyn Signer,
    ) -> Result<Deployment> {
        let manifest = self.load_manifest(model, version, signer)?;
        let head = self.read_artifact(&manifest.head)?;
        let tail = self.read_artifact(&manifest.tail)?;
        Ok(Deployment { manifest, head, tail })
    }
}
