//! Offline stub of the `xla` PJRT binding.
//!
//! The container this reproduction builds in has no network access and no
//! prebuilt `xla_extension`, so the crate cannot link the real `xla`
//! crate. This module mirrors the *exact* API surface
//! [`super::executor`] and [`super::split_model`] consume, with every
//! path that would reach PJRT returning a clean [`Error`] at the first
//! constructor ([`PjRtClient::cpu`] / [`HloModuleProto::from_text_file`]).
//!
//! Everything downstream of the runtime is artifact-gated (tests and
//! examples check for `artifacts/manifest.json` before touching PJRT), so
//! the stub never executes on the supported paths — it exists to keep the
//! runtime layer compiling and its types nameable.
//!
//! To run against real hardware, add the `xla` crate to `Cargo.toml` and
//! replace the `use super::xla_stub as xla;` aliases in `executor.rs` and
//! `split_model.rs` with the crate import; no other code changes are
//! required.

use std::fmt;
use std::path::Path;

/// Stub error type (mirrors `xla::Error`'s `Display` contract).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable in this offline build \
         (see rust/src/runtime/xla_stub.rs for how to link a real xla binding)"
    ))
}

/// Stub PJRT client. [`PjRtClient::cpu`] always errors, so no instance
/// can exist; the methods below are therefore unreachable but keep the
/// executor layer compiling unchanged.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Always fails in the offline build.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform string.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (unreachable: no client can exist).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub HLO module proto. Construction always errors.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Always fails in the offline build.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a parsed proto (reachable only with a proto, which cannot
    /// exist in the offline build).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// Stub loaded executable (obtainable only through [`PjRtClient::compile`],
/// which always errors).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal inputs.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to host.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub host literal. Constructible (the marshalling helpers in
/// `executor.rs` build literals before execution), but all data
/// extraction errors — a literal can only reach those calls through an
/// executable, which cannot exist offline.
#[derive(Debug, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Scalar literal.
    pub fn scalar<T>(_v: T) -> Literal {
        Literal { _priv: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    /// Extract a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Extract the first element.
    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn hlo_load_fails_with_path_context() {
        let err = HloModuleProto::from_text_file("artifacts/x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("x.hlo.txt"));
    }

    #[test]
    fn literals_construct_but_do_not_extract() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.get_first_element::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let _ = Literal::scalar(4.0f32);
    }
}
