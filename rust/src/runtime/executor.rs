//! Thin PJRT wrapper over the `xla` binding.
//!
//! HLO *text* is the interchange format (see python/compile/hlo.py):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids. All
//! exported computations return tuples (`return_tuple=True`), so
//! execution uniformly unwraps a tuple.
//!
//! The offline build aliases the [`super::xla_stub`] module in place of
//! the real `xla` crate (see that module's docs); artifact-gated callers
//! get a clean [`Error::Runtime`] instead of a link failure.

use std::path::Path;

use crate::error::{Error, Result};

use super::xla_stub as xla;

fn xerr(context: &str) -> impl Fn(xla::Error) -> Error + '_ {
    move |e| Error::runtime(format!("{context}: {e}"))
}

/// A PJRT client (CPU in this environment).
pub struct Engine {
    client: xla::PjRtClient,
}

// SAFETY: the PJRT C API is thread-safe by contract (clients and loaded
// executables may be used from multiple threads; the CPU plugin
// dispatches onto its own thread pool). The `xla` crate wraps the client
// in an `Rc` purely for cheap intra-thread cloning — we never clone the
// Rc across threads, only share the owning struct behind `Arc`, and all
// executions additionally serialize through the per-executable mutex in
// [`Executable::run`].
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Construct the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xerr("PjRtClient::cpu"))?;
        Ok(Engine { client })
    }

    /// Platform string (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error::artifact(format!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(xerr("HloModuleProto::from_text_file"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr("compile"))?;
        Ok(Executable {
            exe,
            path: path.display().to_string(),
            run_lock: std::sync::Mutex::new(()),
        })
    }
}

/// A compiled computation ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    path: String,
    /// Serializes `run` calls; see the safety note on [`Engine`].
    run_lock: std::sync::Mutex<()>,
}

// SAFETY: see the note on `Engine`; `run` is additionally serialized by
// `run_lock`, so the wrapped raw executable pointer is never used
// concurrently.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Source artifact path (diagnostics).
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Execute with literal inputs; unwraps the output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let _guard = self.run_lock.lock().unwrap();
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(xerr(&format!("execute {}", self.path)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(xerr("to_literal_sync"))?;
        lit.to_tuple().map_err(xerr("to_tuple"))
    }
}

// ------------------------------------------------------------ literals

/// Build an f32 literal of the given dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::invalid(format!("{} elements for dims {dims:?}", data.len())));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(xerr("reshape"))
}

/// Build an i32 literal of the given dims.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(Error::invalid(format!("{} elements for dims {dims:?}", data.len())));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(xerr("reshape"))
}

/// Scalar f32 literal.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector.
pub fn to_f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(xerr("to_vec<f32>"))
}

/// Extract an i32 vector.
pub fn to_i32s(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(xerr("to_vec<i32>"))
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(xerr("get_first_element"))
}
