//! Typed view of `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Artifact paths of one head/tail pair (quantized + raw variants).
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    /// Quantized head (Pallas quantize epilogue).
    pub head: String,
    /// Quantized tail (Pallas dequantize prologue).
    pub tail: String,
    /// Raw float head (baseline path).
    pub head_raw: String,
    /// Raw float tail (baseline path).
    pub tail_raw: String,
}

impl ArtifactPaths {
    fn parse(v: &Value) -> Result<Self> {
        Ok(ArtifactPaths {
            head: v.str_field("head")?.to_string(),
            tail: v.str_field("tail")?.to_string(),
            head_raw: v.str_field("head_raw")?.to_string(),
            tail_raw: v.str_field("tail_raw")?.to_string(),
        })
    }
}

/// One exported split of a vision model.
#[derive(Debug, Clone)]
pub struct SplitEntry {
    /// Split layer index (SL1–SL4).
    pub sl: usize,
    /// Compiled batch size.
    pub batch: usize,
    /// IF tensor shape (batch-leading).
    pub feature_shape: Vec<usize>,
    /// Flat IF length `T`.
    pub feature_len: usize,
    /// HLO artifact paths.
    pub artifacts: ArtifactPaths,
}

/// A vision model entry.
#[derive(Debug, Clone)]
pub struct VisionEntry {
    /// Unique name, `{model}_{dataset}`.
    pub name: String,
    /// Architecture id (e.g. `resnet_mini`).
    pub model: String,
    /// Dataset id (`synth_a` / `synth_b`).
    pub dataset: String,
    /// Classifier classes.
    pub num_classes: usize,
    /// Input shape `[1, H, W, C]`.
    pub input_shape: Vec<usize>,
    /// Full-model accuracy measured at build time (no compression).
    pub baseline_accuracy: f64,
    /// Test-set binary (relative path).
    pub test_data: String,
    /// Exported splits.
    pub splits: Vec<SplitEntry>,
}

impl VisionEntry {
    /// Find a split by (sl, batch).
    pub fn split(&self, sl: usize, batch: usize) -> Result<&SplitEntry> {
        self.splits
            .iter()
            .find(|s| s.sl == sl && s.batch == batch)
            .ok_or_else(|| {
                Error::artifact(format!("{}: no artifact for SL{sl} batch {batch}", self.name))
            })
    }
}

/// One multiple-choice task binary.
#[derive(Debug, Clone)]
pub struct TaskFile {
    /// Task id (e.g. `retrieval`).
    pub name: String,
    /// Relative path of the .bin.
    pub path: String,
    /// Items in the file.
    pub n_items: usize,
}

/// A language-model entry.
#[derive(Debug, Clone)]
pub struct LmEntry {
    /// Unique name (`llama_mini_s` / `llama_mini_m`).
    pub name: String,
    /// Vocab size.
    pub vocab: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Hidden dim.
    pub dim: usize,
    /// Decoder-block split index.
    pub split: usize,
    /// Compiled batch (== n_choices).
    pub batch: usize,
    /// Flat hidden-state length `T`.
    pub hidden_len: usize,
    /// Per-task baseline accuracy (build-time, uncompressed).
    pub baseline_accuracy: BTreeMap<String, f64>,
    /// HLO artifact paths.
    pub artifacts: ArtifactPaths,
    /// Task binaries.
    pub tasks: Vec<TaskFile>,
}

/// Parsed manifest plus its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory containing manifest.json (all paths are relative to it).
    pub base_dir: PathBuf,
    /// RNG seed the build used.
    pub seed: u64,
    /// Whether this was a `--fast` (smoke) build.
    pub fast: bool,
    /// Vision entries.
    pub vision: Vec<VisionEntry>,
    /// LM entries.
    pub lm: Vec<LmEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`. Every failure names the offending
    /// file: "absent" (with the `make artifacts` hint), "malformed
    /// JSON" (with the parser's position context), and schema errors
    /// are three different problems and must read as such.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!(
                "cannot read {} ({e}) — run `make artifacts` first",
                path.display()
            ))
        })?;
        let v = json::parse(&text).map_err(|e| {
            Error::artifact(format!("{}: malformed JSON: {e}", path.display()))
        })?;
        Self::from_value(&v, dir)
            .map_err(|e| Error::artifact(format!("{}: {e}", path.display())))
    }

    /// Parse from a JSON value (tests use this directly).
    pub fn from_value(v: &Value, base_dir: PathBuf) -> Result<Self> {
        let version = v.usize_field("version")?;
        if version != 1 {
            return Err(Error::artifact(format!("unsupported manifest version {version}")));
        }
        let mut vision = Vec::new();
        for mv in v.req("vision")?.as_arr().unwrap_or(&[]) {
            let mut splits = Vec::new();
            for sv in mv.req("splits")?.as_arr().unwrap_or(&[]) {
                splits.push(SplitEntry {
                    sl: sv.usize_field("sl")?,
                    batch: sv.usize_field("batch")?,
                    feature_shape: parse_usize_arr(sv.req("feature_shape")?)?,
                    feature_len: sv.usize_field("feature_len")?,
                    artifacts: ArtifactPaths::parse(sv.req("artifacts")?)?,
                });
            }
            vision.push(VisionEntry {
                name: mv.str_field("name")?.to_string(),
                model: mv.str_field("model")?.to_string(),
                dataset: mv.str_field("dataset")?.to_string(),
                num_classes: mv.usize_field("num_classes")?,
                input_shape: parse_usize_arr(mv.req("input_shape")?)?,
                baseline_accuracy: mv.f64_field("baseline_accuracy")?,
                test_data: mv.str_field("test_data")?.to_string(),
                splits,
            });
        }
        let mut lm = Vec::new();
        for lv in v.req("lm")?.as_arr().unwrap_or(&[]) {
            let mut baseline = BTreeMap::new();
            if let Some(obj) = lv.req("baseline_accuracy")?.as_obj() {
                for (k, val) in obj {
                    baseline.insert(
                        k.clone(),
                        val.as_f64().ok_or_else(|| Error::config("bad baseline accuracy"))?,
                    );
                }
            }
            let mut tasks = Vec::new();
            for tv in lv.req("tasks")?.as_arr().unwrap_or(&[]) {
                tasks.push(TaskFile {
                    name: tv.str_field("name")?.to_string(),
                    path: tv.str_field("path")?.to_string(),
                    n_items: tv.usize_field("n_items")?,
                });
            }
            lm.push(LmEntry {
                name: lv.str_field("name")?.to_string(),
                vocab: lv.usize_field("vocab")?,
                seq_len: lv.usize_field("seq_len")?,
                dim: lv.usize_field("dim")?,
                split: lv.usize_field("split")?,
                batch: lv.usize_field("batch")?,
                hidden_len: lv.usize_field("hidden_len")?,
                baseline_accuracy: baseline,
                artifacts: ArtifactPaths::parse(lv.req("artifacts")?)?,
                tasks,
            });
        }
        Ok(Manifest {
            base_dir,
            seed: v.usize_field("seed")? as u64,
            fast: v.get("fast").and_then(|b| b.as_bool()).unwrap_or(false),
            vision,
            lm,
        })
    }

    /// Resolve a manifest-relative path.
    pub fn resolve(&self, rel: &str) -> PathBuf {
        self.base_dir.join(rel)
    }

    /// Find a vision entry by name.
    pub fn vision_entry(&self, name: &str) -> Result<&VisionEntry> {
        self.vision
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::artifact(format!("no vision model '{name}' in manifest")))
    }

    /// Find an LM entry by name.
    pub fn lm_entry(&self, name: &str) -> Result<&LmEntry> {
        self.lm
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::artifact(format!("no lm model '{name}' in manifest")))
    }
}

fn parse_usize_arr(v: &Value) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| Error::config("expected array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| Error::config("expected integer array")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "seed": 42, "fast": true,
      "vision": [{
        "name": "resnet_mini_synth_a", "model": "resnet_mini",
        "dataset": "synth_a", "num_classes": 20,
        "input_shape": [1, 32, 32, 3], "baseline_accuracy": 0.91,
        "test_data": "data/synth_a_test.bin",
        "splits": [{
          "sl": 2, "batch": 1, "feature_shape": [1, 16, 16, 32],
          "feature_len": 8192,
          "artifacts": {"head": "models/h.hlo.txt", "tail": "models/t.hlo.txt",
                         "head_raw": "models/hr.hlo.txt", "tail_raw": "models/tr.hlo.txt"}
        }]
      }],
      "lm": [{
        "name": "llama_mini_s", "vocab": 512, "seq_len": 64, "dim": 128,
        "split": 2, "batch": 4, "hidden_len": 32768,
        "baseline_accuracy": {"retrieval": 0.9},
        "artifacts": {"head": "models/lh.hlo.txt", "tail": "models/lt.hlo.txt",
                       "head_raw": "models/lhr.hlo.txt", "tail_raw": "models/ltr.hlo.txt"},
        "tasks": [{"name": "retrieval", "path": "data/lm_retrieval.bin", "n_items": 64}]
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(&v, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.seed, 42);
        assert!(m.fast);
        let ve = m.vision_entry("resnet_mini_synth_a").unwrap();
        assert_eq!(ve.num_classes, 20);
        let s = ve.split(2, 1).unwrap();
        assert_eq!(s.feature_len, 8192);
        assert!(ve.split(3, 1).is_err());
        let le = m.lm_entry("llama_mini_s").unwrap();
        assert_eq!(le.dim, 128);
        assert_eq!(le.baseline_accuracy["retrieval"], 0.9);
        assert_eq!(m.resolve("x/y"), PathBuf::from("/tmp/a/x/y"));
    }

    #[test]
    fn missing_fields_error() {
        let v = json::parse(r#"{"version": 1, "seed": 1}"#).unwrap();
        assert!(Manifest::from_value(&v, PathBuf::new()).is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let v = json::parse(r#"{"version": 9, "seed": 1, "vision": [], "lm": []}"#).unwrap();
        assert!(Manifest::from_value(&v, PathBuf::new()).is_err());
    }

    #[test]
    fn out_of_range_split_lookup_names_the_request() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(&v, PathBuf::new()).unwrap();
        let ve = m.vision_entry("resnet_mini_synth_a").unwrap();
        for (sl, batch) in [(3usize, 1usize), (2, 9), (0, 0)] {
            let err = ve.split(sl, batch).unwrap_err().to_string();
            assert!(
                err.contains(&format!("SL{sl} batch {batch}"))
                    && err.contains("resnet_mini_synth_a"),
                "lookup ({sl},{batch}) must name itself and the entry: {err}"
            );
        }
    }

    /// A scratch dir for the `load` tests (no tempfile crate offline).
    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("rans_sc_manifest_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_names_the_path_on_malformed_json() {
        let dir = scratch("badjson");
        std::fs::write(dir.join("manifest.json"), "{\"version\": 1, ").unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(
            err.contains("manifest.json") && err.contains("malformed JSON"),
            "must name the file and the failure class: {err}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_names_the_path_on_schema_errors() {
        let dir = scratch("badschema");
        // Valid JSON, but the manifest schema is incomplete.
        std::fs::write(dir.join("manifest.json"), r#"{"version": 1, "seed": 1}"#).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest.json"), "schema errors must carry the path: {err}");
        assert!(!err.contains("malformed JSON"), "schema != syntax: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_distinguishes_absent_from_corrupt() {
        let dir = scratch("absent");
        let err = Manifest::load(dir.join("nope")).unwrap_err().to_string();
        assert!(
            err.contains("cannot read") && err.contains("make artifacts"),
            "absent manifest keeps the build hint: {err}"
        );
        assert!(!err.contains("malformed"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
