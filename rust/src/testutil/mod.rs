//! Mini property-testing framework.
//!
//! proptest is unavailable offline, so the crate carries a compact
//! equivalent used by the integration suites: seeded generation from
//! [`crate::util::prng::Rng`], a fixed case budget, failure reporting
//! with the reproducing seed, and greedy shrinking for slice-shaped
//! inputs. Property tests across the repo call [`check`] /
//! [`check_shrink`]; override the base seed with `RANS_SC_PROP_SEED` to
//! replay a failure.

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::prng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Base seed: env `RANS_SC_PROP_SEED` or a fixed default.
pub fn base_seed() -> u64 {
    std::env::var("RANS_SC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Outcome of a single property evaluation.
fn holds<T>(prop: &(impl Fn(&T) -> bool + std::panic::RefUnwindSafe), input: &T) -> bool
where
    T: std::panic::RefUnwindSafe,
{
    catch_unwind(AssertUnwindSafe(|| prop(input))).unwrap_or(false)
}

/// Run `prop` over `cases` generated inputs; panics with the seed and a
/// debug dump of the failing input on the first counterexample.
pub fn check<T: std::fmt::Debug + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool + std::panic::RefUnwindSafe,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !holds(&prop, &input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n{input:#?}\n\
                 replay with RANS_SC_PROP_SEED={}",
                base
            );
        }
    }
}

/// Like [`check`] for `Vec` inputs, with greedy shrinking: on failure,
/// repeatedly tries dropping halves and single elements while the
/// property still fails, then reports the minimized counterexample.
pub fn check_shrink<E: Clone + std::fmt::Debug + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> Vec<E>,
    prop: impl Fn(&Vec<E>) -> bool + std::panic::RefUnwindSafe,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !holds(&prop, &input) {
            let minimized = shrink_vec(input, &prop);
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x});\n\
                 minimized counterexample ({} elems):\n{minimized:#?}\n\
                 replay with RANS_SC_PROP_SEED={}",
                minimized.len(),
                base
            );
        }
    }
}

/// Greedy shrink: drop chunks (halves, quarters, …) then single
/// elements, keeping any reduction that still fails the property.
pub fn shrink_vec<E: Clone + std::panic::RefUnwindSafe>(
    mut failing: Vec<E>,
    prop: &(impl Fn(&Vec<E>) -> bool + std::panic::RefUnwindSafe),
) -> Vec<E> {
    debug_assert!(!holds(prop, &failing));
    let mut chunk = failing.len() / 2;
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= failing.len() {
            let mut candidate = failing.clone();
            candidate.drain(i..i + chunk);
            if !holds(prop, &candidate) {
                failing = candidate; // keep the smaller failure
                // do not advance i: the next chunk shifted into place
            } else {
                i += chunk;
            }
        }
        chunk /= 2;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("u64 halves", 64, |r| r.next_u64(), |&x| x / 2 <= x);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 8, |r| r.next_u64(), |_| false);
    }

    #[test]
    fn shrink_minimizes() {
        // Property: "no element is >= 100". Failing input has some large
        // elements; shrink should reduce to exactly one offending element.
        let prop = |v: &Vec<u32>| v.iter().all(|&x| x < 100);
        let failing = vec![1, 2, 500, 3, 4, 700, 5];
        let min = shrink_vec(failing, &prop);
        assert_eq!(min.len(), 1);
        assert!(min[0] >= 100);
    }

    #[test]
    fn shrink_handles_panicking_property() {
        // Property panics on bad input instead of returning false.
        let prop = |v: &Vec<u32>| {
            if v.contains(&7) {
                panic!("boom");
            }
            true
        };
        let min = shrink_vec(vec![1, 7, 2, 7, 3], &prop);
        assert_eq!(min, vec![7]);
    }

    #[test]
    #[should_panic(expected = "minimized counterexample (1 elems)")]
    fn check_shrink_reports_minimized() {
        check_shrink(
            "no 42s",
            32,
            |r| (0..50).map(|_| r.below(64) as u32).collect(),
            |v| !v.contains(&42),
        );
    }
}
