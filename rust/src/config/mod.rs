//! Configuration system: JSON files + `key=value` overrides.
//!
//! The launcher reads an optional JSON config file and applies
//! dotted-path CLI overrides (`--set channel.gamma_db=20`), so every
//! experiment in EXPERIMENTS.md is reproducible from a recorded command
//! line. Defaults mirror the paper's §4.1 setup.

use std::path::Path;

use crate::channel::ChannelParams;
use crate::coordinator::session::SessionConfig;
use crate::error::{Error, Result};
use crate::tensor::Dtype;
use crate::util::json::{self, ObjBuilder, Value};

/// Top-level application configuration.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// Artifact directory (manifest.json root).
    pub artifacts_dir: String,
    /// Default model route.
    pub model: String,
    /// Split layer.
    pub sl: usize,
    /// Artifact batch size.
    pub batch: usize,
    /// AIQ bit-width Q.
    pub q: u8,
    /// rANS lanes.
    pub lanes: usize,
    /// Interleaved rANS states per lane (1 = v1 scalar streams; 2, 4,
    /// or 8 select the v2 multi-state layout — 4 and 8 additionally
    /// unlock the SSE4.1/AVX2 SIMD decode paths where the host has
    /// them).
    pub states: usize,
    /// Element type of the intermediate features shipped edge→cloud
    /// (`f32`, `f16`, or `bf16` — `--set dtype=bf16` selects the
    /// Llama2-style half-precision LM path). Containers carry the tag
    /// on the wire, so decoders need no matching setting.
    pub dtype: Dtype,
    /// Thread the rANS lanes on encode.
    pub parallel: bool,
    /// Run the one-shot `lanes × states` microbenchmark autotuner
    /// ([`crate::engine::autotune`]) at first use and adopt its pick
    /// for any knob not explicitly set (`--set autotune=off` to
    /// disable).
    pub autotune: bool,
    /// Cloud listen / connect address.
    pub addr: String,
    /// Wireless channel parameters.
    pub channel: ChannelParams,
    /// Batcher buckets.
    pub buckets: Vec<usize>,
    /// Batcher max wait, microseconds.
    pub batch_wait_us: u64,
    /// TCP read/write timeout, milliseconds (`0` disables, restoring
    /// blocking sockets).
    pub io_timeout_ms: u64,
    /// Cloud-side in-flight cap; excess requests are shed with `Busy`.
    pub max_inflight: usize,
    /// Session-layer retry/deadline/heartbeat policy
    /// (`session.deadline_ms`, `session.max_retries`, … as dotted keys).
    pub session: SessionConfig,
    /// Model-registry settings (`registry.dir`, `registry.key`,
    /// `registry.key_id`, `registry.model_version` as dotted keys).
    pub registry: RegistryConfig,
    /// Serving-daemon settings (`daemon.tenant_quota`,
    /// `daemon.max_queue`, `daemon.batch_max`, … as dotted keys). The
    /// daemon additionally reuses the top-level `buckets`,
    /// `batch_wait_us`, and `max_inflight` keys — see
    /// [`AppConfig::daemon_config`].
    pub daemon: DaemonSection,
    /// True once `lanes` was set explicitly (file or override) — the
    /// autotuner never overrides an explicit choice. Recorded configs
    /// re-pin on load, so experiment records reproduce cross-machine.
    lanes_pinned: bool,
    /// True once `states` was set explicitly (see `lanes_pinned`).
    states_pinned: bool,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            artifacts_dir: "artifacts".into(),
            model: "resnet_mini_synth_a".into(),
            sl: 2,
            batch: 1,
            q: 4,
            lanes: 8,
            states: 1,
            dtype: Dtype::F32,
            parallel: true,
            autotune: true,
            addr: "127.0.0.1:7439".into(),
            channel: ChannelParams::default(),
            buckets: vec![1, 8],
            batch_wait_us: 2000,
            io_timeout_ms: 5_000,
            max_inflight: 32,
            session: SessionConfig::default(),
            registry: RegistryConfig::default(),
            daemon: DaemonSection::default(),
            lanes_pinned: false,
            states_pinned: false,
        }
    }
}

/// Settings for the actor-based serving daemon (`rans-sc serve-cloud
/// --daemon` and `rans-sc loadgen`). All of these seed the daemon's
/// live-reconfigurable [`ServingKnobs`](crate::coordinator::ServingKnobs)
/// / controller; they are starting points, not hard-wired limits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DaemonSection {
    /// Per-tenant in-flight quota.
    pub tenant_quota: usize,
    /// Batch queue-depth bound (jobs beyond it shed with `Busy`).
    pub max_queue: usize,
    /// Executor actors (parallel batch lanes).
    pub executors: usize,
    /// Adaptive controller: batch-ceiling floor.
    pub batch_min: usize,
    /// Adaptive controller: batch-ceiling cap.
    pub batch_max: usize,
    /// Adaptive controller: p99 SLO in milliseconds ×1000 (stored as
    /// integer micros so the config stays integer-typed; 25_000 = 25ms).
    pub p99_target_us: u64,
    /// Adaptive controller: observations per decision window.
    pub window: usize,
}

impl Default for DaemonSection {
    fn default() -> Self {
        DaemonSection {
            tenant_quota: 16,
            max_queue: 256,
            executors: 2,
            batch_min: 1,
            batch_max: 32,
            p99_target_us: 25_000,
            window: 64,
        }
    }
}

/// Settings for the signed, content-addressed model registry
/// (`rans-sc registry …` subcommands and version-pinned serving).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegistryConfig {
    /// Registry root directory (chunk objects + signed manifests).
    pub dir: String,
    /// HMAC signing/verification key. The raw string bytes are the key;
    /// empty means "not configured" and the registry subcommands refuse
    /// to run rather than sign with a guessable default.
    pub key: String,
    /// Identifier of the key, bound into signed manifests so a verifier
    /// rejects documents signed under a rotated-out key.
    pub key_id: String,
    /// Deployment version to pin serving to (0 = unversioned legacy:
    /// no version headers, no skew checks).
    pub model_version: u64,
    /// Directory `registry fetch` writes the verified halves into when
    /// no explicit output paths are given on the command line.
    pub out: String,
    /// Chunking strategy for `registry publish`: `"fixed"` (1 MiB
    /// boundaries) or `"cdc"` (content-defined gear-hash boundaries,
    /// insertion-tolerant across versions).
    pub chunking: String,
    /// Source registry directory for `registry sync` / `registry delta`
    /// (a mirror to pull missing chunks from). Empty = must be given on
    /// the command line.
    pub src: String,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            dir: "registry".into(),
            key: String::new(),
            key_id: "default".into(),
            model_version: 0,
            out: "fetched".into(),
            chunking: "fixed".into(),
            src: String::new(),
        }
    }
}

impl AppConfig {
    /// Load from a JSON file, falling back to defaults for absent keys.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::config(format!("{}: {e}", path.as_ref().display())))?;
        let v = json::parse(&text)?;
        let mut cfg = AppConfig::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    /// Merge a JSON object into the config.
    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::config("config root must be an object"))?;
        for (k, val) in obj {
            self.apply_value(k, val)?;
        }
        Ok(())
    }

    fn apply_value(&mut self, key: &str, val: &Value) -> Result<()> {
        let bad = || Error::config(format!("bad value for '{key}'"));
        match key {
            "artifacts_dir" => self.artifacts_dir = val.as_str().ok_or_else(bad)?.into(),
            "model" => self.model = val.as_str().ok_or_else(bad)?.into(),
            "sl" => self.sl = val.as_usize().ok_or_else(bad)?,
            "batch" => self.batch = val.as_usize().ok_or_else(bad)?,
            "q" => {
                let q = val.as_usize().ok_or_else(bad)?;
                if !(1..=16).contains(&q) {
                    return Err(Error::config(format!("q={q} outside [1,16]")));
                }
                self.q = q as u8;
            }
            "lanes" => {
                self.lanes = val.as_usize().ok_or_else(bad)?;
                self.lanes_pinned = true;
            }
            "states" => {
                let s = val.as_usize().ok_or_else(bad)?;
                if !crate::rans::multistate::supported_states(s) {
                    return Err(Error::config(format!(
                        "states={s} unsupported (supported: 1, 2, 4, 8)"
                    )));
                }
                self.states = s;
                self.states_pinned = true;
            }
            "dtype" => {
                let s = val.as_str().ok_or_else(bad)?;
                self.dtype = Dtype::parse(s)?;
            }
            "parallel" => self.parallel = val.as_bool().ok_or_else(bad)?,
            // Accepts JSON booleans (config files, `--set autotune=false`)
            // and the on/off spelling the CLI escape hatch documents
            // (`--set autotune=off`, which arrives as a string).
            "autotune" => {
                self.autotune = match (val.as_bool(), val.as_str()) {
                    (Some(b), _) => b,
                    (None, Some("on")) => true,
                    (None, Some("off")) => false,
                    _ => return Err(bad()),
                }
            }
            "addr" => self.addr = val.as_str().ok_or_else(bad)?.into(),
            "buckets" => {
                let arr = val.as_arr().ok_or_else(bad)?;
                self.buckets = arr
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(bad))
                    .collect::<Result<_>>()?;
            }
            "batch_wait_us" => self.batch_wait_us = val.as_usize().ok_or_else(bad)? as u64,
            "io_timeout_ms" => self.io_timeout_ms = val.as_usize().ok_or_else(bad)? as u64,
            "max_inflight" => self.max_inflight = val.as_usize().ok_or_else(bad)?,
            "session" => {
                let obj = val.as_obj().ok_or_else(bad)?;
                for (sk, sv) in obj {
                    self.apply_value(&format!("session.{sk}"), sv)?;
                }
            }
            "session.deadline_ms" => {
                self.session.deadline_ms = val.as_usize().ok_or_else(bad)? as u64
            }
            "session.try_timeout_ms" => {
                self.session.try_timeout_ms = val.as_usize().ok_or_else(bad)? as u64
            }
            "session.max_retries" => {
                self.session.max_retries = val.as_usize().ok_or_else(bad)? as u32
            }
            "session.base_backoff_ms" => {
                self.session.base_backoff_ms = val.as_usize().ok_or_else(bad)? as u64
            }
            "session.max_backoff_ms" => {
                self.session.max_backoff_ms = val.as_usize().ok_or_else(bad)? as u64
            }
            "session.heartbeat_ms" => {
                self.session.heartbeat_ms = val.as_usize().ok_or_else(bad)? as u64
            }
            "session.seed" => self.session.seed = val.as_usize().ok_or_else(bad)? as u64,
            "registry" => {
                let obj = val.as_obj().ok_or_else(bad)?;
                for (rk, rv) in obj {
                    self.apply_value(&format!("registry.{rk}"), rv)?;
                }
            }
            "registry.dir" => self.registry.dir = val.as_str().ok_or_else(bad)?.into(),
            "registry.key" => self.registry.key = val.as_str().ok_or_else(bad)?.into(),
            "registry.key_id" => self.registry.key_id = val.as_str().ok_or_else(bad)?.into(),
            "registry.model_version" => {
                self.registry.model_version = val.as_usize().ok_or_else(bad)? as u64
            }
            "registry.out" => self.registry.out = val.as_str().ok_or_else(bad)?.into(),
            "registry.chunking" => {
                let v = val.as_str().ok_or_else(bad)?;
                if v != "fixed" && v != "cdc" {
                    return Err(Error::config(format!(
                        "registry.chunking must be 'fixed' or 'cdc', got '{v}'"
                    )));
                }
                self.registry.chunking = v.into();
            }
            "registry.src" => self.registry.src = val.as_str().ok_or_else(bad)?.into(),
            "daemon" => {
                let obj = val.as_obj().ok_or_else(bad)?;
                for (dk, dv) in obj {
                    self.apply_value(&format!("daemon.{dk}"), dv)?;
                }
            }
            "daemon.tenant_quota" => self.daemon.tenant_quota = val.as_usize().ok_or_else(bad)?,
            "daemon.max_queue" => self.daemon.max_queue = val.as_usize().ok_or_else(bad)?,
            "daemon.executors" => self.daemon.executors = val.as_usize().ok_or_else(bad)?,
            "daemon.batch_min" => self.daemon.batch_min = val.as_usize().ok_or_else(bad)?,
            "daemon.batch_max" => {
                let m = val.as_usize().ok_or_else(bad)?;
                if m == 0 {
                    return Err(Error::config("daemon.batch_max must be >= 1"));
                }
                self.daemon.batch_max = m;
            }
            "daemon.p99_target_us" => {
                self.daemon.p99_target_us = val.as_usize().ok_or_else(bad)? as u64
            }
            "daemon.window" => self.daemon.window = val.as_usize().ok_or_else(bad)?,
            "channel" => {
                let obj = val.as_obj().ok_or_else(bad)?;
                for (ck, cv) in obj {
                    self.apply_value(&format!("channel.{ck}"), cv)?;
                }
            }
            "channel.epsilon" => self.channel.epsilon = val.as_f64().ok_or_else(bad)?,
            "channel.bandwidth_hz" => self.channel.bandwidth_hz = val.as_f64().ok_or_else(bad)?,
            "channel.gamma_db" => self.channel.gamma_db = val.as_f64().ok_or_else(bad)?,
            "channel.sigma_h2" => self.channel.sigma_h2 = val.as_f64().ok_or_else(bad)?,
            other => return Err(Error::config(format!("unknown config key '{other}'"))),
        }
        Ok(())
    }

    /// Apply a `key=value` override (dotted paths for nesting).
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let (key, raw) = spec
            .split_once('=')
            .ok_or_else(|| Error::config(format!("override '{spec}' is not key=value")))?;
        // Interpret the raw value as JSON if possible, else as a string.
        let val = json::parse(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.apply_value(key, &val)
    }

    /// True iff `lanes` was set explicitly (file or override) — the
    /// autotuner leaves pinned knobs alone.
    pub fn lanes_pinned(&self) -> bool {
        self.lanes_pinned
    }

    /// True iff `states` was set explicitly (see [`Self::lanes_pinned`]).
    pub fn states_pinned(&self) -> bool {
        self.states_pinned
    }

    /// Assemble the serving-daemon config from the `daemon.*` section
    /// plus the shared top-level serving keys (`buckets`,
    /// `batch_wait_us`, `max_inflight`).
    pub fn daemon_config(&self) -> crate::coordinator::DaemonConfig {
        crate::coordinator::DaemonConfig {
            buckets: self.buckets.clone(),
            max_queue: self.daemon.max_queue,
            max_wait: std::time::Duration::from_micros(self.batch_wait_us),
            max_inflight: self.max_inflight,
            tenant_quota: self.daemon.tenant_quota,
            executors: self.daemon.executors,
            controller: crate::coordinator::daemon::controller::ControllerConfig {
                min_batch: self.daemon.batch_min,
                max_batch: self.daemon.batch_max,
                p99_target_ms: self.daemon.p99_target_us as f64 / 1e3,
                window: self.daemon.window,
                ..Default::default()
            },
        }
    }

    /// Serialize the effective config (for experiment records).
    pub fn to_json(&self) -> Value {
        ObjBuilder::new()
            .field("artifacts_dir", self.artifacts_dir.as_str())
            .field("model", self.model.as_str())
            .field("sl", self.sl)
            .field("batch", self.batch)
            .field("q", self.q as usize)
            .field("lanes", self.lanes)
            .field("states", self.states)
            .field("dtype", self.dtype.name())
            .field("parallel", self.parallel)
            .field("autotune", self.autotune)
            .field("addr", self.addr.as_str())
            .field("buckets", self.buckets.clone())
            .field("batch_wait_us", self.batch_wait_us as usize)
            .field("io_timeout_ms", self.io_timeout_ms as usize)
            .field("max_inflight", self.max_inflight)
            .field(
                "session",
                ObjBuilder::new()
                    .field("deadline_ms", self.session.deadline_ms as usize)
                    .field("try_timeout_ms", self.session.try_timeout_ms as usize)
                    .field("max_retries", self.session.max_retries as usize)
                    .field("base_backoff_ms", self.session.base_backoff_ms as usize)
                    .field("max_backoff_ms", self.session.max_backoff_ms as usize)
                    .field("heartbeat_ms", self.session.heartbeat_ms as usize)
                    .field("seed", self.session.seed as usize)
                    .build(),
            )
            .field(
                "registry",
                ObjBuilder::new()
                    .field("dir", self.registry.dir.as_str())
                    .field("key", self.registry.key.as_str())
                    .field("key_id", self.registry.key_id.as_str())
                    .field("model_version", self.registry.model_version as usize)
                    .field("out", self.registry.out.as_str())
                    .field("chunking", self.registry.chunking.as_str())
                    .field("src", self.registry.src.as_str())
                    .build(),
            )
            .field(
                "daemon",
                ObjBuilder::new()
                    .field("tenant_quota", self.daemon.tenant_quota)
                    .field("max_queue", self.daemon.max_queue)
                    .field("executors", self.daemon.executors)
                    .field("batch_min", self.daemon.batch_min)
                    .field("batch_max", self.daemon.batch_max)
                    .field("p99_target_us", self.daemon.p99_target_us as usize)
                    .field("window", self.daemon.window)
                    .build(),
            )
            .field(
                "channel",
                ObjBuilder::new()
                    .field("epsilon", self.channel.epsilon)
                    .field("bandwidth_hz", self.channel.bandwidth_hz)
                    .field("gamma_db", self.channel.gamma_db)
                    .field("sigma_h2", self.channel.sigma_h2)
                    .build(),
            )
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = AppConfig::default();
        assert_eq!(c.q, 4);
        assert_eq!(c.channel.epsilon, 0.001);
        assert_eq!(c.channel.bandwidth_hz, 10e6);
        assert_eq!(c.channel.gamma_db, 10.0);
    }

    #[test]
    fn json_roundtrip() {
        let c = AppConfig::default();
        let text = c.to_json().to_string_pretty();
        let mut c2 = AppConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c2.q, c.q);
        assert_eq!(c2.buckets, c.buckets);
        assert_eq!(c2.channel, c.channel);
        assert_eq!(c2.dtype, c.dtype);
        assert_eq!(c2.session, c.session);
        assert_eq!(c2.io_timeout_ms, c.io_timeout_ms);
        assert_eq!(c2.max_inflight, c.max_inflight);
        assert_eq!(c2.registry, c.registry);
    }

    #[test]
    fn registry_overrides_and_roundtrip() {
        let mut c = AppConfig::default();
        assert_eq!(c.registry.model_version, 0, "default serving is unversioned");
        assert!(c.registry.key.is_empty(), "no guessable default signing key");
        c.apply_override("registry.dir=/tmp/reg").unwrap();
        c.apply_override("registry.key=super-secret").unwrap();
        c.apply_override("registry.key_id=prod-2026").unwrap();
        c.apply_override("registry.model_version=7").unwrap();
        c.apply_override("registry.out=/tmp/deploy").unwrap();
        c.apply_override("registry.chunking=cdc").unwrap();
        c.apply_override("registry.src=/mnt/mirror").unwrap();
        assert_eq!(c.registry.dir, "/tmp/reg");
        assert_eq!(c.registry.key, "super-secret");
        assert_eq!(c.registry.key_id, "prod-2026");
        assert_eq!(c.registry.model_version, 7);
        assert_eq!(c.registry.out, "/tmp/deploy");
        assert_eq!(c.registry.chunking, "cdc");
        assert_eq!(c.registry.src, "/mnt/mirror");
        assert!(c.apply_override("registry.chunking=rolling").is_err());
        let text = c.to_json().to_string_pretty();
        let mut c2 = AppConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c2.registry, c.registry);
        assert!(c.apply_override("registry.nonsense=1").is_err());
        assert!(c.apply_override("registry.model_version=x").is_err());
    }

    #[test]
    fn session_overrides_and_roundtrip() {
        let mut c = AppConfig::default();
        c.apply_override("session.deadline_ms=1500").unwrap();
        c.apply_override("session.max_retries=7").unwrap();
        c.apply_override("session.heartbeat_ms=250").unwrap();
        c.apply_override("io_timeout_ms=900").unwrap();
        c.apply_override("max_inflight=4").unwrap();
        assert_eq!(c.session.deadline_ms, 1500);
        assert_eq!(c.session.max_retries, 7);
        assert_eq!(c.session.heartbeat_ms, 250);
        assert_eq!(c.io_timeout_ms, 900);
        assert_eq!(c.max_inflight, 4);
        let text = c.to_json().to_string_pretty();
        let mut c2 = AppConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c2.session, c.session);
        assert_eq!(c2.io_timeout_ms, 900);
        assert_eq!(c2.max_inflight, 4);
    }

    #[test]
    fn daemon_overrides_and_roundtrip() {
        let mut c = AppConfig::default();
        assert_eq!(c.daemon, DaemonSection::default());
        c.apply_override("daemon.tenant_quota=4").unwrap();
        c.apply_override("daemon.max_queue=512").unwrap();
        c.apply_override("daemon.executors=8").unwrap();
        c.apply_override("daemon.batch_min=2").unwrap();
        c.apply_override("daemon.batch_max=64").unwrap();
        c.apply_override("daemon.p99_target_us=10000").unwrap();
        c.apply_override("daemon.window=128").unwrap();
        assert_eq!(c.daemon.tenant_quota, 4);
        assert_eq!(c.daemon.max_queue, 512);
        assert_eq!(c.daemon.executors, 8);
        assert_eq!(c.daemon.batch_min, 2);
        assert_eq!(c.daemon.batch_max, 64);
        assert_eq!(c.daemon.p99_target_us, 10_000);
        assert_eq!(c.daemon.window, 128);
        let text = c.to_json().to_string_pretty();
        let mut c2 = AppConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c2.daemon, c.daemon);
        assert!(c.apply_override("daemon.nonsense=1").is_err());
        assert!(c.apply_override("daemon.batch_max=0").is_err());
        assert!(c.apply_override("daemon.window=x").is_err());
        // The assembled DaemonConfig stitches daemon.* with the shared
        // top-level serving keys.
        c.apply_override("batch_wait_us=750").unwrap();
        c.apply_override("max_inflight=9").unwrap();
        let d = c.daemon_config();
        assert_eq!(d.max_wait, std::time::Duration::from_micros(750));
        assert_eq!(d.max_inflight, 9);
        assert_eq!(d.tenant_quota, 4);
        assert_eq!(d.controller.max_batch, 64);
        assert!((d.controller.p99_target_ms - 10.0).abs() < 1e-9);
    }

    #[test]
    fn dtype_json_roundtrip_non_default() {
        let mut c = AppConfig::default();
        c.apply_override("dtype=bf16").unwrap();
        let text = c.to_json().to_string_pretty();
        let mut c2 = AppConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c2.dtype, Dtype::Bf16);
    }

    #[test]
    fn overrides() {
        let mut c = AppConfig::default();
        c.apply_override("q=6").unwrap();
        c.apply_override("channel.gamma_db=20").unwrap();
        c.apply_override("model=llama_mini_s").unwrap();
        c.apply_override("parallel=false").unwrap();
        c.apply_override("buckets=[1,4,16]").unwrap();
        c.apply_override("states=4").unwrap();
        assert_eq!(c.states, 4);
        c.apply_override("states=8").unwrap();
        assert_eq!(c.states, 8);
        assert!(c.states_pinned());
        assert!(!c.lanes_pinned());
        c.apply_override("lanes=4").unwrap();
        assert!(c.lanes_pinned());
        assert!(c.autotune);
        c.apply_override("autotune=off").unwrap();
        assert!(!c.autotune);
        c.apply_override("autotune=on").unwrap();
        assert!(c.autotune);
        c.apply_override("autotune=false").unwrap();
        assert!(!c.autotune);
        c.apply_override("autotune=true").unwrap();
        assert!(c.autotune);
        assert_eq!(c.dtype, Dtype::F32);
        c.apply_override("dtype=bf16").unwrap();
        assert_eq!(c.dtype, Dtype::Bf16);
        c.apply_override("dtype=f16").unwrap();
        assert_eq!(c.dtype, Dtype::F16);
        c.apply_override("dtype=f32").unwrap();
        assert_eq!(c.dtype, Dtype::F32);
        assert_eq!(c.q, 6);
        assert_eq!(c.channel.gamma_db, 20.0);
        assert_eq!(c.model, "llama_mini_s");
        assert!(!c.parallel);
        assert_eq!(c.buckets, vec![1, 4, 16]);
    }

    #[test]
    fn bad_overrides_rejected() {
        let mut c = AppConfig::default();
        assert!(c.apply_override("nonsense").is_err());
        assert!(c.apply_override("q=99").is_err());
        assert!(c.apply_override("states=3").is_err());
        assert!(c.apply_override("states=16").is_err());
        assert!(c.apply_override("dtype=f64").is_err());
        assert!(c.apply_override("dtype=half").is_err());
        assert!(c.apply_override("unknown_key=1").is_err());
        assert!(c.apply_override("sl=x").is_err());
        assert!(c.apply_override("autotune=maybe").is_err());
        assert!(c.apply_override("autotune=1").is_err());
        assert!(c.apply_override("session.deadline_ms=x").is_err());
        assert!(c.apply_override("session.nonsense=1").is_err());
        assert!(c.apply_override("max_inflight=no").is_err());
    }

    /// Recorded configs must reproduce cross-machine: serializing pins
    /// lanes/states on re-load, so the autotuner cannot change them.
    #[test]
    fn json_roundtrip_pins_tunable_knobs() {
        let c = AppConfig::default();
        assert!(!c.lanes_pinned() && !c.states_pinned());
        let text = c.to_json().to_string_pretty();
        let mut c2 = AppConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert!(c2.lanes_pinned() && c2.states_pinned());
        assert!(c2.autotune);
        c2.apply_override("autotune=off").unwrap();
        let text = c2.to_json().to_string_pretty();
        let mut c3 = AppConfig::default();
        c3.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert!(!c3.autotune);
    }
}
