//! # rans-sc
//!
//! Reproduction of *"Range Asymmetric Numeral Systems-Based Lightweight
//! Intermediate Feature Compression for Split Computing of Deep Neural
//! Networks"* (Sung, Im, Palakonda & Kang, CS.DC 2025).
//!
//! The crate implements the paper's full system as the Layer-3 (Rust)
//! coordinator of a three-layer Rust + JAX + Pallas stack:
//!
//! * [`rans`] — the core range-ANS entropy codec (Eqs. 2–4), including an
//!   N-way interleaved variant used for multi-lane (GPU-style) throughput.
//! * [`quant`] — asymmetric integer quantization, AIQ (Eq. 6).
//! * [`tensor`] — dtype-tagged zero-copy tensor views ([`tensor::TensorRef`] /
//!   [`tensor::TensorMut`]) with hand-rolled f16/bf16 conversions, so
//!   half-precision LM features compress without an intermediate f32 copy.
//! * [`sparse`] — the *modified* CSR format with non-cumulative row counts.
//! * [`reshape`] — the entropy/cost model `T_tot(N) = ℓ_D · H(p(N))` and
//!   Algorithm 1 (approximate enumeration for the optimal reshape `Ñ`).
//! * [`pipeline`] — the end-to-end intermediate-feature codec
//!   (reshape → AIQ → CSR → concat → rANS) and its container format.
//! * [`engine`] — the persistent chunk-parallel compression engine: a
//!   long-lived worker pool shared by every caller, a per-(shape, Q)
//!   reshape-plan cache, and the chunked v2 container with per-chunk
//!   checksums for streaming/partial decode. The [`pipeline`] entry
//!   points are thin wrappers over the shared engine.
//! * [`channel`] — the ε-outage wireless channel latency model.
//! * [`baselines`] — E-1 binary serialization, E-2 tANS, E-3 DietGPU-style
//!   interleaved rANS, plus lz77/byte-rans general-purpose comparators.
//! * [`runtime`] — PJRT executor loading AOT-lowered HLO artifacts
//!   produced by the Python (JAX + Pallas) compile path (offline builds
//!   use the stub in `runtime::xla_stub`).
//! * [`coordinator`] — the split-computing serving system: edge node,
//!   cloud node, wire protocol, transports, dynamic batcher, router —
//!   all sharing the engine's worker pool.
//! * [`telemetry`] — metrics registry and latency-breakdown histograms.
//! * [`eval`] — experiment drivers shared by `benches/` and `examples/`.
//!
//! Python/JAX runs only at build time (`make artifacts`); the binaries in
//! this crate are self-contained once `artifacts/` exists.

pub mod baselines;
pub mod channel;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod eval;
pub mod pipeline;
pub mod quant;
pub mod rans;
pub mod reshape;
pub mod runtime;
pub mod sparse;
pub mod tans;
pub mod telemetry;
pub mod tensor;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};

/// Crate version string (from Cargo metadata).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
