//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. Variants are
//! grouped by subsystem so callers can branch on the failure domain
//! (codec vs. runtime vs. transport) without string matching.
//!
//! Errors additionally classify into **retryable** (a resend can
//! plausibly succeed: transport faults, timeouts, explicit load sheds)
//! vs. **fatal** (resending the same bytes reproduces the failure:
//! corruption, codec invariants, protocol/version skew) — see
//! [`Error::is_retryable`]. The session layer
//! ([`crate::coordinator::session`]) and `SimulatedLink` retransmission
//! branch on this classification instead of string matching.
//!
//! The `Display`/`Error` impls are hand-written: the offline build carries
//! no `thiserror`, and the surface is small enough that the derive buys
//! nothing.

use std::fmt;

/// Unified error type for the rans-sc crate.
#[derive(Debug)]
pub enum Error {
    /// Compressed payload is malformed (bad magic, truncated, CRC
    /// mismatch, impossible header fields).
    Corrupt(String),

    /// An entropy-codec invariant was violated (zero-frequency symbol on
    /// the encode path, state underflow, alphabet overflow).
    Codec(String),

    /// Invalid argument from the caller (shape mismatch, Q out of range,
    /// N does not divide T, empty input where data is required).
    InvalidArg(String),

    /// Artifact loading / manifest problems (missing file, bad JSON,
    /// schema mismatch between manifest and HLO artifact).
    Artifact(String),

    /// PJRT runtime failures surfaced from the XLA binding (or its
    /// offline stub).
    Runtime(String),

    /// Wire-protocol violations between edge and cloud nodes.
    Protocol(String),

    /// Transport-level failures (connection refused, simulated outage
    /// budget exhausted, channel closed).
    Transport(String),

    /// A blocking operation exceeded its deadline (transport recv
    /// timeout, session deadline exhausted). Always retryable.
    Timeout(String),

    /// The peer explicitly shed the request (bounded queue full or the
    /// deadline was provably unmeetable) and hinted when to retry.
    Rejected {
        /// Suggested backoff before retrying, milliseconds.
        retry_after_ms: u64,
        /// Human-readable shed reason.
        message: String,
    },

    /// Head/tail model deployment versions disagree (the wire's
    /// `VersionSkew` reply, or a registry/hot-swap version conflict).
    /// Fatal until the node resyncs from the registry: resending the
    /// same features meets the same mismatched tail.
    VersionSkew {
        /// The peer's (or slot's) currently active model version.
        active: u64,
        /// The version that was offered/requested and rejected.
        offered: u64,
        /// Human-readable context.
        message: String,
    },

    /// Configuration file / CLI parsing problems.
    Config(String),

    /// JSON parse errors from the hand-rolled parser in `util::json`.
    Json {
        /// Byte offset of the parse failure.
        offset: usize,
        /// Parser message.
        msg: String,
    },

    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Corrupt(m) => write!(f, "corrupt container: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Rejected { retry_after_ms, message } => {
                write!(f, "rejected (retry after {retry_after_ms} ms): {message}")
            }
            Error::VersionSkew { active, offered, message } => {
                write!(f, "model version skew (active v{active}, offered v{offered}): {message}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json { offset, msg } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for [`Error::Corrupt`].
    pub fn corrupt(msg: impl Into<String>) -> Self {
        Error::Corrupt(msg.into())
    }
    /// Shorthand constructor for [`Error::Codec`].
    pub fn codec(msg: impl Into<String>) -> Self {
        Error::Codec(msg.into())
    }
    /// Shorthand constructor for [`Error::InvalidArg`].
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArg(msg.into())
    }
    /// Shorthand constructor for [`Error::Artifact`].
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    /// Shorthand constructor for [`Error::Runtime`].
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Shorthand constructor for [`Error::Protocol`].
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
    /// Shorthand constructor for [`Error::Transport`].
    pub fn transport(msg: impl Into<String>) -> Self {
        Error::Transport(msg.into())
    }
    /// Shorthand constructor for [`Error::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand constructor for [`Error::Timeout`].
    pub fn timeout(msg: impl Into<String>) -> Self {
        Error::Timeout(msg.into())
    }
    /// Shorthand constructor for [`Error::Rejected`].
    pub fn rejected(retry_after_ms: u64, msg: impl Into<String>) -> Self {
        Error::Rejected { retry_after_ms, message: msg.into() }
    }
    /// Shorthand constructor for [`Error::VersionSkew`].
    pub fn version_skew(active: u64, offered: u64, msg: impl Into<String>) -> Self {
        Error::VersionSkew { active, offered, message: msg.into() }
    }

    /// True when a retry of the same operation can plausibly succeed.
    ///
    /// Retryable: transport faults, timeouts, explicit load sheds, and
    /// transient I/O kinds (a reset/aborted/broken connection heals by
    /// reconnecting). Fatal: corruption, codec invariants, protocol
    /// violations (including version skew — the peer will reject the
    /// resent bytes identically), bad arguments, artifact/runtime/config
    /// failures, and non-transient I/O.
    pub fn is_retryable(&self) -> bool {
        match self {
            Error::Transport(_) | Error::Timeout(_) | Error::Rejected { .. } => true,
            Error::Io(e) => matches!(
                e.kind(),
                std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::ConnectionRefused
                    | std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::NotConnected
                    | std::io::ErrorKind::Interrupted
            ),
            Error::Corrupt(_)
            | Error::Codec(_)
            | Error::InvalidArg(_)
            | Error::Artifact(_)
            | Error::Runtime(_)
            | Error::Protocol(_)
            | Error::VersionSkew { .. }
            | Error::Config(_)
            | Error::Json { .. } => false,
        }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = Error::codec("state underflow");
        assert_eq!(e.to_string(), "codec error: state underflow");
        let e = Error::Json { offset: 12, msg: "bad literal".into() };
        assert!(e.to_string().contains("byte 12"));
    }

    #[test]
    fn retryable_classification() {
        assert!(Error::transport("peer closed").is_retryable());
        assert!(Error::timeout("recv deadline").is_retryable());
        assert!(Error::rejected(25, "queue full").is_retryable());
        let transient = std::io::Error::new(std::io::ErrorKind::ConnectionReset, "rst");
        assert!(Error::Io(transient).is_retryable());
        let persistent = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        assert!(!Error::Io(persistent).is_retryable());
        // Corruption and version skew are fatal: the peer deterministically
        // rejects the same bytes again.
        assert!(!Error::corrupt("crc mismatch").is_retryable());
        assert!(!Error::protocol("peer predates dtype tagging").is_retryable());
        assert!(!Error::codec("state underflow").is_retryable());
        assert!(!Error::config("bad key").is_retryable());
        assert!(!Error::version_skew(3, 2, "edge head is behind").is_retryable());
    }

    #[test]
    fn version_skew_display_names_both_versions() {
        let e = Error::version_skew(5, 4, "resync from registry");
        assert_eq!(
            e.to_string(),
            "model version skew (active v5, offered v4): resync from registry"
        );
    }

    #[test]
    fn rejected_display_carries_hint() {
        let e = Error::rejected(40, "cloud inflight cap");
        assert_eq!(e.to_string(), "rejected (retry after 40 ms): cloud inflight cap");
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
