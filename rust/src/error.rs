//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. Variants are
//! grouped by subsystem so callers can branch on the failure domain
//! (codec vs. runtime vs. transport) without string matching.

use thiserror::Error;

/// Unified error type for the rans-sc crate.
#[derive(Debug, Error)]
pub enum Error {
    /// Compressed payload is malformed (bad magic, truncated, CRC
    /// mismatch, impossible header fields).
    #[error("corrupt container: {0}")]
    Corrupt(String),

    /// An entropy-codec invariant was violated (zero-frequency symbol on
    /// the encode path, state underflow, alphabet overflow).
    #[error("codec error: {0}")]
    Codec(String),

    /// Invalid argument from the caller (shape mismatch, Q out of range,
    /// N does not divide T, empty input where data is required).
    #[error("invalid argument: {0}")]
    InvalidArg(String),

    /// Artifact loading / manifest problems (missing file, bad JSON,
    /// schema mismatch between manifest and HLO artifact).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failures surfaced from the `xla` crate.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Wire-protocol violations between edge and cloud nodes.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Transport-level failures (connection refused, simulated outage
    /// budget exhausted, channel closed).
    #[error("transport error: {0}")]
    Transport(String),

    /// Configuration file / CLI parsing problems.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse errors from the hand-rolled parser in `util::json`.
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Underlying I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor for [`Error::Corrupt`].
    pub fn corrupt(msg: impl Into<String>) -> Self {
        Error::Corrupt(msg.into())
    }
    /// Shorthand constructor for [`Error::Codec`].
    pub fn codec(msg: impl Into<String>) -> Self {
        Error::Codec(msg.into())
    }
    /// Shorthand constructor for [`Error::InvalidArg`].
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidArg(msg.into())
    }
    /// Shorthand constructor for [`Error::Artifact`].
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    /// Shorthand constructor for [`Error::Runtime`].
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Shorthand constructor for [`Error::Protocol`].
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
    /// Shorthand constructor for [`Error::Transport`].
    pub fn transport(msg: impl Into<String>) -> Self {
        Error::Transport(msg.into())
    }
    /// Shorthand constructor for [`Error::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = Error::codec("state underflow");
        assert_eq!(e.to_string(), "codec error: state underflow");
        let e = Error::Json { offset: 12, msg: "bad literal".into() };
        assert!(e.to_string().contains("byte 12"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
