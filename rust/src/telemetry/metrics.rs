//! Metrics registry and per-request latency breakdown.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::{ObjBuilder, Value};

use super::histogram::LogHistogram;

/// The paper's four latency factors for one request (§2.2), plus queue
/// time introduced by the batcher.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// Time spent queued in the batcher before edge compute, ms.
    pub queue_ms: f64,
    /// (i) edge-side head compute + encoding, ms.
    pub encode_ms: f64,
    /// (ii) wireless transfer (simulated ε-outage latency), ms.
    pub transfer_ms: f64,
    /// (iii) cloud-side decoding, ms.
    pub decode_ms: f64,
    /// (iv) device transfer + tail compute, ms.
    pub compute_ms: f64,
}

impl LatencyBreakdown {
    /// End-to-end latency.
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.encode_ms + self.transfer_ms + self.decode_ms + self.compute_ms
    }
}

/// Thread-safe metrics registry: named counters and histograms.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<LogHistogram>>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Increment a counter by `delta`.
    pub fn incr(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Read a counter (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> Arc<LogHistogram> {
        let mut map = self.histograms.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(LogHistogram::new())))
    }

    /// Record a full latency breakdown under a prefix.
    pub fn record_breakdown(&self, prefix: &str, b: &LatencyBreakdown) {
        self.histogram(&format!("{prefix}.queue_ms")).record_ms(b.queue_ms);
        self.histogram(&format!("{prefix}.encode_ms")).record_ms(b.encode_ms);
        self.histogram(&format!("{prefix}.transfer_ms")).record_ms(b.transfer_ms);
        self.histogram(&format!("{prefix}.decode_ms")).record_ms(b.decode_ms);
        self.histogram(&format!("{prefix}.compute_ms")).record_ms(b.compute_ms);
        self.histogram(&format!("{prefix}.total_ms")).record_ms(b.total_ms());
    }

    /// Snapshot everything as JSON (for `stats` RPC and reports).
    pub fn snapshot(&self) -> Value {
        let mut counters = ObjBuilder::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters = counters.field(k, v.load(Ordering::Relaxed) as usize);
        }
        let mut hists = ObjBuilder::new();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            hists = hists.field(
                k,
                ObjBuilder::new()
                    .field("count", h.count() as usize)
                    .field("mean_ms", h.mean_ms())
                    .field("p50_ms", h.quantile_ms(0.5))
                    .field("p99_ms", h.quantile_ms(0.99))
                    .field("max_ms", h.max_ms())
                    .build(),
            );
        }
        ObjBuilder::new()
            .field("counters", counters.build())
            .field("histograms", hists.build())
            .build()
    }

    /// [`Registry::snapshot`] rendered as a compact JSON string — the
    /// machine-readable export the chaos soak and bench-smoke assert
    /// robustness counters (`session.retry_total`, `session.shed_total`,
    /// `cloud.shed_total`, `session.reconnect_total`, …) against without
    /// scraping logs.
    pub fn snapshot_json(&self) -> String {
        self.snapshot().to_string_compact()
    }

    /// A name-prefixing view of this registry: every counter and
    /// histogram touched through the returned handle lands under
    /// `<prefix>.<name>` in the parent, so scoped series show up in
    /// [`Registry::snapshot_json`] next to everything else with zero
    /// extra plumbing.
    ///
    /// The serving daemon uses `scoped("tenant.<id>")` for its
    /// per-tenant counters; the resulting key schema is
    /// `tenant.<id>.requests` / `.ok` / `.shed` / `.errors` /
    /// `.quota_rejected` (see the daemon module docs).
    pub fn scoped(self: &Arc<Self>, prefix: &str) -> Scoped {
        Scoped { registry: Arc::clone(self), prefix: prefix.to_string() }
    }

    /// Human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {}\n", h.summary()));
        }
        out
    }
}

/// Prefixing handle returned by [`Registry::scoped`]. Cheap to clone;
/// nested scopes concatenate (`scoped("tenant").scoped("a")` →
/// `tenant.a.*`).
#[derive(Debug, Clone)]
pub struct Scoped {
    registry: Arc<Registry>,
    prefix: String,
}

impl Scoped {
    /// The full prefix this handle writes under.
    pub fn prefix(&self) -> &str {
        &self.prefix
    }

    fn key(&self, name: &str) -> String {
        format!("{}.{name}", self.prefix)
    }

    /// Get or create `<prefix>.<name>` in the parent registry.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        self.registry.counter(&self.key(name))
    }

    /// Increment `<prefix>.<name>` by `delta`.
    pub fn incr(&self, name: &str, delta: u64) {
        self.registry.incr(&self.key(name), delta);
    }

    /// Read `<prefix>.<name>` (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.registry.get(&self.key(name))
    }

    /// Get or create histogram `<prefix>.<name>`.
    pub fn histogram(&self, name: &str) -> Arc<LogHistogram> {
        self.registry.histogram(&self.key(name))
    }

    /// A deeper scope under this one.
    pub fn scoped(&self, sub: &str) -> Scoped {
        Scoped { registry: Arc::clone(&self.registry), prefix: self.key(sub) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.incr("requests", 3);
        r.incr("requests", 2);
        assert_eq!(r.get("requests"), 5);
        assert_eq!(r.get("missing"), 0);
    }

    #[test]
    fn snapshot_json_exposes_robustness_counters() {
        let r = Registry::new();
        r.incr("session.retry_total", 4);
        r.incr("cloud.shed_total", 2);
        let json = r.snapshot_json();
        assert!(json.contains("\"session.retry_total\":4"), "{json}");
        assert!(json.contains("\"cloud.shed_total\":2"), "{json}");
        // Round-trips through the crate's own parser.
        let v = crate::util::json::parse(&json).unwrap();
        let counters = v.get("counters").unwrap();
        assert_eq!(counters.get("session.retry_total").unwrap().as_f64().unwrap(), 4.0);
    }

    #[test]
    fn scoped_series_land_in_the_parent_snapshot() {
        let r = Arc::new(Registry::new());
        let tenant = r.scoped("tenant.edge-07");
        tenant.incr("requests", 3);
        tenant.incr("quota_rejected", 1);
        tenant.histogram("latency_ms").record_ms(4.0);
        // Scoped writes are plain prefixed keys in the parent.
        assert_eq!(r.get("tenant.edge-07.requests"), 3);
        assert_eq!(tenant.get("requests"), 3);
        let json = r.snapshot_json();
        assert!(json.contains("\"tenant.edge-07.requests\":3"), "{json}");
        assert!(json.contains("\"tenant.edge-07.quota_rejected\":1"), "{json}");
        assert!(json.contains("tenant.edge-07.latency_ms"), "{json}");
        // Nested scopes concatenate.
        let deep = tenant.scoped("model");
        deep.incr("hits", 1);
        assert_eq!(r.get("tenant.edge-07.model.hits"), 1);
        assert_eq!(deep.prefix(), "tenant.edge-07.model");
    }

    #[test]
    fn breakdown_total() {
        let b = LatencyBreakdown {
            queue_ms: 0.5,
            encode_ms: 1.0,
            transfer_ms: 2.0,
            decode_ms: 0.5,
            compute_ms: 1.0,
        };
        assert!((b.total_ms() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn record_breakdown_populates_histograms() {
        let r = Registry::new();
        let b = LatencyBreakdown {
            queue_ms: 0.0,
            encode_ms: 1.0,
            transfer_ms: 4.0,
            decode_ms: 0.5,
            compute_ms: 0.5,
        };
        r.record_breakdown("edge", &b);
        assert_eq!(r.histogram("edge.total_ms").count(), 1);
        assert!(r.histogram("edge.transfer_ms").mean_ms() > 3.5);
    }

    #[test]
    fn snapshot_is_valid_json() {
        let r = Registry::new();
        r.incr("a", 1);
        r.histogram("lat").record_ms(2.0);
        let v = r.snapshot();
        let text = v.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("counters").unwrap().get("a").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn concurrent_counter_updates() {
        let r = std::sync::Arc::new(Registry::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let r = std::sync::Arc::clone(&r);
                s.spawn(move || {
                    for _ in 0..1000 {
                        r.incr("hits", 1);
                    }
                });
            }
        });
        assert_eq!(r.get("hits"), 8000);
    }
}
