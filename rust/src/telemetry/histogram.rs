//! Log-bucketed latency histogram.
//!
//! Fixed memory, lock-free recording (atomic buckets), ~4% relative
//! error — the standard shape for serving-path latency metrics. Buckets
//! are logarithmic over nanoseconds-to-minutes.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: covers 1 ns … ~18 minutes at 16 buckets/octave...
/// concretely `BUCKETS_PER_OCTAVE` sub-buckets per power of two over
/// 64 octaves of nanoseconds, capped.
const OCTAVES: usize = 40; // 2^40 ns ≈ 18 minutes
const SUB: usize = 8; // sub-buckets per octave → ~9% bucket width
const BUCKETS: usize = OCTAVES * SUB + 1;

/// Lock-free log-bucketed histogram of nanosecond values.
#[derive(Debug)]
pub struct LogHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LogHistogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    fn bucket_index(ns: u64) -> usize {
        if ns == 0 {
            return 0;
        }
        let octave = 63 - ns.leading_zeros() as usize; // floor(log2)
        let frac = if octave == 0 {
            0
        } else {
            // Top SUB bits below the leading bit select the sub-bucket.
            ((ns >> octave.saturating_sub(3)) & (SUB as u64 - 1)) as usize
        };
        (octave * SUB + frac).min(BUCKETS - 1)
    }

    /// Lower bound (ns) represented by bucket `i`.
    fn bucket_floor(i: usize) -> u64 {
        let octave = i / SUB;
        let frac = i % SUB;
        if octave == 0 {
            return frac as u64;
        }
        let base = 1u64 << octave;
        base + ((base as u128 * frac as u128 / SUB as u128) as u64)
    }

    /// Record one duration in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record a duration.
    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Record milliseconds (f64 convenience for simulated latencies).
    pub fn record_ms(&self, ms: f64) {
        self.record_ns((ms.max(0.0) * 1e6) as u64);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Max in milliseconds.
    pub fn max_ms(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate quantile (bucket lower bound), `q ∈ [0,1]`, in ms.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_floor(i) as f64 / 1e6;
            }
        }
        self.max_ms()
    }

    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count(),
            self.mean_ms(),
            self.quantile_ms(0.50),
            self.quantile_ms(0.99),
            self.max_ms()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_sum_max() {
        let h = LogHistogram::new();
        for ms in [1.0, 2.0, 3.0] {
            h.record_ms(ms);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean_ms() - 2.0).abs() < 0.01);
        assert!((h.max_ms() - 3.0).abs() < 0.01);
    }

    #[test]
    fn quantiles_are_monotone_and_bounded() {
        let h = LogHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1_000_000); // 1..1000 ms
        }
        let p50 = h.quantile_ms(0.5);
        let p90 = h.quantile_ms(0.9);
        let p99 = h.quantile_ms(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // ~10% bucket resolution.
        assert!((p50 - 500.0).abs() / 500.0 < 0.15, "p50 {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.15, "p99 {p99}");
    }

    #[test]
    fn zero_and_huge_values_do_not_panic() {
        let h = LogHistogram::new();
        h.record_ns(0);
        h.record_ns(u64::MAX);
        assert_eq!(h.count(), 2);
        let _ = h.summary();
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(LogHistogram::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..1000 {
                        h.record_ns(i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 8000);
    }

    #[test]
    fn empty_histogram_summary() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile_ms(0.5), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
    }
}
