//! Metrics and latency accounting.
//!
//! The paper decomposes split-computing latency into four factors
//! (§2.2): edge encode, wireless transfer, cloud decode, and GPU
//! integration + tail compute. [`LatencyBreakdown`] carries exactly that
//! decomposition per request; [`Registry`] aggregates counters and
//! log-bucketed histograms across the serving stack. [`Scoped`] is a
//! name-prefixing view of a registry (the daemon's per-tenant
//! `tenant.<id>.*` counters are scoped handles), so multi-tenant series
//! appear in one `snapshot_json()` without separate registries.

pub mod histogram;
pub mod metrics;

pub use histogram::LogHistogram;
pub use metrics::{LatencyBreakdown, Registry, Scoped};
