//! Metrics and latency accounting.
//!
//! The paper decomposes split-computing latency into four factors
//! (§2.2): edge encode, wireless transfer, cloud decode, and GPU
//! integration + tail compute. [`LatencyBreakdown`] carries exactly that
//! decomposition per request; [`Registry`] aggregates counters and
//! log-bucketed histograms across the serving stack.

pub mod histogram;
pub mod metrics;

pub use histogram::LogHistogram;
pub use metrics::{LatencyBreakdown, Registry};
