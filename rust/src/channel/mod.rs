//! Wireless channel models for the edge→cloud link.
//!
//! The paper reports communication latency `T_comm` via the ε-outage
//! model of ref. [13] (Yun et al.), not a physical link; this module
//! implements that analytic model plus a stochastic packet-level
//! simulator (outage → retransmission) used by the transport layer for
//! failure-injection tests.

pub mod outage;

pub use outage::{ChannelParams, OutageChannel, TransmitOutcome};
