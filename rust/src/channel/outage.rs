//! ε-outage channel model.
//!
//! For a Rayleigh block-fading link with average SNR `γ`, channel-gain
//! variance `σ_h²`, and bandwidth `W`, the ε-outage rate is the largest
//! rate guaranteed with probability `1 − ε`:
//!
//! ```text
//! R_ε = W · log2(1 + γ · σ_h² · F⁻¹(ε)),   F⁻¹(ε) = −ln(1 − ε)
//! ```
//!
//! (`F` is the CDF of the exponential `|h|²`). Communication latency for
//! a `b`-bit payload is `T_comm = b / R_ε`. The paper's defaults
//! (§4.1): `ε = 0.001`, `W = 10 MHz`, `σ_h² = 1`, `γ = 10 dB`.
//!
//! Because `T_comm` is proportional to payload size for fixed channel
//! parameters, the paper's highlighted `T_comm` *ratios* (e.g. 2.6×–2.7×
//! at Q = 6) equal the corresponding compressed-size ratios; absolute
//! values depend only on the parameter set, which is configurable here.

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Channel parameterization (paper §4.1 defaults via `Default`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChannelParams {
    /// Outage probability ε.
    pub epsilon: f64,
    /// Bandwidth W in Hz.
    pub bandwidth_hz: f64,
    /// Average SNR γ in dB.
    pub gamma_db: f64,
    /// Channel gain variance σ_h².
    pub sigma_h2: f64,
}

impl Default for ChannelParams {
    fn default() -> Self {
        ChannelParams { epsilon: 0.001, bandwidth_hz: 10e6, gamma_db: 10.0, sigma_h2: 1.0 }
    }
}

impl ChannelParams {
    /// Validate parameter ranges.
    pub fn validated(self) -> Result<Self> {
        if !(0.0 < self.epsilon && self.epsilon < 1.0) {
            return Err(Error::invalid(format!("epsilon {} outside (0,1)", self.epsilon)));
        }
        if self.bandwidth_hz <= 0.0 || self.sigma_h2 <= 0.0 {
            return Err(Error::invalid("bandwidth and sigma_h2 must be positive"));
        }
        Ok(self)
    }

    /// Linear SNR.
    pub fn gamma_linear(&self) -> f64 {
        10f64.powf(self.gamma_db / 10.0)
    }
}

/// Outcome of a stochastic transmission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransmitOutcome {
    /// Total latency including retransmissions, seconds.
    pub latency_s: f64,
    /// Number of outage-triggered retransmissions.
    pub retries: u32,
}

/// The ε-outage channel.
#[derive(Debug, Clone)]
pub struct OutageChannel {
    params: ChannelParams,
}

impl OutageChannel {
    /// Construct with validated parameters.
    pub fn new(params: ChannelParams) -> Result<Self> {
        Ok(OutageChannel { params: params.validated()? })
    }

    /// Paper-default channel.
    pub fn paper_default() -> Self {
        OutageChannel { params: ChannelParams::default() }
    }

    /// The channel parameters in use.
    pub fn params(&self) -> &ChannelParams {
        &self.params
    }

    /// ε-outage rate `R_ε` in bits/second.
    pub fn rate_bps(&self) -> f64 {
        let p = &self.params;
        let f_inv = -(1.0 - p.epsilon).ln();
        p.bandwidth_hz * (1.0 + p.gamma_linear() * p.sigma_h2 * f_inv).log2()
    }

    /// Deterministic `T_comm` (seconds) for a payload of `bytes`.
    pub fn comm_latency_s(&self, bytes: usize) -> f64 {
        (bytes as f64 * 8.0) / self.rate_bps()
    }

    /// Deterministic `T_comm` in milliseconds.
    pub fn comm_latency_ms(&self, bytes: usize) -> f64 {
        self.comm_latency_s(bytes) * 1e3
    }

    /// Stochastic transmission: sample the Rayleigh gain per attempt; an
    /// attempt whose instantaneous capacity falls below `R_ε` is an
    /// outage and the packet is retransmitted (simple ARQ), up to
    /// `max_retries`.
    pub fn transmit(
        &self,
        bytes: usize,
        rng: &mut Rng,
        max_retries: u32,
    ) -> Result<TransmitOutcome> {
        let r_eps = self.rate_bps();
        let p = &self.params;
        let base = self.comm_latency_s(bytes);
        let mut latency = 0.0;
        for attempt in 0..=max_retries {
            // |h|² ~ Exp(mean σ_h²).
            let gain = rng.exponential(1.0 / p.sigma_h2);
            let capacity = p.bandwidth_hz * (1.0 + p.gamma_linear() * gain).log2();
            latency += base;
            if capacity >= r_eps {
                return Ok(TransmitOutcome { latency_s: latency, retries: attempt });
            }
        }
        Err(Error::transport(format!(
            "outage persisted across {max_retries} retransmissions"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_rate() {
        // ε=0.001, W=10MHz, γ=10dB, σ²=1 →
        // R = 1e7 · log2(1 + 10 · (−ln 0.999)) ≈ 1.43624e5 bps.
        let ch = OutageChannel::paper_default();
        let r = ch.rate_bps();
        assert!((r - 1.43624e5).abs() / 1.43624e5 < 1e-4, "rate {r}");
    }

    #[test]
    fn latency_proportional_to_size() {
        let ch = OutageChannel::paper_default();
        let t1 = ch.comm_latency_s(1000);
        let t4 = ch.comm_latency_s(4000);
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ratio_equals_size_ratio() {
        // The paper's T_comm speedup factors are exactly size ratios.
        let ch = OutageChannel::paper_default();
        let baseline = ch.comm_latency_ms(3_240_000);
        let ours = ch.comm_latency_ms(1_230_000);
        assert!(((baseline / ours) - (3_240_000.0 / 1_230_000.0)).abs() < 1e-9);
    }

    #[test]
    fn higher_snr_is_faster() {
        let slow = OutageChannel::paper_default();
        let fast = OutageChannel::new(ChannelParams { gamma_db: 20.0, ..Default::default() })
            .unwrap();
        assert!(fast.comm_latency_s(1000) < slow.comm_latency_s(1000));
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(OutageChannel::new(ChannelParams { epsilon: 0.0, ..Default::default() }).is_err());
        assert!(OutageChannel::new(ChannelParams { epsilon: 1.0, ..Default::default() }).is_err());
        assert!(
            OutageChannel::new(ChannelParams { bandwidth_hz: -1.0, ..Default::default() })
                .is_err()
        );
    }

    #[test]
    fn stochastic_outage_rate_close_to_epsilon() {
        // With ε = 0.05, about 5% of attempts should fail.
        let ch = OutageChannel::new(ChannelParams { epsilon: 0.05, ..Default::default() })
            .unwrap();
        let mut rng = Rng::new(1);
        let mut retries = 0u64;
        let trials = 20_000;
        for _ in 0..trials {
            let out = ch.transmit(100, &mut rng, 50).unwrap();
            retries += out.retries as u64;
        }
        let rate = retries as f64 / trials as f64;
        assert!((rate - 0.05).abs() < 0.01, "observed outage rate {rate}");
    }

    #[test]
    fn budget_exhaustion_is_retryable() {
        // Driving ε toward 1 makes every attempt an outage; exhausting
        // the ARQ budget must surface as a *retryable* transport error
        // so the session layer can back off and try again, rather than
        // treating a bad radio interval as fatal.
        let ch = OutageChannel::new(ChannelParams { epsilon: 0.999, ..Default::default() })
            .unwrap();
        let mut rng = Rng::new(7);
        let err = (0..32)
            .find_map(|_| ch.transmit(1000, &mut rng, 0).err())
            .expect("ε=0.999 must produce an outage within 32 single-attempt sends");
        assert!(err.is_retryable(), "{err}");
    }

    #[test]
    fn transmit_latency_includes_retries() {
        let ch = OutageChannel::new(ChannelParams { epsilon: 0.5, ..Default::default() })
            .unwrap();
        let mut rng = Rng::new(3);
        let base = ch.comm_latency_s(1000);
        for _ in 0..100 {
            let out = ch.transmit(1000, &mut rng, 100).unwrap();
            let expected = base * (out.retries as f64 + 1.0);
            assert!((out.latency_s - expected).abs() < 1e-12);
        }
    }
}
