//! Readers for the test-set binaries written by `python/compile/data.py`.
//!
//! * [`vision`] — "RSCD" image/label sets.
//! * [`lm_tasks`] — "RSCT" multiple-choice task files.
//!
//! Formats are little-endian and mirrored field-for-field with the
//! Python writers; every reader validates magic, version and size
//! arithmetic before trusting any count.

pub mod lm_tasks;
pub mod vision;

pub use lm_tasks::{McItem, McTask};
pub use vision::VisionSet;
