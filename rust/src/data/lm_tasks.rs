//! Multiple-choice task reader ("RSCT").
//!
//! Layout: magic, u32 version, u32 n_items, u32 n_choices, u32 seq_len,
//! u32 vocab; per item: u32 correct, then per choice: u32 score_start,
//! u32 score_len, seq_len×u32 tokens.

use std::path::Path;

use crate::error::{Error, Result};

/// One choice of one item.
#[derive(Debug, Clone)]
pub struct McChoice {
    /// Token sequence, `seq_len` long.
    pub tokens: Vec<u32>,
    /// First scored position (answer span start).
    pub score_start: usize,
    /// Scored span length.
    pub score_len: usize,
}

/// One multiple-choice item.
#[derive(Debug, Clone)]
pub struct McItem {
    /// Index of the correct choice.
    pub correct: usize,
    /// The choices.
    pub choices: Vec<McChoice>,
}

/// A loaded task file.
#[derive(Debug, Clone)]
pub struct McTask {
    /// Choices per item.
    pub n_choices: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Items.
    pub items: Vec<McItem>,
}

fn read_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    let end = *pos + 4;
    if end > buf.len() {
        return Err(Error::corrupt("task bin truncated"));
    }
    let v = u32::from_le_bytes([buf[*pos], buf[*pos + 1], buf[*pos + 2], buf[*pos + 3]]);
    *pos = end;
    Ok(v)
}

impl McTask {
    /// Parse from bytes.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < 4 || &buf[0..4] != b"RSCT" {
            return Err(Error::corrupt("bad task magic"));
        }
        let mut pos = 4usize;
        let version = read_u32(buf, &mut pos)?;
        if version != 1 {
            return Err(Error::corrupt(format!("task bin version {version}")));
        }
        let n_items = read_u32(buf, &mut pos)? as usize;
        let n_choices = read_u32(buf, &mut pos)? as usize;
        let seq_len = read_u32(buf, &mut pos)? as usize;
        let vocab = read_u32(buf, &mut pos)? as usize;
        if n_choices == 0 || seq_len == 0 || vocab == 0 {
            return Err(Error::corrupt("degenerate task header"));
        }
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            let correct = read_u32(buf, &mut pos)? as usize;
            if correct >= n_choices {
                return Err(Error::corrupt("correct index out of range"));
            }
            let mut choices = Vec::with_capacity(n_choices);
            for _ in 0..n_choices {
                let score_start = read_u32(buf, &mut pos)? as usize;
                let score_len = read_u32(buf, &mut pos)? as usize;
                if score_start == 0 || score_start + score_len > seq_len {
                    return Err(Error::corrupt("score span out of range"));
                }
                let mut tokens = Vec::with_capacity(seq_len);
                for _ in 0..seq_len {
                    let t = read_u32(buf, &mut pos)?;
                    if t as usize >= vocab {
                        return Err(Error::corrupt("token out of vocab"));
                    }
                    tokens.push(t);
                }
                choices.push(McChoice { tokens, score_start, score_len });
            }
            items.push(McItem { correct, choices });
        }
        if pos != buf.len() {
            return Err(Error::corrupt("trailing bytes in task bin"));
        }
        Ok(McTask { n_choices, seq_len, vocab, items })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let buf = std::fs::read(path.as_ref()).map_err(|e| {
            Error::artifact(format!("cannot read {}: {e}", path.as_ref().display()))
        })?;
        Self::from_bytes(&buf)
    }

    /// Flatten one item's choices into a single i32 token batch
    /// (n_choices × seq_len), the LM head artifact's input layout.
    pub fn item_batch(&self, item: &McItem) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.n_choices * self.seq_len);
        for ch in &item.choices {
            out.extend(ch.tokens.iter().map(|&t| t as i32));
        }
        out
    }
}

/// Score choices from tail logits (n_choices × seq_len × vocab,
/// row-major): sum of log-softmax of each answer token at its
/// predicting position (t−1). Returns the argmax choice.
pub fn score_choices(logits: &[f32], task: &McTask, item: &McItem) -> usize {
    let v = task.vocab;
    let t = task.seq_len;
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, ch) in item.choices.iter().enumerate() {
        let base = ci * t * v;
        let mut score = 0.0f64;
        for pos in ch.score_start..ch.score_start + ch.score_len {
            let row = &logits[base + (pos - 1) * v..base + pos * v];
            // log-softmax of the target token.
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln()
                + mx as f64;
            score += row[ch.tokens[pos] as usize] as f64 - lse;
        }
        if score > best.0 {
            best = (score, ci);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"RSCT");
        for v in [1u32, 1, 2, 4, 16] {
            buf.extend_from_slice(&v.to_le_bytes()); // version, items, choices, seq, vocab
        }
        buf.extend_from_slice(&1u32.to_le_bytes()); // correct = 1
        for c in 0..2u32 {
            buf.extend_from_slice(&2u32.to_le_bytes()); // score_start
            buf.extend_from_slice(&2u32.to_le_bytes()); // score_len
            for i in 0..4u32 {
                buf.extend_from_slice(&(c + i).to_le_bytes());
            }
        }
        buf
    }

    #[test]
    fn parses_sample() {
        let task = McTask::from_bytes(&sample_bytes()).unwrap();
        assert_eq!(task.items.len(), 1);
        assert_eq!(task.items[0].correct, 1);
        assert_eq!(task.items[0].choices[1].tokens, vec![1, 2, 3, 4]);
        let batch = task.item_batch(&task.items[0]);
        assert_eq!(batch.len(), 8);
    }

    #[test]
    fn rejects_corruption() {
        let good = sample_bytes();
        assert!(McTask::from_bytes(&good[..10]).is_err());
        let mut bad = good.clone();
        bad[24] = 9; // correct index (offset 24) → out of range
        assert!(McTask::from_bytes(&bad).is_err());
        let mut bad = good.clone();
        bad.push(0);
        assert!(McTask::from_bytes(&bad).is_err());
    }

    #[test]
    fn scoring_picks_highest_logprob() {
        let task = McTask::from_bytes(&sample_bytes()).unwrap();
        let item = &task.items[0];
        let v = task.vocab;
        let t = task.seq_len;
        // Choice 1's answer tokens are 3 at pos 2 and 4 at pos 3
        // (scored at rows 1 and 2). Give them high logits.
        let mut logits = vec![0.0f32; 2 * t * v];
        let base = 1 * t * v;
        logits[base + 1 * v + 3] = 10.0;
        logits[base + 2 * v + 4] = 10.0;
        assert_eq!(score_choices(&logits, &task, item), 1);
    }
}
