//! Vision test-set reader ("RSCD").
//!
//! Layout: magic, u32 version, u32 count, u32 h, u32 w, u32 c,
//! u32 num_classes, count×u32 labels, count·h·w·c f32 images (NHWC).

use std::path::Path;

use crate::error::{Error, Result};

/// An in-memory vision evaluation set.
#[derive(Debug, Clone)]
pub struct VisionSet {
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Number of classes.
    pub num_classes: usize,
    /// Labels, one per image.
    pub labels: Vec<u32>,
    /// Images, flattened NHWC.
    pub images: Vec<f32>,
}

fn read_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    let end = *pos + 4;
    if end > buf.len() {
        return Err(Error::corrupt("vision bin truncated"));
    }
    let v = u32::from_le_bytes([buf[*pos], buf[*pos + 1], buf[*pos + 2], buf[*pos + 3]]);
    *pos = end;
    Ok(v)
}

impl VisionSet {
    /// Samples in the set.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Flat pixels per image.
    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow image `i` as a flat slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.image_len();
        &self.images[i * n..(i + 1) * n]
    }

    /// Concatenate images `[start, start+count)` (for batched execution);
    /// wraps around the set so any batch size can be filled.
    pub fn batch(&self, start: usize, count: usize) -> (Vec<f32>, Vec<u32>) {
        let n = self.image_len();
        let mut xs = Vec::with_capacity(count * n);
        let mut ys = Vec::with_capacity(count);
        for k in 0..count {
            let i = (start + k) % self.len();
            xs.extend_from_slice(self.image(i));
            ys.push(self.labels[i]);
        }
        (xs, ys)
    }

    /// Parse from bytes.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < 4 || &buf[0..4] != b"RSCD" {
            return Err(Error::corrupt("bad vision magic"));
        }
        let mut pos = 4usize;
        let version = read_u32(buf, &mut pos)?;
        if version != 1 {
            return Err(Error::corrupt(format!("vision bin version {version}")));
        }
        let count = read_u32(buf, &mut pos)? as usize;
        let h = read_u32(buf, &mut pos)? as usize;
        let w = read_u32(buf, &mut pos)? as usize;
        let c = read_u32(buf, &mut pos)? as usize;
        let num_classes = read_u32(buf, &mut pos)? as usize;
        let img_len = h
            .checked_mul(w)
            .and_then(|x| x.checked_mul(c))
            .ok_or_else(|| Error::corrupt("image dims overflow"))?;
        let expect = pos + count * 4 + count * img_len * 4;
        if buf.len() != expect {
            return Err(Error::corrupt(format!(
                "vision bin is {} bytes, expected {expect}",
                buf.len()
            )));
        }
        let mut labels = Vec::with_capacity(count);
        for _ in 0..count {
            let l = read_u32(buf, &mut pos)?;
            if l as usize >= num_classes {
                return Err(Error::corrupt("label out of range"));
            }
            labels.push(l);
        }
        let mut images = Vec::with_capacity(count * img_len);
        for chunk in buf[pos..].chunks_exact(4) {
            images.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(VisionSet { h, w, c, num_classes, labels, images })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let buf = std::fs::read(path.as_ref()).map_err(|e| {
            Error::artifact(format!("cannot read {}: {e}", path.as_ref().display()))
        })?;
        Self::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes(count: u32, h: u32, w: u32, c: u32, classes: u32) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"RSCD");
        for v in [1u32, count, h, w, c, classes] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for i in 0..count {
            buf.extend_from_slice(&(i % classes).to_le_bytes());
        }
        for i in 0..count * h * w * c {
            buf.extend_from_slice(&(i as f32).to_le_bytes());
        }
        buf
    }

    #[test]
    fn parses_and_indexes() {
        let set = VisionSet::from_bytes(&sample_bytes(3, 2, 2, 1, 2)).unwrap();
        assert_eq!(set.len(), 3);
        assert_eq!(set.image_len(), 4);
        assert_eq!(set.image(1), &[4.0, 5.0, 6.0, 7.0]);
        let (xs, ys) = set.batch(2, 2); // wraps to image 0
        assert_eq!(ys, vec![0, 0]);
        assert_eq!(&xs[0..4], set.image(2));
        assert_eq!(&xs[4..8], set.image(0));
    }

    #[test]
    fn rejects_bad_magic_and_sizes() {
        assert!(VisionSet::from_bytes(b"XXXX").is_err());
        let mut b = sample_bytes(2, 2, 2, 1, 2);
        b.pop();
        assert!(VisionSet::from_bytes(&b).is_err());
        let mut b = sample_bytes(2, 2, 2, 1, 2);
        b[4] = 9; // version
        assert!(VisionSet::from_bytes(&b).is_err());
    }

    #[test]
    fn rejects_out_of_range_label() {
        let mut b = sample_bytes(2, 1, 1, 1, 2);
        // First label at offset 28.
        b[28] = 7;
        assert!(VisionSet::from_bytes(&b).is_err());
    }
}
