//! Multi-lane interleaved rANS.
//!
//! The paper reports sub-millisecond encode/decode by running rANS on the
//! GPU; the parallel decomposition used there (and in DietGPU) is a set
//! of *independent coder states*, each owning a slice of the symbol
//! stream, whose outputs are concatenated with per-lane offsets. This
//! module is the CPU analogue: `lanes` scalar coders over contiguous
//! chunks, fanned out across threads. All lanes share one frequency
//! table, exactly like the paper's single summed table for `D = v⊕c⊕r` —
//! and therefore also share its lazily-built division-free coding
//! tables ([`FreqTable::enc_table`]/[`FreqTable::dec_table`]): the
//! first lane to touch the table builds them, every other lane reuses
//! them for free.
//!
//! Two stream layouts share this framing (after the container header,
//! which stores the table):
//!
//! ```text
//! v1 (scalar lanes — the compatibility default, byte-identical to the
//!     pre-v2 format):
//! [varint lane_count]                       // ≥ 1 by construction
//! [varint symbol_count]
//! [varint byte_len × lane_count]            // per-lane payload sizes
//! [lane 0 payload] [lane 1 payload] ...     // scalar rANS streams
//!
//! v2 (multi-state lanes — gated behind the layout marker):
//! [varint 0]                                // layout marker; a v1
//!                                           // stream can never start
//!                                           // with 0 (lane_count ≥ 1)
//! [varint states_per_lane]                  // N ∈ {1, 2, 4, 8}
//! [varint lane_count] [varint symbol_count]
//! [varint byte_len × lane_count]
//! [lane 0 payload] ...                      // N-state rANS streams
//!                                           // (see super::multistate)
//! ```
//!
//! The two axes of parallelism compose: `lane_count` is the
//! thread-level split (contiguous chunks, one coder per chunk) and
//! `states_per_lane` is the instruction-level split *within* each lane
//! (round-robin interleaved states, no extra metadata). Per-lane
//! decode additionally dispatches through the cross-ISA backend seam
//! ([`super::simd`]): 4- and 8-state lanes run the vectorized gather
//! rounds (SSE4.1/AVX2 on x86_64, NEON on aarch64) with no change to
//! the bytes on the wire.

use crate::error::{Error, Result};
use crate::util::varint;

use super::freq::FreqTable;
use super::multistate::{decode_multistate, encode_multistate, supported_states};

/// Maximum supported lanes (sanity bound for header validation).
pub const MAX_LANES: usize = 1024;

/// Which per-lane stream layout an encoder emits. The decoder never
/// needs this: both layouts are self-describing (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StreamLayout {
    /// v1 scalar lanes — one rANS state per lane. The compatibility
    /// default; output is byte-identical to the pre-v2 wire format.
    #[default]
    V1,
    /// v2 lanes with this many interleaved rANS states per lane
    /// (ILP/SIMD decode; supported counts: 1, 2, 4, 8).
    MultiState(usize),
}

impl StreamLayout {
    /// Interleaved rANS states per lane under this layout.
    pub fn states_per_lane(&self) -> usize {
        match self {
            StreamLayout::V1 => 1,
            StreamLayout::MultiState(n) => *n,
        }
    }
}

/// A parsed interleaved stream header (borrowed payloads).
#[derive(Debug)]
pub struct InterleavedStream<'a> {
    /// Total symbol count across lanes.
    pub symbol_count: usize,
    /// Interleaved rANS states per lane (1 for v1 streams).
    pub states_per_lane: usize,
    /// Per-lane (symbol_count, payload) pairs.
    pub lanes: Vec<(usize, &'a [u8])>,
}

/// Split `count` symbols into `lanes` near-equal contiguous chunks.
/// Every lane gets `count / lanes` symbols and the first `count % lanes`
/// lanes get one extra — identical partitioning on encode and decode.
pub fn lane_spans(count: usize, lanes: usize) -> Vec<std::ops::Range<usize>> {
    let lanes = lanes.max(1);
    let base = count / lanes;
    let extra = count % lanes;
    let mut spans = Vec::with_capacity(lanes);
    let mut start = 0;
    for i in 0..lanes {
        let len = base + usize::from(i < extra);
        spans.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, count);
    spans
}

/// Assemble per-lane payloads into the v1 interleaved wire layout.
///
/// This is the single definition of the v1 stream framing: the
/// scoped-thread encoder below and the pooled encoder in
/// [`crate::engine`] both feed their lane payloads through here, so the
/// two paths are byte-identical by construction.
pub fn assemble_stream(lanes: usize, symbol_count: usize, payloads: &[Vec<u8>]) -> Vec<u8> {
    debug_assert_eq!(lanes, payloads.len());
    let total: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total + 4 * lanes + 16);
    varint::write_usize(&mut out, lanes);
    varint::write_usize(&mut out, symbol_count);
    for p in payloads {
        varint::write_usize(&mut out, p.len());
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
    out
}

/// Assemble per-lane payloads under `layout`: the v1 framing above, or
/// the v2 framing (marker + state count) with multi-state lane payloads.
/// Like [`assemble_stream`], this is the single definition both the
/// scoped-thread and pooled encoders share.
pub fn assemble_stream_with_layout(
    layout: StreamLayout,
    lanes: usize,
    symbol_count: usize,
    payloads: &[Vec<u8>],
) -> Vec<u8> {
    let states = match layout {
        StreamLayout::V1 => return assemble_stream(lanes, symbol_count, payloads),
        StreamLayout::MultiState(n) => n,
    };
    debug_assert_eq!(lanes, payloads.len());
    debug_assert!(supported_states(states));
    let total: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total + 4 * lanes + 18);
    varint::write_usize(&mut out, 0); // v2 layout marker
    varint::write_usize(&mut out, states);
    varint::write_usize(&mut out, lanes);
    varint::write_usize(&mut out, symbol_count);
    for p in payloads {
        varint::write_usize(&mut out, p.len());
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
    out
}

/// Encode `symbols` with `lanes` independent rANS states.
///
/// `parallel` controls whether lanes run on scoped threads (the
/// per-call fan-out baseline; the serving path uses the pooled
/// [`crate::engine`] instead) or sequentially; both produce
/// byte-identical output.
pub fn encode_interleaved(
    symbols: &[u32],
    table: &FreqTable,
    lanes: usize,
    parallel: bool,
) -> Result<Vec<u8>> {
    encode_interleaved_with_layout(symbols, table, lanes, StreamLayout::V1, parallel)
}

/// Encode `symbols` with `lanes` coders under `layout`: scalar lanes
/// (v1, the default elsewhere) or `N`-state interleaved lanes (v2).
pub fn encode_interleaved_with_layout(
    symbols: &[u32],
    table: &FreqTable,
    lanes: usize,
    layout: StreamLayout,
    parallel: bool,
) -> Result<Vec<u8>> {
    let states = layout.states_per_lane();
    if !supported_states(states) {
        return Err(Error::invalid(format!(
            "unsupported states-per-lane {states} (supported: 1, 2, 4, 8)"
        )));
    }
    let lanes = lanes.clamp(1, MAX_LANES);
    let spans = lane_spans(symbols.len(), lanes);

    let payloads: Vec<Result<Vec<u8>>> = if parallel && lanes > 1 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = spans
                .iter()
                .map(|span| {
                    let chunk = &symbols[span.clone()];
                    scope.spawn(move || encode_multistate(chunk, table, states))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("lane panicked")).collect()
        })
    } else {
        spans
            .iter()
            .map(|span| encode_multistate(&symbols[span.clone()], table, states))
            .collect()
    };

    let payloads: Vec<Vec<u8>> = payloads.into_iter().collect::<Result<_>>()?;
    Ok(assemble_stream_with_layout(layout, lanes, symbols.len(), &payloads))
}

/// A parsed stream header with offset-based lane spans (no payload
/// borrows), for callers that need `'static` lane tasks — the pooled
/// engine slices an `Arc`'d buffer instead of borrowing.
#[derive(Debug)]
pub struct StreamSpans {
    /// Total symbol count across lanes.
    pub symbol_count: usize,
    /// Interleaved rANS states per lane (1 for v1 streams).
    pub states_per_lane: usize,
    /// Per-lane (symbol_count, byte range) pairs.
    pub lanes: Vec<(usize, std::ops::Range<usize>)>,
}

/// Parse an interleaved header (either layout — v2 streams are
/// recognized by the leading zero marker), returning per-lane symbol
/// counts and byte *ranges* into `bytes`.
pub fn parse_stream_spans(bytes: &[u8]) -> Result<StreamSpans> {
    let mut pos = 0usize;
    let first = varint::read_usize(bytes, &mut pos)?;
    let (states_per_lane, lanes) = if first == 0 {
        // v2 layout marker (a v1 stream always starts with lane_count ≥ 1).
        let states = varint::read_usize(bytes, &mut pos)?;
        if !supported_states(states) {
            return Err(Error::corrupt(format!(
                "bad states-per-lane {states} (supported: 1, 2, 4, 8)"
            )));
        }
        (states, varint::read_usize(bytes, &mut pos)?)
    } else {
        (1, first)
    };
    if lanes == 0 || lanes > MAX_LANES {
        return Err(Error::corrupt(format!("bad lane count {lanes}")));
    }
    let symbol_count = varint::read_usize(bytes, &mut pos)?;
    let mut lens = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        lens.push(varint::read_usize(bytes, &mut pos)?);
    }
    let spans = lane_spans(symbol_count, lanes);
    let mut out = Vec::with_capacity(lanes);
    for (i, len) in lens.into_iter().enumerate() {
        let end = pos
            .checked_add(len)
            .ok_or_else(|| Error::corrupt("lane length overflow"))?;
        if end > bytes.len() {
            return Err(Error::corrupt("lane payload truncated"));
        }
        out.push((spans[i].len(), pos..end));
        pos = end;
    }
    if pos != bytes.len() {
        return Err(Error::corrupt("trailing bytes after last lane"));
    }
    Ok(StreamSpans { symbol_count, states_per_lane, lanes: out })
}

/// Parse the interleaved header, borrowing lane payloads from `bytes`.
pub fn parse_stream(bytes: &[u8]) -> Result<InterleavedStream<'_>> {
    let parsed = parse_stream_spans(bytes)?;
    let lanes = parsed
        .lanes
        .into_iter()
        .map(|(count, range)| (count, &bytes[range]))
        .collect();
    Ok(InterleavedStream {
        symbol_count: parsed.symbol_count,
        states_per_lane: parsed.states_per_lane,
        lanes,
    })
}

/// Decode an interleaved stream produced by [`encode_interleaved`] or
/// [`encode_interleaved_with_layout`] — both layouts are
/// self-describing, so no layout argument is needed.
pub fn decode_interleaved(bytes: &[u8], table: &FreqTable, parallel: bool) -> Result<Vec<u32>> {
    let stream = parse_stream(bytes)?;
    let states = stream.states_per_lane;
    let decoded: Vec<Result<Vec<u32>>> = if parallel && stream.lanes.len() > 1 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = stream
                .lanes
                .iter()
                .map(|&(count, payload)| {
                    scope.spawn(move || decode_multistate(payload, count, table, states))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("lane panicked")).collect()
        })
    } else {
        stream
            .lanes
            .iter()
            .map(|&(count, payload)| decode_multistate(payload, count, table, states))
            .collect()
    };

    let mut out = Vec::with_capacity(stream.symbol_count);
    for d in decoded {
        out.extend(d?);
    }
    debug_assert_eq!(out.len(), stream.symbol_count);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample(seed: u64, len: usize, alphabet: usize) -> (Vec<u32>, FreqTable) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, 1.2) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, alphabet);
        (symbols, table)
    }

    #[test]
    fn lane_spans_partition() {
        for (count, lanes) in [(10, 3), (0, 4), (7, 7), (5, 8), (100, 1)] {
            let spans = lane_spans(count, lanes);
            assert_eq!(spans.len(), lanes.max(1));
            let total: usize = spans.iter().map(|s| s.len()).sum();
            assert_eq!(total, count);
            for w in spans.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn roundtrip_various_lane_counts() {
        let (symbols, table) = sample(1, 10_000, 64);
        for lanes in [1, 2, 3, 8, 16] {
            for parallel in [false, true] {
                let bytes = encode_interleaved(&symbols, &table, lanes, parallel).unwrap();
                let back = decode_interleaved(&bytes, &table, parallel).unwrap();
                assert_eq!(back, symbols, "lanes={lanes} parallel={parallel}");
            }
        }
    }

    #[test]
    fn parallel_and_serial_are_byte_identical() {
        let (symbols, table) = sample(2, 50_000, 128);
        let a = encode_interleaved(&symbols, &table, 8, false).unwrap();
        let b = encode_interleaved(&symbols, &table, 8, true).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn more_lanes_than_symbols() {
        let (symbols, table) = sample(3, 5, 8);
        let bytes = encode_interleaved(&symbols, &table, 16, true).unwrap();
        assert_eq!(decode_interleaved(&bytes, &table, true).unwrap(), symbols);
    }

    #[test]
    fn empty_stream() {
        let table = FreqTable::from_symbols(&[], 4);
        let bytes = encode_interleaved(&[], &table, 4, true).unwrap();
        assert_eq!(decode_interleaved(&bytes, &table, true).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn interleaving_overhead_is_small() {
        // Header + per-lane state words only: ~6 bytes per extra lane.
        let (symbols, table) = sample(4, 100_000, 32);
        let one = encode_interleaved(&symbols, &table, 1, false).unwrap().len();
        let eight = encode_interleaved(&symbols, &table, 8, false).unwrap().len();
        assert!(eight < one + 8 * 16, "1 lane {one}B vs 8 lanes {eight}B");
    }

    #[test]
    fn corrupt_headers_rejected() {
        let (symbols, table) = sample(5, 100, 8);
        let bytes = encode_interleaved(&symbols, &table, 2, false).unwrap();
        assert!(parse_stream(&bytes[..1]).is_err());
        let mut garbled = bytes.clone();
        garbled[0] = 0xFF; // lane count varint → huge
        assert!(decode_interleaved(&garbled, &table, false).is_err());
        let truncated = &bytes[..bytes.len() - 1];
        assert!(decode_interleaved(truncated, &table, false).is_err());
    }

    #[test]
    fn v2_roundtrip_states_by_lanes() {
        let (symbols, table) = sample(6, 10_000, 64);
        for states in [1usize, 2, 4, 8] {
            for lanes in [1usize, 2, 3, 8] {
                for parallel in [false, true] {
                    let bytes = encode_interleaved_with_layout(
                        &symbols,
                        &table,
                        lanes,
                        StreamLayout::MultiState(states),
                        parallel,
                    )
                    .unwrap();
                    let back = decode_interleaved(&bytes, &table, parallel).unwrap();
                    assert_eq!(back, symbols, "states={states} lanes={lanes}");
                }
            }
        }
    }

    #[test]
    fn v2_layout_is_flagged_and_v1_unchanged() {
        let (symbols, table) = sample(7, 5000, 32);
        // V1 layout through the layout-aware path is byte-identical to
        // the legacy entry point.
        let legacy = encode_interleaved(&symbols, &table, 4, false).unwrap();
        let v1 = encode_interleaved_with_layout(
            &symbols, &table, 4, StreamLayout::V1, false,
        )
        .unwrap();
        assert_eq!(legacy, v1);
        // A multi-state stream leads with the zero marker + state count.
        let v2 = encode_interleaved_with_layout(
            &symbols,
            &table,
            4,
            StreamLayout::MultiState(2),
            false,
        )
        .unwrap();
        assert_eq!(&v2[0..2], &[0u8, 2]);
        let parsed = parse_stream(&v2).unwrap();
        assert_eq!(parsed.states_per_lane, 2);
        assert_eq!(parse_stream(&v1).unwrap().states_per_lane, 1);
    }

    #[test]
    fn v2_empty_and_single_symbol_streams() {
        let table = FreqTable::from_symbols(&[], 4);
        for states in [2usize, 4, 8] {
            let bytes = encode_interleaved_with_layout(
                &[],
                &table,
                4,
                StreamLayout::MultiState(states),
                false,
            )
            .unwrap();
            assert_eq!(decode_interleaved(&bytes, &table, false).unwrap(), Vec::<u32>::new());
        }
        let (symbols, table) = sample(8, 1, 8);
        for states in [2usize, 4, 8] {
            let bytes = encode_interleaved_with_layout(
                &symbols,
                &table,
                4,
                StreamLayout::MultiState(states),
                false,
            )
            .unwrap();
            assert_eq!(decode_interleaved(&bytes, &table, false).unwrap(), symbols);
        }
    }

    #[test]
    fn v2_corrupt_headers_rejected() {
        let (symbols, table) = sample(9, 400, 16);
        let bytes = encode_interleaved_with_layout(
            &symbols,
            &table,
            2,
            StreamLayout::MultiState(4),
            false,
        )
        .unwrap();
        // Pristine stream decodes.
        assert_eq!(decode_interleaved(&bytes, &table, false).unwrap(), symbols);

        // State count 0: [marker 0][states 0] — rejected at parse.
        let mut zero_states = bytes.clone();
        zero_states[1] = 0;
        assert!(decode_interleaved(&zero_states, &table, false).is_err());

        // State count above MAX_STATES (and an unsupported in-range 3).
        for bad in [3u8, crate::rans::multistate::MAX_STATES as u8 + 1, 0x7F] {
            let mut garbled = bytes.clone();
            garbled[1] = bad;
            assert!(decode_interleaved(&garbled, &table, false).is_err(), "states={bad}");
        }

        // Truncated per-state payload: cutting into the final lane's
        // state-word block must fail (header says more bytes than exist).
        let truncated = &bytes[..bytes.len() - 3];
        assert!(decode_interleaved(truncated, &table, false).is_err());

        // A lane payload shorter than its state-word block: the
        // per-lane decoder must reject it even when the framing parses.
        let stream = parse_stream(&bytes).unwrap();
        let &(count, payload) = stream.lanes.last().unwrap();
        assert!(payload.len() >= 16, "4-state lane carries 16 state bytes");
        assert!(
            crate::rans::multistate::decode_multistate(&payload[..15], count, &table, 4)
                .is_err()
        );
    }

    #[test]
    fn v2_unsupported_encode_states_rejected() {
        let (symbols, table) = sample(10, 100, 8);
        for states in [0usize, 3, 5, 6, 7, 9, 64] {
            assert!(encode_interleaved_with_layout(
                &symbols,
                &table,
                2,
                StreamLayout::MultiState(states),
                false,
            )
            .is_err());
        }
    }
}
