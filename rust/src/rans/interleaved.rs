//! Multi-lane interleaved rANS.
//!
//! The paper reports sub-millisecond encode/decode by running rANS on the
//! GPU; the parallel decomposition used there (and in DietGPU) is a set
//! of *independent coder states*, each owning a slice of the symbol
//! stream, whose outputs are concatenated with per-lane offsets. This
//! module is the CPU analogue: `lanes` scalar coders over contiguous
//! chunks, fanned out across threads. All lanes share one frequency
//! table, exactly like the paper's single summed table for `D = v⊕c⊕r` —
//! and therefore also share its lazily-built division-free coding
//! tables ([`FreqTable::enc_table`]/[`FreqTable::dec_table`]): the
//! first lane to touch the table builds them, every other lane reuses
//! them for free.
//!
//! Stream layout (after the container header, which stores the table):
//!
//! ```text
//! [varint lane_count] [varint symbol_count]
//! [varint byte_len × lane_count]            // per-lane payload sizes
//! [lane 0 payload] [lane 1 payload] ...
//! ```

use crate::error::{Error, Result};
use crate::util::varint;

use super::decode::decode;
use super::encode::encode;
use super::freq::FreqTable;

/// Maximum supported lanes (sanity bound for header validation).
pub const MAX_LANES: usize = 1024;

/// A parsed interleaved stream header (borrowed payloads).
#[derive(Debug)]
pub struct InterleavedStream<'a> {
    /// Total symbol count across lanes.
    pub symbol_count: usize,
    /// Per-lane (symbol_count, payload) pairs.
    pub lanes: Vec<(usize, &'a [u8])>,
}

/// Split `count` symbols into `lanes` near-equal contiguous chunks.
/// Every lane gets `count / lanes` symbols and the first `count % lanes`
/// lanes get one extra — identical partitioning on encode and decode.
pub fn lane_spans(count: usize, lanes: usize) -> Vec<std::ops::Range<usize>> {
    let lanes = lanes.max(1);
    let base = count / lanes;
    let extra = count % lanes;
    let mut spans = Vec::with_capacity(lanes);
    let mut start = 0;
    for i in 0..lanes {
        let len = base + usize::from(i < extra);
        spans.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, count);
    spans
}

/// Assemble per-lane payloads into the interleaved wire layout.
///
/// This is the single definition of the stream framing: the scoped-thread
/// encoder below and the pooled encoder in [`crate::engine`] both feed
/// their lane payloads through here, so the two paths are byte-identical
/// by construction.
pub fn assemble_stream(lanes: usize, symbol_count: usize, payloads: &[Vec<u8>]) -> Vec<u8> {
    debug_assert_eq!(lanes, payloads.len());
    let total: usize = payloads.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total + 4 * lanes + 16);
    varint::write_usize(&mut out, lanes);
    varint::write_usize(&mut out, symbol_count);
    for p in payloads {
        varint::write_usize(&mut out, p.len());
    }
    for p in payloads {
        out.extend_from_slice(p);
    }
    out
}

/// Encode `symbols` with `lanes` independent rANS states.
///
/// `parallel` controls whether lanes run on scoped threads (the
/// per-call fan-out baseline; the serving path uses the pooled
/// [`crate::engine`] instead) or sequentially; both produce
/// byte-identical output.
pub fn encode_interleaved(
    symbols: &[u32],
    table: &FreqTable,
    lanes: usize,
    parallel: bool,
) -> Result<Vec<u8>> {
    let lanes = lanes.clamp(1, MAX_LANES);
    let spans = lane_spans(symbols.len(), lanes);

    let payloads: Vec<Result<Vec<u8>>> = if parallel && lanes > 1 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = spans
                .iter()
                .map(|span| {
                    let chunk = &symbols[span.clone()];
                    scope.spawn(move || encode(chunk, table))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("lane panicked")).collect()
        })
    } else {
        spans.iter().map(|span| encode(&symbols[span.clone()], table)).collect()
    };

    let payloads: Vec<Vec<u8>> = payloads.into_iter().collect::<Result<_>>()?;
    Ok(assemble_stream(lanes, symbols.len(), &payloads))
}

/// Parse the interleaved header, returning per-lane symbol counts and
/// byte *ranges* into `bytes` (offset-based so callers that need
/// `'static` lane tasks — the pooled engine — can slice an `Arc`'d
/// buffer instead of borrowing).
pub fn parse_stream_spans(
    bytes: &[u8],
) -> Result<(usize, Vec<(usize, std::ops::Range<usize>)>)> {
    let mut pos = 0usize;
    let lanes = varint::read_usize(bytes, &mut pos)?;
    if lanes == 0 || lanes > MAX_LANES {
        return Err(Error::corrupt(format!("bad lane count {lanes}")));
    }
    let symbol_count = varint::read_usize(bytes, &mut pos)?;
    let mut lens = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        lens.push(varint::read_usize(bytes, &mut pos)?);
    }
    let spans = lane_spans(symbol_count, lanes);
    let mut out = Vec::with_capacity(lanes);
    for (i, len) in lens.into_iter().enumerate() {
        let end = pos
            .checked_add(len)
            .ok_or_else(|| Error::corrupt("lane length overflow"))?;
        if end > bytes.len() {
            return Err(Error::corrupt("lane payload truncated"));
        }
        out.push((spans[i].len(), pos..end));
        pos = end;
    }
    if pos != bytes.len() {
        return Err(Error::corrupt("trailing bytes after last lane"));
    }
    Ok((symbol_count, out))
}

/// Parse the interleaved header, borrowing lane payloads from `bytes`.
pub fn parse_stream(bytes: &[u8]) -> Result<InterleavedStream<'_>> {
    let (symbol_count, spans) = parse_stream_spans(bytes)?;
    let lanes = spans
        .into_iter()
        .map(|(count, range)| (count, &bytes[range]))
        .collect();
    Ok(InterleavedStream { symbol_count, lanes })
}

/// Decode an interleaved stream produced by [`encode_interleaved`].
pub fn decode_interleaved(bytes: &[u8], table: &FreqTable, parallel: bool) -> Result<Vec<u32>> {
    let stream = parse_stream(bytes)?;
    let decoded: Vec<Result<Vec<u32>>> = if parallel && stream.lanes.len() > 1 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = stream
                .lanes
                .iter()
                .map(|&(count, payload)| scope.spawn(move || decode(payload, count, table)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("lane panicked")).collect()
        })
    } else {
        stream
            .lanes
            .iter()
            .map(|&(count, payload)| decode(payload, count, table))
            .collect()
    };

    let mut out = Vec::with_capacity(stream.symbol_count);
    for d in decoded {
        out.extend(d?);
    }
    debug_assert_eq!(out.len(), stream.symbol_count);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample(seed: u64, len: usize, alphabet: usize) -> (Vec<u32>, FreqTable) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, 1.2) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, alphabet);
        (symbols, table)
    }

    #[test]
    fn lane_spans_partition() {
        for (count, lanes) in [(10, 3), (0, 4), (7, 7), (5, 8), (100, 1)] {
            let spans = lane_spans(count, lanes);
            assert_eq!(spans.len(), lanes.max(1));
            let total: usize = spans.iter().map(|s| s.len()).sum();
            assert_eq!(total, count);
            for w in spans.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn roundtrip_various_lane_counts() {
        let (symbols, table) = sample(1, 10_000, 64);
        for lanes in [1, 2, 3, 8, 16] {
            for parallel in [false, true] {
                let bytes = encode_interleaved(&symbols, &table, lanes, parallel).unwrap();
                let back = decode_interleaved(&bytes, &table, parallel).unwrap();
                assert_eq!(back, symbols, "lanes={lanes} parallel={parallel}");
            }
        }
    }

    #[test]
    fn parallel_and_serial_are_byte_identical() {
        let (symbols, table) = sample(2, 50_000, 128);
        let a = encode_interleaved(&symbols, &table, 8, false).unwrap();
        let b = encode_interleaved(&symbols, &table, 8, true).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn more_lanes_than_symbols() {
        let (symbols, table) = sample(3, 5, 8);
        let bytes = encode_interleaved(&symbols, &table, 16, true).unwrap();
        assert_eq!(decode_interleaved(&bytes, &table, true).unwrap(), symbols);
    }

    #[test]
    fn empty_stream() {
        let table = FreqTable::from_symbols(&[], 4);
        let bytes = encode_interleaved(&[], &table, 4, true).unwrap();
        assert_eq!(decode_interleaved(&bytes, &table, true).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn interleaving_overhead_is_small() {
        // Header + per-lane state words only: ~6 bytes per extra lane.
        let (symbols, table) = sample(4, 100_000, 32);
        let one = encode_interleaved(&symbols, &table, 1, false).unwrap().len();
        let eight = encode_interleaved(&symbols, &table, 8, false).unwrap().len();
        assert!(eight < one + 8 * 16, "1 lane {one}B vs 8 lanes {eight}B");
    }

    #[test]
    fn corrupt_headers_rejected() {
        let (symbols, table) = sample(5, 100, 8);
        let bytes = encode_interleaved(&symbols, &table, 2, false).unwrap();
        assert!(parse_stream(&bytes[..1]).is_err());
        let mut garbled = bytes.clone();
        garbled[0] = 0xFF; // lane count varint → huge
        assert!(decode_interleaved(&garbled, &table, false).is_err());
        let truncated = &bytes[..bytes.len() - 1];
        assert!(decode_interleaved(truncated, &table, false).is_err());
    }
}
