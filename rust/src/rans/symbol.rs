//! Precomputed per-symbol coding metadata for the division-free rANS
//! core (ryg/rans_static style).
//!
//! The textbook state transition (Eq. 2) costs a hardware `div` + `mod`
//! per encoded symbol and three dependent table loads per decoded
//! symbol. Both are paid once per *table* instead:
//!
//! * [`EncSymbol`] replaces `state / freq` and `state % freq` with one
//!   widening multiply by a fixed-point reciprocal plus a shift — an
//!   **exact** integer division, so the emitted bytes are identical.
//! * [`DecEntry`] fuses the decoder's `slot → symbol`, `freq`, and
//!   `cdf` lookups into a single 8-byte entry, one load per symbol;
//!   the full table is `SCALE` × 8 B = 32 KiB, L1-resident.
//!
//! # Why the reciprocal is 33 bits, not 32
//!
//! rans_static's 32-bit `rcp_freq = ceil(2^(31+shift) / freq)` is exact
//! only while `x · e < 2^(31+shift)` for the reciprocal error
//! `e = rcp·freq − 2^(31+shift) < freq`. With byte-wise renormalization
//! (`x < 2^(31−scale_bits)·freq`) that bound always holds, but our codec
//! renormalizes 16 bits at a time, so `x < 2^(32−SCALE_BITS)·freq` and
//! the bound fails by one bit for `freq ∈ [2897, 4095]` (exhaustively
//! confirmed by `rust/tests/golden/gen_golden.py`). We therefore use the
//! (shift+33)-bit reciprocal `m = ceil(2^(32+shift) / freq)`. Its top
//! bit is always set (`2^32 ≤ m < 2^33`), so only the low 32 bits are
//! stored and the quotient folds into one multiply-high and one add:
//!
//! ```text
//! m = 2^32 + rcp_lo
//! q = floor(x·m / 2^(32+shift))
//!   = (x + mulhi32(x, rcp_lo)) >> shift        // exact for all x < 2^32
//! ```
//!
//! Exactness: with `e = m·freq − 2^(32+shift) ≤ freq − 1 < 2^shift`,
//! the error term satisfies `x·e ≤ (2^32−1)(freq−1) < 2^(32+shift)`,
//! which is the Alverson/Granlund–Montgomery sufficient condition for
//! `q = floor(x/freq)` over the whole 32-bit state range — no special
//! case for `freq == 1` (then `rcp_lo == 0`, `shift == 0`, `q = x`).

use super::freq::{SCALE, SCALE_BITS};

/// Encoder-side renormalization emits 16 bits whenever
/// `state >= x_max = 2^(32−SCALE_BITS) · freq`; one flush always
/// suffices because it leaves `state < 2^16 ≤ x_max`.
const X_MAX_SHIFT: u32 = 32 - SCALE_BITS;

/// Per-symbol encoder metadata: everything the state transition
/// `C(s, x) = floor(x/f)·2^n + F(s) + (x mod f)` needs, with the
/// division strength-reduced to a reciprocal multiply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EncSymbol {
    /// Renormalization bound `2^(32−SCALE_BITS) · freq` (up to `2^32`,
    /// hence 64-bit). Zero for never-seen symbols, which the encoder
    /// rejects before touching the state.
    pub x_max: u64,
    /// Low 32 bits of the reciprocal `m = 2^32 + rcp_lo`
    /// `= ceil(2^(32+rcp_shift) / freq)`.
    pub rcp_lo: u32,
    /// Post-multiply shift: `ceil(log2(freq))`.
    pub rcp_shift: u32,
    /// Additive bias `F(s)` (the symbol's exclusive CDF / start slot).
    pub bias: u32,
    /// `SCALE − freq`, so `C(s, x) = x + bias + q·cmpl_freq`.
    pub cmpl_freq: u32,
    /// Normalized frequency `f(s)` (0 for never-seen symbols).
    pub freq: u32,
}

impl EncSymbol {
    /// Build the metadata for a symbol with normalized frequency `freq`
    /// and exclusive CDF `cdf`. `freq == 0` yields an inert entry the
    /// encoder refuses to code.
    pub fn new(freq: u32, cdf: u32) -> Self {
        debug_assert!(freq <= SCALE && cdf + freq <= SCALE);
        if freq == 0 {
            return EncSymbol {
                x_max: 0,
                rcp_lo: 0,
                rcp_shift: 0,
                bias: 0,
                cmpl_freq: 0,
                freq: 0,
            };
        }
        // ceil(log2(freq)): 0 for freq == 1, SCALE_BITS for freq == SCALE.
        let shift = u32::BITS - (freq - 1).leading_zeros();
        // m = ceil(2^(32+shift) / freq) ∈ [2^32, 2^33); store m − 2^32.
        let m = ((1u64 << (32 + shift)) + freq as u64 - 1) / freq as u64;
        debug_assert!((1u64 << 32..1u64 << 33).contains(&m));
        EncSymbol {
            x_max: (freq as u64) << X_MAX_SHIFT,
            rcp_lo: (m - (1u64 << 32)) as u32,
            rcp_shift: shift,
            bias: cdf,
            cmpl_freq: SCALE - freq,
            freq,
        }
    }

    /// Exact `state / freq` via the reciprocal (valid for any 32-bit
    /// state; the encoder only calls it with `state < x_max`).
    #[inline(always)]
    pub fn quotient(&self, state: u32) -> u32 {
        let x = state as u64;
        ((x + ((x * self.rcp_lo as u64) >> 32)) >> self.rcp_shift) as u32
    }
}

/// Fused decoder entry for one slot: symbol identity, its frequency,
/// and `bias = slot − F(sym)` (the offset inside the symbol's slot
/// range), so the inverse transition
/// `D(x) = f·floor(x/2^n) + (x mod 2^n) − F(sym)` needs exactly one
/// table load:
///
/// ```text
/// e = table[state & (SCALE−1)]
/// state = e.freq · (state >> SCALE_BITS) + e.bias
/// ```
///
/// `repr(C, align(8))` pads the three `u16`s to an 8-byte stride so
/// entries never straddle a cache line — and, with the padding held in
/// an explicit *zeroed* field, every byte of the entry is initialized,
/// so the SIMD gather decoder ([`crate::rans::simd`]) may load a whole
/// slot as one `u64` (little-endian: `sym | freq << 16 | bias << 32`)
/// without touching uninitialized memory. Construct entries through
/// [`DecEntry::new`] so the padding invariant can't be skipped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(C, align(8))]
pub struct DecEntry {
    /// Symbol owning this slot.
    pub sym: u16,
    /// Normalized frequency `f(sym)` (≤ `SCALE`, fits `u16`).
    pub freq: u16,
    /// `slot − F(sym)` ∈ `[0, freq)`.
    pub bias: u16,
    /// Explicit padding, always zero (see the struct docs).
    pad: u16,
}

impl DecEntry {
    /// Build an entry with the padding zeroed.
    #[inline]
    pub const fn new(sym: u16, freq: u16, bias: u16) -> Self {
        DecEntry { sym, freq, bias, pad: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// The reciprocal must reproduce hardware division exactly for every
    /// normalized frequency at adversarial states. The only residue
    /// class where an off-by-one can appear is `x ≡ freq−1 (mod freq)`,
    /// so boundaries around multiples of `freq` are probed explicitly
    /// (gen_golden.py runs the exhaustive sweep; this is the fast CI
    /// version).
    #[test]
    fn reciprocal_matches_division_for_all_freqs() {
        let mut rng = Rng::new(0xD1CE);
        for freq in 1..=SCALE {
            let e = EncSymbol::new(freq, 0);
            let hi = e.x_max.min(1u64 << 32);
            let mut probe = |x: u64| {
                if x < hi {
                    let x = x as u32;
                    assert_eq!(e.quotient(x), x / freq, "freq={freq} x={x}");
                }
            };
            for k in [hi / freq as u64, hi / freq as u64 / 2, 1, 2] {
                let base = k * freq as u64;
                probe(base.wrapping_sub(1));
                probe(base);
                probe(base + 1);
            }
            probe(hi - 1);
            for _ in 0..16 {
                probe(rng.below(hi));
            }
        }
    }

    #[test]
    fn transition_matches_textbook_formula() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let freq = 1 + rng.below(SCALE as u64 - 1) as u32;
            let cdf = rng.below((SCALE - freq) as u64 + 1) as u32;
            let e = EncSymbol::new(freq, cdf);
            for _ in 0..50 {
                // States the encoder can hold at transition time.
                let state = rng.below(e.x_max) as u32;
                let q = e.quotient(state);
                let fast = state + e.bias + q * e.cmpl_freq;
                let exact = ((state / freq) << SCALE_BITS) + (state % freq) + cdf;
                assert_eq!(fast, exact, "freq={freq} cdf={cdf} state={state}");
            }
        }
    }

    #[test]
    fn zero_freq_entry_is_inert() {
        let e = EncSymbol::new(0, 0);
        assert_eq!(e.x_max, 0);
        assert_eq!(e.freq, 0);
    }

    #[test]
    fn full_mass_symbol() {
        // freq == SCALE: shift == SCALE_BITS, reciprocal exact power of 2.
        let e = EncSymbol::new(SCALE, 0);
        assert_eq!(e.rcp_lo, 0);
        assert_eq!(e.rcp_shift, SCALE_BITS);
        assert_eq!(e.cmpl_freq, 0);
        assert_eq!(e.x_max, 1u64 << 32);
        assert_eq!(e.quotient(0xFFFF_FFFF), 0xFFFF_FFFF >> SCALE_BITS);
    }

    #[test]
    fn dec_entry_is_8_bytes() {
        assert_eq!(std::mem::size_of::<DecEntry>(), 8);
        assert_eq!(std::mem::align_of::<DecEntry>(), 8);
    }

    /// The SIMD gather decoder loads entries as little-endian `u64`s
    /// (`sym | freq << 16 | bias << 32`); the `repr(C)` field order and
    /// the zeroed explicit padding must uphold that view exactly.
    #[test]
    #[cfg(target_endian = "little")]
    fn dec_entry_u64_view_matches_fields() {
        for (sym, freq, bias) in [(0u16, 1u16, 0u16), (7, 4095, 4094), (65535, 1, 0)] {
            let e = DecEntry::new(sym, freq, bias);
            // SAFETY: DecEntry is repr(C, align(8)), 8 bytes, with every
            // byte initialized (explicit zero padding), so reading it
            // back as a u64 is defined.
            let bits = unsafe { *(&e as *const DecEntry as *const u64) };
            let expect = sym as u64 | (freq as u64) << 16 | (bias as u64) << 32;
            assert_eq!(bits, expect, "sym={sym} freq={freq} bias={bias}");
        }
    }
}
