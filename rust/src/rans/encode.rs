//! Scalar rANS encoder.
//!
//! Implements the state transition of Eq. (2):
//!
//! ```text
//! s_i = floor(s_{i-1} / f(x_i)) * 2^n + F(x_i) + (s_{i-1} mod f(x_i))
//! ```
//!
//! with `2^n = SCALE` and 16-bit renormalization: the state lives in
//! `[2^16, 2^32)`; before absorbing a symbol whose frequency would push
//! it out of range, the low 16 bits are flushed to the byte stream
//! (the "Encoder Side" renormalization of §2.1).
//!
//! Symbols are consumed in *reverse* order and the emitted bytes are
//! reversed at the end, so the decoder walks both the symbol stream and
//! the byte stream forward — the standard LIFO→FIFO arrangement.

use crate::error::{Error, Result};

use super::freq::{FreqTable, SCALE_BITS};

/// Lower bound of the normalized state interval (`2^16`).
pub const STATE_LOWER: u32 = 1 << 16;

/// Encode `symbols` under `table`, returning the bitstream.
///
/// Layout: `[4-byte final state LE] [renormalization bytes, decode order]`.
/// An empty symbol stream encodes to the 4-byte initial state only.
///
/// Errors if a symbol is outside the table's alphabet or has zero
/// normalized frequency (i.e. never occurred when the table was built).
pub fn encode(symbols: &[u32], table: &FreqTable) -> Result<Vec<u8>> {
    let m = table.alphabet() as u32;
    let mut state: u32 = STATE_LOWER;
    // Renormalization bytes are pushed in encode order (reverse of decode
    // order) and reversed once at the end.
    let mut rev_bytes: Vec<u8> = Vec::with_capacity(symbols.len());

    for &sym in symbols.iter().rev() {
        if sym >= m {
            return Err(Error::codec(format!("symbol {sym} outside alphabet {m}")));
        }
        let freq = table.freq_of(sym);
        if freq == 0 {
            return Err(Error::codec(format!("symbol {sym} has zero frequency")));
        }
        // Renormalize: max state from which we can encode `sym` and stay
        // below 2^32 after the transition. Computed in u64: with a
        // full-mass symbol (freq == SCALE) the bound is exactly 2^32.
        let x_max = (((STATE_LOWER >> SCALE_BITS) as u64) << 16) * freq as u64;
        while state as u64 >= x_max {
            // Push hi then lo: the final whole-stream reversal restores
            // little-endian order within each 16-bit chunk while putting
            // chunks in decode (reverse-encode) order.
            rev_bytes.push(((state >> 8) & 0xFF) as u8);
            rev_bytes.push((state & 0xFF) as u8);
            state >>= 16;
        }
        // Eq. (2).
        state = ((state / freq) << SCALE_BITS) + (state % freq) + table.cdf_of(sym);
    }

    let mut out = Vec::with_capacity(4 + rev_bytes.len());
    out.extend_from_slice(&state.to_le_bytes());
    out.extend(rev_bytes.iter().rev());
    Ok(out)
}

/// Exact encoded size in bytes without materializing the stream
/// (used by cost-model validation tests).
pub fn encoded_len(symbols: &[u32], table: &FreqTable) -> Result<usize> {
    encode(symbols, table).map(|v| v.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rans::decode::decode;

    #[test]
    fn empty_stream_is_header_only() {
        let table = FreqTable::from_symbols(&[], 8);
        let bytes = encode(&[], &table).unwrap();
        assert_eq!(bytes.len(), 4);
        assert_eq!(decode(&bytes, 0, &table).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn rejects_out_of_alphabet() {
        let table = FreqTable::from_symbols(&[0, 1, 2], 3);
        assert!(encode(&[3], &table).is_err());
    }

    #[test]
    fn rejects_zero_frequency_symbol() {
        // Symbol 2 never occurs in the training stream.
        let table = FreqTable::from_symbols(&[0, 0, 1], 3);
        assert!(encode(&[2], &table).is_err());
    }

    #[test]
    fn single_symbol_stream() {
        let table = FreqTable::from_symbols(&[5], 8);
        let bytes = encode(&[5], &table).unwrap();
        assert_eq!(decode(&bytes, 1, &table).unwrap(), vec![5]);
    }

    #[test]
    fn degenerate_distribution_compresses_hard() {
        // 10k copies of one symbol: entropy 0, so output ≈ header only.
        let symbols = vec![3u32; 10_000];
        let table = FreqTable::from_symbols(&symbols, 8);
        let bytes = encode(&symbols, &table).unwrap();
        assert!(bytes.len() <= 8, "got {} bytes", bytes.len());
    }
}
