//! Scalar rANS encoder, division-free.
//!
//! Implements the state transition of Eq. (2):
//!
//! ```text
//! s_i = floor(s_{i-1} / f(x_i)) * 2^n + F(x_i) + (s_{i-1} mod f(x_i))
//! ```
//!
//! with `2^n = SCALE` and 16-bit renormalization: the state lives in
//! `[2^16, 2^32)`; before absorbing a symbol whose frequency would push
//! it out of range, the low 16 bits are flushed to the byte stream
//! (the "Encoder Side" renormalization of §2.1).
//!
//! The division and modulo are strength-reduced to one widening
//! multiply by a precomputed per-symbol reciprocal
//! ([`super::symbol::EncSymbol`], built lazily by
//! [`FreqTable::enc_table`]). The reciprocal division is *exact*, so
//! the emitted bytes are identical to the textbook div/mod encoder —
//! `rust/tests/golden_vectors.rs` pins this byte-for-byte against
//! committed golden vectors and an in-test reference implementation.
//!
//! Renormalization is a single branch, not a loop: one 16-bit flush
//! leaves `state < 2^16 ≤ x_max` (since `x_max = 2^20·freq ≥ 2^20`),
//! so a second iteration can never fire.
//!
//! Symbols are consumed in *reverse* order and the emitted bytes are
//! reversed at the end, so the decoder walks both the symbol stream and
//! the byte stream forward — the standard LIFO→FIFO arrangement.

use crate::error::{Error, Result};

use super::freq::FreqTable;

/// Lower bound of the normalized state interval (`2^16`).
pub const STATE_LOWER: u32 = 1 << 16;

/// The shared encoder core: runs the full state recurrence and hands
/// every 16-bit renormalization flush to `flush(hi, lo)`. [`encode`]
/// materializes the stream; [`encoded_len`] only counts — one
/// definition, so the two can never drift.
///
/// Returns the final state.
#[inline(always)]
fn encode_core(
    symbols: &[u32],
    table: &FreqTable,
    mut flush: impl FnMut(u8, u8),
) -> Result<u32> {
    let m = table.alphabet() as u32;
    let enc = table.enc_table();
    let mut state: u32 = STATE_LOWER;

    for &sym in symbols.iter().rev() {
        if sym >= m {
            return Err(Error::codec(format!("symbol {sym} outside alphabet {m}")));
        }
        let e = &enc[sym as usize];
        if e.freq == 0 {
            return Err(Error::codec(format!("symbol {sym} has zero frequency")));
        }
        // Renormalize (at most once — see module docs). Push hi then lo:
        // the final whole-stream reversal restores little-endian order
        // within each 16-bit chunk while putting chunks in decode
        // (reverse-encode) order.
        if state as u64 >= e.x_max {
            flush((state >> 8) as u8, state as u8);
            state >>= 16;
        }
        // Eq. (2), division-free: q = state / freq exactly, then
        // C(s, state) = state + F(s) + q·(SCALE − freq).
        let q = e.quotient(state);
        state = state + e.bias + q * e.cmpl_freq;
    }
    Ok(state)
}

/// Encode `symbols` under `table`, returning the bitstream.
///
/// Layout: `[4-byte final state LE] [renormalization bytes, decode order]`.
/// An empty symbol stream encodes to the 4-byte initial state only.
///
/// Errors if a symbol is outside the table's alphabet or has zero
/// normalized frequency (i.e. never occurred when the table was built).
pub fn encode(symbols: &[u32], table: &FreqTable) -> Result<Vec<u8>> {
    // Renormalization bytes are pushed in encode order (reverse of decode
    // order) and reversed once at the end.
    let mut rev_bytes: Vec<u8> = Vec::with_capacity(symbols.len());
    let state = encode_core(symbols, table, |hi, lo| {
        rev_bytes.push(hi);
        rev_bytes.push(lo);
    })?;

    let mut out = Vec::with_capacity(4 + rev_bytes.len());
    out.extend_from_slice(&state.to_le_bytes());
    out.extend(rev_bytes.iter().rev());
    Ok(out)
}

/// Exact encoded size in bytes without materializing the stream: runs
/// the same state recurrence as [`encode`] but only counts
/// renormalization flushes (used by cost-model validation tests and
/// size probes on the reshape search path).
pub fn encoded_len(symbols: &[u32], table: &FreqTable) -> Result<usize> {
    let mut renorm_bytes = 0usize;
    encode_core(symbols, table, |_, _| renorm_bytes += 2)?;
    Ok(4 + renorm_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rans::decode::decode;
    use crate::util::prng::Rng;

    #[test]
    fn empty_stream_is_header_only() {
        let table = FreqTable::from_symbols(&[], 8);
        let bytes = encode(&[], &table).unwrap();
        assert_eq!(bytes.len(), 4);
        assert_eq!(decode(&bytes, 0, &table).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn rejects_out_of_alphabet() {
        let table = FreqTable::from_symbols(&[0, 1, 2], 3);
        assert!(encode(&[3], &table).is_err());
        assert!(encoded_len(&[3], &table).is_err());
    }

    #[test]
    fn rejects_zero_frequency_symbol() {
        // Symbol 2 never occurs in the training stream.
        let table = FreqTable::from_symbols(&[0, 0, 1], 3);
        assert!(encode(&[2], &table).is_err());
        assert!(encoded_len(&[2], &table).is_err());
    }

    #[test]
    fn single_symbol_stream() {
        let table = FreqTable::from_symbols(&[5], 8);
        let bytes = encode(&[5], &table).unwrap();
        assert_eq!(decode(&bytes, 1, &table).unwrap(), vec![5]);
    }

    #[test]
    fn degenerate_distribution_compresses_hard() {
        // 10k copies of one symbol: entropy 0, so output ≈ header only.
        let symbols = vec![3u32; 10_000];
        let table = FreqTable::from_symbols(&symbols, 8);
        let bytes = encode(&symbols, &table).unwrap();
        assert!(bytes.len() <= 8, "got {} bytes", bytes.len());
    }

    /// `encoded_len` must agree with `encode(...).len()` on randomized
    /// streams across distribution shapes (the counting pass shares the
    /// state recurrence, so any drift is a real bug).
    #[test]
    fn encoded_len_matches_materialized_stream() {
        let mut rng = Rng::new(0xBEEF);
        for (alphabet, zipf_s) in [(4usize, 1.0), (32, 1.3), (256, 2.0)] {
            for len in [0usize, 1, 5, 997, 20_000] {
                let symbols: Vec<u32> =
                    (0..len).map(|_| rng.zipf(alphabet, zipf_s) as u32).collect();
                let table = FreqTable::from_symbols(&symbols, alphabet);
                let bytes = encode(&symbols, &table).unwrap();
                assert_eq!(
                    encoded_len(&symbols, &table).unwrap(),
                    bytes.len(),
                    "alphabet {alphabet} len {len}"
                );
            }
        }
        // Degenerate full-mass table.
        let symbols = vec![0u32; 5000];
        let table = FreqTable::from_symbols(&symbols, 1);
        assert_eq!(
            encoded_len(&symbols, &table).unwrap(),
            encode(&symbols, &table).unwrap().len()
        );
    }
}
