//! Scalar rANS decoder with a fused slot table.
//!
//! Implements symbol recovery (Eq. 3) and the inverse state transition
//! (Eq. 4):
//!
//! ```text
//! slot = s_i mod 2^n ;   x_i  such that  F(x_i) ≤ slot < F(x_i + 1)
//! s_{i-1} = f(x_i) * floor(s_i / 2^n) + slot − F(x_i)
//! ```
//!
//! plus the "Decoder Side" renormalization of §2.1: whenever the state
//! falls below `2^16`, two bytes are fetched from the stream.
//!
//! Two deviations from the textbook loop, both load/branch reductions
//! with provably identical output on valid streams:
//!
//! * The three dependent lookups (`slot → sym`, `freq[sym]`,
//!   `cdf[sym]`) are fused into one 8-byte
//!   [`super::symbol::DecEntry`] load per symbol; the entry's `bias`
//!   field pre-folds `slot − F(sym)`, so Eq. (4) becomes
//!   `s ← freq · (s >> n) + bias`.
//! * Renormalization is a single branch, not a loop. With a 32-bit
//!   state, 16-bit refills, and `SCALE_BITS = 12`: a valid stream keeps
//!   `s ≥ 2^16` at the top of each iteration, so
//!   `freq · (s >> 12) + bias ≥ 1·2^4 > 0`, and one refill lifts any
//!   state `≥ 1` back to `≥ 2^16`. A second iteration could only fire
//!   from state 0 — unreachable from a valid header; corrupt streams
//!   that reach it fail the final state/position checks (and the
//!   container CRC upstream) exactly as before.

use crate::error::{Error, Result};

use super::encode::STATE_LOWER;
use super::freq::{FreqTable, SCALE, SCALE_BITS};

/// Decode exactly `count` symbols from `bytes` under `table`.
///
/// `bytes` must be a stream produced by [`super::encode::encode`] with
/// the same table; anything else yields `Error::Corrupt` (truncation) or
/// garbage symbols that fail downstream CRC checks in the container.
pub fn decode(bytes: &[u8], count: usize, table: &FreqTable) -> Result<Vec<u32>> {
    if bytes.len() < 4 {
        return Err(Error::corrupt("rANS stream shorter than state header"));
    }
    let mut state = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    let mut pos = 4usize;
    // `count` comes from untrusted headers on the serving path; cap the
    // up-front reservation and let the vec grow organically so a forged
    // count fails in the decode loop instead of aborting the allocator.
    let mut out = Vec::with_capacity(count.min(1 << 20));
    let dec = table.dec_table();
    let mask = SCALE - 1;

    for _ in 0..count {
        // Eq. (3) + Eq. (4): one fused load yields the symbol, its
        // frequency, and the pre-folded `slot − F(sym)` bias.
        let e = dec[(state & mask) as usize];
        state = (e.freq as u32) * (state >> SCALE_BITS) + e.bias as u32;
        // Renormalize (at most once — see module docs).
        if state < STATE_LOWER {
            if pos + 2 > bytes.len() {
                return Err(Error::corrupt("rANS stream truncated mid-renormalization"));
            }
            let lo = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as u32;
            state = (state << 16) | lo;
            pos += 2;
        }
        out.push(e.sym as u32);
    }

    if state != STATE_LOWER {
        return Err(Error::corrupt(format!(
            "rANS final state {state:#x}, expected {STATE_LOWER:#x}"
        )));
    }
    if pos != bytes.len() {
        return Err(Error::corrupt(format!(
            "rANS stream has {} trailing bytes",
            bytes.len() - pos
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rans::encode::encode;
    use crate::util::prng::Rng;

    fn sample_stream(seed: u64, len: usize, alphabet: usize) -> (Vec<u32>, FreqTable) {
        let mut rng = Rng::new(seed);
        let symbols: Vec<u32> = (0..len).map(|_| rng.zipf(alphabet, 1.1) as u32).collect();
        let table = FreqTable::from_symbols(&symbols, alphabet);
        (symbols, table)
    }

    #[test]
    fn truncated_stream_detected() {
        let (symbols, table) = sample_stream(1, 5000, 40);
        let bytes = encode(&symbols, &table).unwrap();
        // Header-only truncation.
        assert!(decode(&bytes[..3], symbols.len(), &table).is_err());
        // Drop trailing payload bytes: either truncation is detected or
        // the final-state check fires.
        let cut = &bytes[..bytes.len() - 2];
        assert!(decode(cut, symbols.len(), &table).is_err());
    }

    #[test]
    fn trailing_garbage_detected() {
        let (symbols, table) = sample_stream(2, 1000, 16);
        let mut bytes = encode(&symbols, &table).unwrap();
        bytes.extend_from_slice(&[0xAB, 0xCD]);
        assert!(decode(&bytes, symbols.len(), &table).is_err());
    }

    #[test]
    fn wrong_count_detected() {
        let (symbols, table) = sample_stream(3, 1000, 16);
        let bytes = encode(&symbols, &table).unwrap();
        // Asking for fewer symbols leaves payload/state inconsistent.
        assert!(decode(&bytes, symbols.len() - 1, &table).is_err());
    }

    #[test]
    fn bitflip_detected_or_changes_output() {
        // A flipped byte cannot silently decode to the original symbols.
        let (symbols, table) = sample_stream(4, 2000, 32);
        let mut bytes = encode(&symbols, &table).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        match decode(&bytes, symbols.len(), &table) {
            Err(_) => {}
            Ok(decoded) => assert_ne!(decoded, symbols),
        }
    }

    /// A corrupt header can start the state below `2^16` — the one
    /// regime where the single-branch renorm and the textbook `while`
    /// loop could behave differently. The decoder must still fail
    /// cleanly, never panic or loop.
    #[test]
    fn sub_renorm_header_state_fails_cleanly() {
        let (symbols, table) = sample_stream(5, 500, 16);
        let mut bytes = encode(&symbols, &table).unwrap();
        for forged in [0u32, 1, 0xFFFF] {
            bytes[0..4].copy_from_slice(&forged.to_le_bytes());
            match decode(&bytes, symbols.len(), &table) {
                Err(_) => {}
                Ok(decoded) => assert_ne!(decoded, symbols),
            }
        }
    }
}
