//! Frequency tables for the rANS/tANS coders.
//!
//! The paper transmits the summed frequency vector `F` of the
//! concatenated stream `D = v ⊕ c ⊕ r` as side information; this module
//! owns that representation. Raw counts are normalized so they sum to
//! `2^SCALE_BITS` (the paper's `2^n` precision), every occurring symbol
//! keeps a nonzero share, and the decoder can rebuild CDFs and an O(1)
//! slot→symbol table from the serialized counts alone.

use std::sync::OnceLock;

use crate::error::{Error, Result};
use crate::util::varint;

use super::symbol::{DecEntry, EncSymbol};

/// Precision of normalized frequencies: totals sum to `2^SCALE_BITS`.
///
/// 12 bits keeps the slot→symbol table at 4096 entries (L1-resident) and
/// leaves 16-bit renormalization exact with a 32-bit state.
pub const SCALE_BITS: u32 = 12;

/// `2^SCALE_BITS`.
pub const SCALE: u32 = 1 << SCALE_BITS;

/// Normalized frequency table with CDF and fused decode/encode lookup.
#[derive(Debug, Clone)]
pub struct FreqTable {
    /// Normalized frequency per symbol; sums to [`SCALE`].
    freq: Vec<u32>,
    /// Exclusive cumulative frequencies; `cdf[m] == SCALE`.
    cdf: Vec<u32>,
    /// Fused `slot → {sym, freq, bias}` decode table, `SCALE` entries
    /// of 8 bytes — one L1-resident load per decoded symbol.
    dec: Vec<DecEntry>,
    /// Division-free encoder metadata, one entry per symbol. Built on
    /// first use and cached for the table's lifetime, so the engine's
    /// plan cache and every interleaved/chunked path that shares a
    /// table (via `Arc` or otherwise) pays the build cost once.
    enc: OnceLock<Box<[EncSymbol]>>,
}

/// Tables are equal iff their normalized frequencies are equal; the
/// CDF and the fused decode/encode tables are pure functions of `freq`.
impl PartialEq for FreqTable {
    fn eq(&self, other: &Self) -> bool {
        self.freq == other.freq
    }
}

impl FreqTable {
    /// Build a table from raw (unnormalized) counts.
    ///
    /// `counts.len()` is the alphabet size `m` (≤ 2^16). At least one
    /// count must be nonzero. Symbols with nonzero raw counts are
    /// guaranteed a nonzero normalized frequency, so any symbol present
    /// in the data remains encodable.
    pub fn from_counts(counts: &[u64]) -> Result<Self> {
        let m = counts.len();
        if m == 0 {
            return Err(Error::invalid("empty alphabet"));
        }
        if m > u16::MAX as usize + 1 {
            return Err(Error::invalid(format!("alphabet {m} exceeds 65536")));
        }
        if m as u32 > SCALE {
            return Err(Error::invalid(format!(
                "alphabet {m} exceeds frequency precision {SCALE}"
            )));
        }
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return Err(Error::invalid("all-zero frequency counts"));
        }

        // Largest-remainder normalization to SCALE, with a floor of 1 for
        // occurring symbols.
        let mut freq = vec![0u32; m];
        let mut assigned: u32 = 0;
        let mut remainders: Vec<(f64, usize)> = Vec::with_capacity(m);
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let exact = c as f64 * SCALE as f64 / total as f64;
            let floor = (exact.floor() as u32).max(1);
            freq[i] = floor;
            assigned += floor;
            remainders.push((exact - exact.floor(), i));
        }
        // Distribute leftovers (or claw back overshoot) by remainder rank.
        if assigned < SCALE {
            let mut need = SCALE - assigned;
            remainders.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            let mut idx = 0;
            while need > 0 {
                let (_, i) = remainders[idx % remainders.len()];
                freq[i] += 1;
                need -= 1;
                idx += 1;
            }
        } else if assigned > SCALE {
            let mut excess = assigned - SCALE;
            // Take from the largest frequencies first; never drop below 1.
            let mut order: Vec<usize> = (0..m).filter(|&i| freq[i] > 1).collect();
            order.sort_by(|&a, &b| freq[b].cmp(&freq[a]));
            let mut idx = 0;
            while excess > 0 {
                if order.is_empty() {
                    return Err(Error::codec(
                        "cannot normalize: alphabet too large for precision",
                    ));
                }
                let i = order[idx % order.len()];
                if freq[i] > 1 {
                    freq[i] -= 1;
                    excess -= 1;
                }
                idx += 1;
                // Periodically re-filter to drop symbols that hit 1.
                if idx % order.len() == 0 {
                    order.retain(|&j| freq[j] > 1);
                }
            }
        }
        debug_assert_eq!(freq.iter().sum::<u32>(), SCALE);
        Self::from_normalized(freq)
    }

    /// Build from already-normalized frequencies (must sum to [`SCALE`]).
    pub fn from_normalized(freq: Vec<u32>) -> Result<Self> {
        let total: u64 = freq.iter().map(|&f| f as u64).sum();
        if total != SCALE as u64 {
            return Err(Error::codec(format!(
                "normalized frequencies sum to {total}, expected {SCALE}"
            )));
        }
        let m = freq.len();
        let mut cdf = vec![0u32; m + 1];
        for i in 0..m {
            cdf[i + 1] = cdf[i] + freq[i];
        }
        let mut dec = vec![DecEntry::new(0, 0, 0); SCALE as usize];
        for s in 0..m {
            for slot in cdf[s]..cdf[s + 1] {
                dec[slot as usize] =
                    DecEntry::new(s as u16, freq[s] as u16, (slot - cdf[s]) as u16);
            }
        }
        Ok(FreqTable { freq, cdf, dec, enc: OnceLock::new() })
    }

    /// Histogram `symbols` over `alphabet` and normalize.
    pub fn from_symbols(symbols: &[u32], alphabet: usize) -> Self {
        if symbols.is_empty() {
            // Degenerate-but-valid table: uniform over the alphabet. The
            // encoder never consults it for zero symbols, but decode(0)
            // needs a structurally valid table.
            let m = alphabet.max(1);
            let counts = vec![1u64; m];
            return Self::from_counts(&counts).expect("uniform table is always valid");
        }
        let counts = crate::util::stats::histogram(symbols, alphabet);
        Self::from_counts(&counts).expect("nonempty symbol stream yields valid table")
    }

    /// Alphabet size `m`.
    #[inline]
    pub fn alphabet(&self) -> usize {
        self.freq.len()
    }

    /// Normalized frequency of `sym` (0 for never-seen symbols).
    #[inline]
    pub fn freq_of(&self, sym: u32) -> u32 {
        self.freq[sym as usize]
    }

    /// Exclusive CDF (start slot) of `sym`.
    #[inline]
    pub fn cdf_of(&self, sym: u32) -> u32 {
        self.cdf[sym as usize]
    }

    /// Symbol owning `slot` (`slot < SCALE`).
    #[inline]
    pub fn sym_of_slot(&self, slot: u32) -> u32 {
        debug_assert!(slot < SCALE);
        self.dec[slot as usize].sym as u32
    }

    /// The fused `slot → {sym, freq, bias}` decode table (`SCALE`
    /// entries). The scalar decoder indexes it directly so each symbol
    /// costs exactly one table load.
    #[inline]
    pub fn dec_table(&self) -> &[DecEntry] {
        &self.dec
    }

    /// Division-free encoder metadata, one [`EncSymbol`] per symbol.
    /// Built lazily on first call and cached; concurrent first calls
    /// from pooled lanes race benignly inside the `OnceLock`.
    pub fn enc_table(&self) -> &[EncSymbol] {
        self.enc.get_or_init(|| {
            self.freq
                .iter()
                .zip(&self.cdf)
                .map(|(&f, &c)| EncSymbol::new(f, c))
                .collect()
        })
    }

    /// All normalized frequencies.
    pub fn freqs(&self) -> &[u32] {
        &self.freq
    }

    /// Shannon entropy (bits/symbol) implied by the *normalized* table.
    pub fn entropy(&self) -> f64 {
        let mut h = 0.0;
        for &f in &self.freq {
            if f > 0 {
                let p = f as f64 / SCALE as f64;
                h -= p * p.log2();
            }
        }
        h
    }

    /// Serialize as varint-packed counts (side information in the
    /// container). Layout: `m` then `m` frequencies.
    pub fn serialize(&self, out: &mut Vec<u8>) {
        varint::write_usize(out, self.freq.len());
        for &f in &self.freq {
            varint::write_u64(out, f as u64);
        }
    }

    /// Inverse of [`FreqTable::serialize`].
    pub fn deserialize(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let m = varint::read_usize(buf, pos)?;
        if m == 0 || m as u32 > SCALE {
            return Err(Error::corrupt(format!("bad alphabet size {m}")));
        }
        let mut freq = Vec::with_capacity(m);
        for _ in 0..m {
            let f = varint::read_u64(buf, pos)?;
            if f > SCALE as u64 {
                return Err(Error::corrupt("frequency exceeds precision"));
            }
            freq.push(f as u32);
        }
        Self::from_normalized(freq)
    }

    /// Serialized size in bytes, computed arithmetically from varint
    /// widths — no scratch allocation (used by cost models on the
    /// reshape search path, where it runs once per candidate `N`).
    pub fn serialized_len(&self) -> usize {
        varint::len_usize(self.freq.len())
            + self.freq.iter().map(|&f| varint::len_u64(f as u64)).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn normalization_sums_to_scale() {
        let counts = vec![5u64, 0, 3, 900, 1, 1];
        let t = FreqTable::from_counts(&counts).unwrap();
        assert_eq!(t.freqs().iter().sum::<u32>(), SCALE);
        // Occurring symbols keep nonzero mass; absent symbols get none.
        assert!(t.freq_of(0) >= 1);
        assert_eq!(t.freq_of(1), 0);
        assert!(t.freq_of(3) > t.freq_of(2));
    }

    #[test]
    fn cdf_is_consistent() {
        let counts = vec![10u64, 20, 30, 40];
        let t = FreqTable::from_counts(&counts).unwrap();
        for s in 0..4u32 {
            assert_eq!(t.cdf_of(s) + t.freq_of(s), if s == 3 { SCALE } else { t.cdf_of(s + 1) });
        }
    }

    #[test]
    fn slot_lookup_matches_cdf() {
        let mut rng = Rng::new(5);
        let counts: Vec<u64> = (0..100).map(|_| rng.below(1000)).collect();
        let t = FreqTable::from_counts(&counts).unwrap();
        for slot in 0..SCALE {
            let sym = t.sym_of_slot(slot);
            assert!(t.cdf_of(sym) <= slot && slot < t.cdf_of(sym) + t.freq_of(sym));
        }
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(FreqTable::from_counts(&[]).is_err());
        assert!(FreqTable::from_counts(&[0, 0, 0]).is_err());
        assert!(FreqTable::from_normalized(vec![1, 2, 3]).is_err());
    }

    #[test]
    fn single_symbol_table() {
        let t = FreqTable::from_counts(&[42]).unwrap();
        assert_eq!(t.freq_of(0), SCALE);
        assert_eq!(t.entropy(), 0.0);
    }

    #[test]
    fn many_rare_symbols_each_keep_mass() {
        // 3000 symbols each occurring once: below SCALE so representable.
        let counts = vec![1u64; 3000];
        let t = FreqTable::from_counts(&counts).unwrap();
        assert!(t.freqs().iter().all(|&f| f >= 1));
        assert_eq!(t.freqs().iter().sum::<u32>(), SCALE);
    }

    #[test]
    fn alphabet_above_precision_rejected() {
        let counts = vec![1u64; SCALE as usize + 1];
        assert!(FreqTable::from_counts(&counts).is_err());
    }

    #[test]
    fn serialize_roundtrip() {
        let mut rng = Rng::new(17);
        for _ in 0..20 {
            let m = rng.range_u64(1, 300) as usize;
            let counts: Vec<u64> = (0..m).map(|_| rng.below(10_000)).collect();
            if counts.iter().all(|&c| c == 0) {
                continue;
            }
            let t = FreqTable::from_counts(&counts).unwrap();
            let mut buf = Vec::new();
            t.serialize(&mut buf);
            let mut pos = 0;
            let back = FreqTable::deserialize(&buf, &mut pos).unwrap();
            assert_eq!(back, t);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn deserialize_rejects_bad_sum() {
        let mut buf = Vec::new();
        varint::write_usize(&mut buf, 2);
        varint::write_u64(&mut buf, 100);
        varint::write_u64(&mut buf, 100);
        let mut pos = 0;
        assert!(FreqTable::deserialize(&buf, &mut pos).is_err());
    }

    #[test]
    fn entropy_of_uniform_table() {
        let t = FreqTable::from_counts(&vec![7u64; 16]).unwrap();
        assert!((t.entropy() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn serialized_len_matches_serialize_exactly() {
        let mut rng = Rng::new(31);
        for _ in 0..50 {
            let m = rng.range_u64(1, 2000) as usize;
            let counts: Vec<u64> = (0..m).map(|_| rng.below(100_000)).collect();
            if counts.iter().all(|&c| c == 0) {
                continue;
            }
            let t = FreqTable::from_counts(&counts).unwrap();
            let mut buf = Vec::new();
            t.serialize(&mut buf);
            assert_eq!(t.serialized_len(), buf.len(), "m={m}");
        }
        // Degenerate single-symbol table: freq == SCALE needs 2 varint
        // bytes, alphabet length 1 needs 1.
        let t = FreqTable::from_counts(&[9]).unwrap();
        assert_eq!(t.serialized_len(), 1 + 2);
    }

    #[test]
    fn fused_decode_table_matches_accessors() {
        let mut rng = Rng::new(33);
        let counts: Vec<u64> = (0..200).map(|_| rng.below(500)).collect();
        let t = FreqTable::from_counts(&counts).unwrap();
        let dec = t.dec_table();
        assert_eq!(dec.len(), SCALE as usize);
        for slot in 0..SCALE {
            let e = dec[slot as usize];
            assert_eq!(e.sym as u32, t.sym_of_slot(slot));
            assert_eq!(e.freq as u32, t.freq_of(e.sym as u32));
            assert_eq!(e.bias as u32, slot - t.cdf_of(e.sym as u32));
        }
    }

    #[test]
    fn enc_table_is_consistent_and_cached() {
        let mut rng = Rng::new(34);
        let counts: Vec<u64> = (0..64).map(|_| rng.below(1000)).collect();
        let t = FreqTable::from_counts(&counts).unwrap();
        let a = t.enc_table().as_ptr();
        let b = t.enc_table().as_ptr();
        assert_eq!(a, b, "enc table must be built once and cached");
        for (s, e) in t.enc_table().iter().enumerate() {
            assert_eq!(e.freq, t.freq_of(s as u32));
            if e.freq > 0 {
                assert_eq!(e.bias, t.cdf_of(s as u32));
                assert_eq!(e.cmpl_freq, SCALE - e.freq);
            }
        }
    }

    #[test]
    fn clone_and_eq_ignore_lazy_state() {
        let t = FreqTable::from_counts(&[3, 5, 8]).unwrap();
        let before = t.clone();
        let _ = t.enc_table(); // populate the lazy cache on one side only
        assert_eq!(t, before);
        assert_eq!(t.clone(), before);
    }
}
